#!/usr/bin/env python
"""Diff a fresh benchmark JSON dump against a committed baseline.

The ``backend-parity`` CI job runs TABLE 8 with ``--repeats 5 --json
BENCH_exec.json`` and then gates on this script: any pallas row whose
measured ``us_per_call`` regresses more than ``--max-regress`` (default
25%) over the committed baseline fails the job.

Rows are matched by (table title, row name).  Rows present on only one
side are reported but never fail the gate (new workloads appear, old ones
retire).  Only rows whose recorded ``backend`` matches ``--backend``
(default ``pallas``) gate; pass ``--backend ''`` to gate every measured
row.  Speedups are reported alongside regressions so improvements are
visible in the CI log.

Wall-clock baselines are machine-specific: refresh the committed one from
the same class of machine that gates on it (CI refreshes from CI):

    python -m benchmarks.run --tables exec --repeats 5 --json BENCH_exec.json
    python scripts/bench_compare.py BENCH_exec.json --update

Exit status: 0 clean / regressions within bound, 1 gate failure, 2 usage.
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
from typing import Dict, Tuple

DEFAULT_BASELINE = "benchmarks/baselines/BENCH_exec.json"


def _rows(dump: dict) -> Dict[Tuple[str, str], dict]:
    out = {}
    for table, rows in dump.items():
        for rec in rows:
            out[(table, rec.get("name", "?"))] = rec
    return out


def _metric(rows: Dict[Tuple[str, str], dict], key: Tuple[str, str],
            normalize: str):
    """A row's gating metric: raw ``us_per_call``, or — with
    ``normalize`` — its ratio to the same workload's ``normalize``-backend
    row in the same dump (machine-speed independent: TABLE 8 names rows
    ``<workload>[<backend>]``)."""
    rec = rows.get(key)
    if rec is None or not rec.get("us_per_call"):
        return None
    us = rec["us_per_call"]
    if not normalize:
        return us
    table, name = key
    base_name = name.split("[", 1)[0]
    ref = rows.get((table, f"{base_name}[{normalize}]"))
    if ref is None or not ref.get("us_per_call"):
        return None
    return us / ref["us_per_call"]


def compare(new: dict, base: dict, *, backend: str, max_regress: float,
            normalize: str = "") -> Tuple[list, list, int]:
    """Return (report lines, failing lines, number of rows gated)."""
    new_rows, base_rows = _rows(new), _rows(base)
    unit = "x" if normalize else "us"
    lines, failures, gated_rows = [], [], 0
    for key in sorted(set(new_rows) | set(base_rows)):
        table, name = key
        if key not in new_rows or key not in base_rows:
            missing = "only-baseline" if key not in new_rows else "only-new"
            lines.append(f"  {missing:>14s}  {name}")
            continue
        nus = _metric(new_rows, key, normalize)
        bus = _metric(base_rows, key, normalize)
        if nus is None or bus is None:
            continue
        ratio = nus / bus
        gated = (not backend) or (new_rows[key].get("backend") == backend)
        gated_rows += gated
        tag = f"{name:40s} {bus:10.2f}{unit} -> {nus:10.2f}{unit}  " \
              f"({ratio:5.2f}x)"
        if gated and ratio > 1.0 + max_regress:
            failures.append(tag)
            lines.append("  REGRESSION  " + tag)
        else:
            lines.append("  " + ("ok    " if gated else "info  ") + tag)
    return lines, failures, gated_rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python scripts/bench_compare.py", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("new", metavar="NEW.json",
                    help="fresh dump from benchmarks.run --json")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"committed baseline (default {DEFAULT_BASELINE})")
    ap.add_argument("--backend", default="pallas",
                    help="gate only rows recorded for this backend "
                         "(default pallas; '' gates every measured row)")
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="max tolerated fractional us_per_call growth "
                         "(default 0.25 = +25%%)")
    ap.add_argument("--normalize", default="", metavar="BACKEND",
                    help="gate each row's us_per_call RATIO to the same "
                         "workload's BACKEND row in the same dump (e.g. "
                         "'reference') — machine-speed independent, so a "
                         "baseline committed from one machine gates runs "
                         "on another; default: raw us_per_call")
    ap.add_argument("--update", action="store_true",
                    help="copy NEW.json over the baseline instead of "
                         "comparing")
    args = ap.parse_args(argv)

    if args.update:
        shutil.copyfile(args.new, args.baseline)
        print(f"baseline {args.baseline} <- {args.new}")
        return 0
    try:
        with open(args.new) as f:
            new = json.load(f)
        with open(args.baseline) as f:
            base = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_compare: {e}", file=sys.stderr)
        return 2

    lines, failures, gated = compare(new, base, backend=args.backend,
                                     max_regress=args.max_regress,
                                     normalize=args.normalize)
    print(f"bench_compare: {args.new} vs {args.baseline} "
          f"(gate: backend={args.backend or '*'}, "
          f"max +{args.max_regress:.0%}"
          + (f", normalized to {args.normalize}" if args.normalize else "")
          + ")")
    print("\n".join(lines) or "  (no comparable rows)")
    if failures:
        print(f"\n{len(failures)} row(s) regressed past the bound:")
        for f in failures:
            print("  " + f)
        return 1
    if gated == 0:
        # fail CLOSED: a gate that matched nothing (renamed rows, schema
        # drift, missing normalize rows) must not pass silently
        print("\nno row matched the gate — refusing to pass an empty gate "
              "(check row names / --backend / --normalize, or --update "
              "the baseline)", file=sys.stderr)
        return 1
    print(f"\n{gated} gated row(s) within bound")
    return 0


if __name__ == "__main__":
    sys.exit(main())
