#!/usr/bin/env python
"""Diff a fresh benchmark JSON dump against a committed baseline.

Two CI gates run on this script:

* ``backend-parity`` / ``bench-trajectory`` run TABLE 8 with ``--repeats 5
  --json BENCH_exec.json`` and gate the pallas rows' measured
  ``us_per_call`` (normalized to the same run's ``reference`` rows so
  runner speed drops out) against ``benchmarks/baselines/BENCH_exec.json``.
* ``bench-smoke`` / ``bench-trajectory`` run TABLE 7 with ``--json
  BENCH_hpc.json`` and gate the *model* trajectory — ``--metric
  speedup_vs_implicit --higher-is-better`` against
  ``benchmarks/baselines/BENCH_hpc.json``; the model numbers are
  deterministic, so any drift is a real co-design change.

Rows are matched by (table title, row name).  Rows present on only one
side are reported but never fail the gate: a **new row** (a workload
added since the baseline was committed — sparse rows did this) prints a
clear "run --update" hint instead of failing opaquely; a row only in the
baseline is reported as retired.  Only rows whose recorded ``backend``
matches ``--backend`` (default ``pallas``) gate; pass ``--backend ''`` to
gate every measured row.  Speedups are reported alongside regressions so
improvements are visible in the CI log.

Wall-clock baselines are machine-specific: refresh the committed one from
the same class of machine that gates on it (CI refreshes from CI):

    python -m benchmarks.run --tables exec --repeats 5 --json BENCH_exec.json
    python scripts/bench_compare.py BENCH_exec.json --update

``--update`` creates the baseline's parent directories if needed.

Exit status: 0 clean / regressions within bound, 1 gate failure, 2 usage.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import sys
from typing import Dict, Tuple

DEFAULT_BASELINE = "benchmarks/baselines/BENCH_exec.json"


def _rows(dump: dict) -> Dict[Tuple[str, str], dict]:
    out = {}
    for table, rows in dump.items():
        if not isinstance(rows, list):
            continue   # top-level "meta" / "obs" blocks are not row tables
        for rec in rows:
            out[(table, rec.get("name", "?"))] = rec
    return out


def _metric(rows: Dict[Tuple[str, str], dict], key: Tuple[str, str],
            normalize: str, metric: str):
    """A row's gating metric: ``metric`` read from the record (top level
    first, then the ``derived`` columns), or — with ``normalize`` — its
    ratio to the same workload's ``normalize``-backend row in the same
    dump (machine-speed independent: TABLE 8 names rows
    ``<workload>[<backend>]``)."""
    rec = rows.get(key)
    if rec is None:
        return None
    val = rec.get(metric, rec.get("derived", {}).get(metric))
    # only a genuinely absent/non-numeric value is "missing": a metric of
    # exactly 0.0 (e.g. a collapsed speedup) must still gate, not slip
    # through the cracks
    if not isinstance(val, (int, float)) or isinstance(val, bool):
        return None
    if not normalize:
        return val
    table, name = key
    base_name = name.split("[", 1)[0]
    ref = rows.get((table, f"{base_name}[{normalize}]"))
    if ref is None:
        return None
    ref_val = ref.get(metric, ref.get("derived", {}).get(metric))
    if (not isinstance(ref_val, (int, float)) or isinstance(ref_val, bool)
            or ref_val == 0):
        return None
    return val / ref_val


def parse_metrics(metric: str, higher_is_better: bool = False
                  ) -> list:
    """``--metric`` spec -> ``[(name, higher_is_better), ...]``.

    Comma-separated, each entry optionally carrying its own direction as
    ``name:higher`` / ``name:lower`` — so one invocation gates throughput
    *and* latency (``requests_per_s:higher,p99_ms:lower``).  Entries
    without a suffix inherit the ``--higher-is-better`` flag.
    """
    out = []
    for part in metric.split(","):
        part = part.strip()
        if not part:
            continue
        name, sep, direction = part.partition(":")
        if not sep:
            out.append((name, higher_is_better))
        elif direction in ("higher", "lower"):
            out.append((name, direction == "higher"))
        else:
            raise ValueError(f"bad metric direction {part!r}: use "
                             "name, name:higher or name:lower")
    if not out:
        raise ValueError("empty --metric spec")
    return out


#: derived columns worth echoing when a gate trips: the operating-point
#: parameters (capacity / density / overbook / split) that tell WHICH
#: crossover cell moved, without re-running the bench
_PARAM_KEYS = ("capacity_kib", "capacity_mib", "capacity_bytes", "density",
               "overbook", "best_split", "pattern", "bandwidth")


def _row_detail(new_rec: dict, base_rec: dict, mname: str,
                raw_n, raw_b) -> str:
    """Failure forensics for one regressed row: the raw (un-normalized)
    metric value on both sides plus the row's recorded operating-point
    parameters."""
    parts = []
    if isinstance(raw_n, (int, float)) and isinstance(raw_b, (int, float)):
        parts.append(f"{mname}: baseline={raw_b:g} current={raw_n:g}")
    nd, bd = new_rec.get("derived", {}), base_rec.get("derived", {})
    for k in _PARAM_KEYS:
        if k in nd:
            v, bv = nd[k], bd.get(k)
            parts.append(f"{k}={v}" if bv in (None, v)
                         else f"{k}={v} (baseline {bv})")
    return "; ".join(parts)


def compare(new: dict, base: dict, *, backend: str, max_regress: float,
            normalize: str = "", metric: str = "us_per_call",
            higher_is_better: bool = False,
            baseline_path: str = DEFAULT_BASELINE
            ) -> Tuple[list, list, int]:
    """Return (report lines, failing lines, number of (row, metric) cells
    gated).  ``metric`` takes the :func:`parse_metrics` spec — several
    comma-separated metrics, each with its own direction, gate in one
    pass."""
    metrics = parse_metrics(metric, higher_is_better)
    new_rows, base_rows = _rows(new), _rows(base)
    unit = "x" if normalize else ""
    lines, failures, gated_rows = [], [], 0
    for key in sorted(set(new_rows) | set(base_rows)):
        table, name = key
        if key not in base_rows:
            # new workloads appear between baseline refreshes (sparse rows
            # did); report them clearly, never fail the gate on them
            lines.append(f"  new-row       {name} — not in the baseline; "
                         "run `scripts/bench_compare.py NEW.json "
                         f"--baseline {baseline_path} --update` to adopt "
                         "it")
            continue
        if key not in new_rows:
            lines.append(f"  retired       {name} — baseline only")
            continue
        for mname, higher in metrics:
            nus = _metric(new_rows, key, normalize, mname)
            bus = _metric(base_rows, key, normalize, mname)
            if nus is None or bus is None:
                continue
            # a zero baseline can't ratio: infinitely worse unless the new
            # value is zero too (then nothing changed)
            ratio = (nus / bus if bus
                     else (1.0 if nus == 0 else float("inf")))
            gated = (not backend) or \
                (new_rows[key].get("backend") == backend)
            gated_rows += gated
            label = name if len(metrics) == 1 else f"{name} [{mname}]"
            tag = f"{label:40s} {bus:10.3f}{unit} -> {nus:10.3f}{unit}  " \
                  f"({ratio:5.2f}x)"
            regressed = (ratio < 1.0 - max_regress if higher
                         else ratio > 1.0 + max_regress)
            if gated and regressed:
                detail = _row_detail(new_rows[key], base_rows[key], mname,
                                     _metric(new_rows, key, "", mname),
                                     _metric(base_rows, key, "", mname))
                if detail:
                    tag += f"\n                [{detail}]"
                failures.append(tag)
                lines.append("  REGRESSION  " + tag)
            else:
                lines.append("  " + ("ok    " if gated else "info  ") + tag)
    return lines, failures, gated_rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python scripts/bench_compare.py", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("new", metavar="NEW.json",
                    help="fresh dump from benchmarks.run --json")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"committed baseline (default {DEFAULT_BASELINE})")
    ap.add_argument("--backend", default="pallas",
                    help="gate only rows recorded for this backend "
                         "(default pallas; '' gates every measured row)")
    ap.add_argument("--metric", default="us_per_call",
                    help="which recorded value(s) gate: us_per_call "
                         "(default) or any derived column; comma-separate "
                         "several, each optionally with its own direction "
                         "(e.g. 'requests_per_s:higher,p99_ms:lower' for "
                         "the TABLE 9 serving gate)")
    ap.add_argument("--higher-is-better", action="store_true",
                    help="default direction for metrics without a "
                         ":higher/:lower suffix — the metric improves "
                         "upward (speedups): fail when it *drops* past "
                         "--max-regress instead")
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="max tolerated fractional metric regression "
                         "(default 0.25 = 25%%)")
    ap.add_argument("--normalize", default="", metavar="BACKEND",
                    help="gate each row's metric RATIO to the same "
                         "workload's BACKEND row in the same dump (e.g. "
                         "'reference') — machine-speed independent, so a "
                         "baseline committed from one machine gates runs "
                         "on another; default: the raw metric")
    ap.add_argument("--update", action="store_true",
                    help="copy NEW.json over the baseline instead of "
                         "comparing (creates parent dirs)")
    args = ap.parse_args(argv)

    if args.update:
        pathlib.Path(args.baseline).parent.mkdir(parents=True,
                                                 exist_ok=True)
        shutil.copyfile(args.new, args.baseline)
        print(f"baseline {args.baseline} <- {args.new}")
        return 0
    try:
        with open(args.new) as f:
            new = json.load(f)
        with open(args.baseline) as f:
            base = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_compare: {e}", file=sys.stderr)
        return 2

    try:
        metrics = parse_metrics(args.metric, args.higher_is_better)
        lines, failures, gated = compare(
            new, base, backend=args.backend, max_regress=args.max_regress,
            normalize=args.normalize, metric=args.metric,
            higher_is_better=args.higher_is_better,
            baseline_path=args.baseline)
    except ValueError as e:
        print(f"bench_compare: {e}", file=sys.stderr)
        return 2
    gate = ", ".join(f"{m} max {'-' if hi else '+'}"
                     f"{args.max_regress:.0%}" for m, hi in metrics)
    print(f"bench_compare: {args.new} vs {args.baseline} "
          f"(gate: backend={args.backend or '*'}, {gate}"
          + (f", normalized to {args.normalize}" if args.normalize else "")
          + ")")
    print("\n".join(lines) or "  (no comparable rows)")
    if failures:
        print(f"\n{len(failures)} row(s) regressed past the bound:")
        for f in failures:
            print("  " + f)
        return 1
    if gated == 0:
        # fail CLOSED: a gate that matched nothing (renamed rows, schema
        # drift, missing normalize rows) must not pass silently
        print("\nno row matched the gate — refusing to pass an empty gate "
              "(check row names / --backend / --metric / --normalize, or "
              "--update the baseline)", file=sys.stderr)
        return 1
    print(f"\n{gated} gated row(s) within bound")
    return 0


if __name__ == "__main__":
    sys.exit(main())
