#!/usr/bin/env python
"""Render a ``repro.obs`` artifact as a table.

Accepts any of the three export formats and auto-detects which one it got:

* a **JSONL span trace** (``CELLO_OBS=jsonl:PATH`` /
  ``tracer().export_jsonl``) — one JSON object per line;
* a **Chrome trace_event JSON** (``CELLO_OBS=chrome:PATH`` /
  ``tracer().export_chrome``) — ``{"traceEvents": [...]}``, the file you
  would load in Perfetto;
* a **metrics snapshot JSON** (``repro.obs.snapshot()`` serialized, or a
  ``benchmarks.run --json`` dump carrying it under its ``obs`` key).

Span renders show the nested timeline (indent = depth) plus a per-name
aggregate; metrics renders show one row per labeled cell, histograms with
count/mean/p50/p90/p99/max.

``--validate`` checks the file against the documented export schema
(``docs/observability.md``) instead of rendering — exit 0 on a valid file,
1 on the first violation.  CI's ``obs-smoke`` job gates on this.

    python scripts/obs_report.py /tmp/cello.trace.json
    python scripts/obs_report.py /tmp/cello.jsonl --validate
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Any, Dict, List

try:
    from repro.obs import tracing
except ImportError:                     # run from a checkout without install
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "src"))
    from repro.obs import tracing

_KINDS = ("counter", "gauge", "histogram")


# --------------------------------------------------------------------------
# format detection
# --------------------------------------------------------------------------

def detect(path: str) -> str:
    """"jsonl" | "chrome" | "metrics" for ``path`` (raises ValueError)."""
    with open(path) as f:
        head = f.read(1 << 20)
    try:
        doc = json.loads(head) if head.strip() else None
    except json.JSONDecodeError:
        doc = None
    if isinstance(doc, dict):
        if "traceEvents" in doc:
            return "chrome"
        if _looks_like_snapshot(doc) or _looks_like_snapshot(doc.get("obs")):
            return "metrics"
    # JSONL: every non-blank line its own object
    if all(line.lstrip().startswith("{")
           for line in head.splitlines() if line.strip()) and head.strip():
        return "jsonl"
    raise ValueError(f"{path}: not a span trace (jsonl/chrome) or metrics "
                     "snapshot")


def _looks_like_snapshot(doc: Any) -> bool:
    return (isinstance(doc, dict) and bool(doc)
            and all(isinstance(v, dict) and v.get("kind") in _KINDS
                    and isinstance(v.get("cells"), list)
                    for v in doc.values()))


def load_metrics(path: str) -> Dict[str, Any]:
    with open(path) as f:
        doc = json.load(f)
    if _looks_like_snapshot(doc):
        return doc
    if _looks_like_snapshot(doc.get("obs")):
        return doc["obs"]
    raise ValueError(f"{path}: no metrics snapshot found")


# --------------------------------------------------------------------------
# rendering
# --------------------------------------------------------------------------

def _fmt_args(args: Dict[str, Any]) -> str:
    return " ".join(f"{k}={v}" for k, v in sorted(args.items()))


def _span_lines(spans: List[Dict[str, Any]]) -> List[str]:
    lines = [f"{'ts_ms':>10}  {'dur_ms':>10}  span"]
    totals: Dict[str, List[float]] = {}
    for rec in spans:
        name, dur_ms = rec["name"], rec["dur_us"] / 1e3
        indent = "  " * rec.get("depth", 0)
        args = _fmt_args(rec.get("args") or {})
        lines.append(f"{rec['ts_us'] / 1e3:10.3f}  {dur_ms:10.3f}  "
                     f"{indent}{name}" + (f"  [{args}]" if args else ""))
        totals.setdefault(name, []).append(dur_ms)
    lines.append("")
    lines.append(f"{'count':>6}  {'total_ms':>10}  {'mean_ms':>10}  name")
    for name in sorted(totals):
        ds = totals[name]
        lines.append(f"{len(ds):6d}  {sum(ds):10.3f}  "
                     f"{sum(ds) / len(ds):10.3f}  {name}")
    return lines


def render_jsonl(path: str) -> List[str]:
    spans = sorted(tracing.load_jsonl(path), key=lambda r: r["ts_us"])
    return _span_lines(spans)


def render_chrome(path: str) -> List[str]:
    with open(path) as f:
        doc = json.load(f)
    spans = [{"name": ev.get("name", "?"), "ts_us": ev.get("ts", 0),
              "dur_us": ev.get("dur", 0), "depth": 0,
              "args": ev.get("args") or {}}
             for ev in doc.get("traceEvents", [])]
    spans.sort(key=lambda r: r["ts_us"])
    # reconstruct nesting from interval containment per tid-less stream:
    # a span is one deeper than the enclosing not-yet-closed span
    open_until: List[float] = []
    for rec in spans:
        while open_until and rec["ts_us"] >= open_until[-1] - 1e-9:
            open_until.pop()
        rec["depth"] = len(open_until)
        open_until.append(rec["ts_us"] + rec["dur_us"])
    return _span_lines(spans)


def render_metrics(path: str) -> List[str]:
    snap = load_metrics(path)
    lines: List[str] = []
    for name in sorted(snap):
        inst = snap[name]
        unit = f" [{inst['unit']}]" if inst.get("unit") else ""
        lines.append(f"{name}{unit}  ({inst['kind']})"
                     + (f" — {inst['help']}" if inst.get("help") else ""))
        for cell in inst.get("cells", []):
            labels = _fmt_args(cell.get("labels") or {}) or "-"
            v = cell.get("value")
            if isinstance(v, dict):                    # histogram summary
                if not v.get("count"):
                    lines.append(f"    {labels:48s}  count=0")
                    continue
                qs = "  ".join(
                    f"{q}={v[q]:.6g}" for q in
                    ("mean", "p50", "p90", "p99", "max")
                    if v.get(q) is not None)
                lines.append(f"    {labels:48s}  count={v['count']}  {qs}")
            else:
                num = f"{v:g}" if isinstance(v, float) else str(v)
                lines.append(f"    {labels:48s}  {num}")
    return lines or ["(empty snapshot)"]


# --------------------------------------------------------------------------
# validation (the documented schema contract)
# --------------------------------------------------------------------------

def validate_metrics(path: str) -> int:
    snap = load_metrics(path)
    n = 0
    for name, inst in snap.items():
        where = f"{path}: {name}"
        if inst.get("kind") not in _KINDS:
            raise ValueError(f"{where}: kind must be one of {_KINDS}")
        for cell in inst.get("cells", ()):
            if not isinstance(cell.get("labels"), dict):
                raise ValueError(f"{where}: cell labels must be an object")
            v = cell.get("value")
            if inst["kind"] == "histogram":
                if not isinstance(v, dict) or "count" not in v:
                    raise ValueError(f"{where}: histogram cell value must "
                                     "be a summary object with a count")
            elif not isinstance(v, (int, float)):
                raise ValueError(f"{where}: {inst['kind']} cell value must "
                                 "be a number")
            n += 1
    return n


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python scripts/obs_report.py", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("file", help="jsonl span trace, Chrome trace JSON, or "
                                 "metrics snapshot JSON")
    ap.add_argument("--format", choices=("auto", "jsonl", "chrome",
                                         "metrics"), default="auto",
                    help="override format auto-detection")
    ap.add_argument("--validate", action="store_true",
                    help="check the file against the documented schema "
                         "instead of rendering")
    args = ap.parse_args(argv)
    try:
        fmt = detect(args.file) if args.format == "auto" else args.format
        if args.validate:
            n = {"jsonl": tracing.validate_jsonl,
                 "chrome": tracing.validate_chrome,
                 "metrics": validate_metrics}[fmt](args.file)
            what = "spans" if fmt == "jsonl" else (
                "events" if fmt == "chrome" else "cells")
            print(f"{args.file}: valid {fmt} ({n} {what})")
            return 0
        lines = {"jsonl": render_jsonl, "chrome": render_chrome,
                 "metrics": render_metrics}[fmt](args.file)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"obs_report: {e}", file=sys.stderr)
        return 1
    print(f"# {args.file} ({fmt})")
    print("\n".join(lines))
    return 0


if __name__ == "__main__":
    sys.exit(main())
