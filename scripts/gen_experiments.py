"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from
experiments/dryrun/*.json.  §Perf is maintained by hand (hypothesis log) in
EXPERIMENTS.perf.md and embedded verbatim.

    PYTHONPATH=src python scripts/gen_experiments.py
"""
import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRY = os.path.join(ROOT, "experiments", "dryrun")

ARCH_ORDER = ["recurrentgemma-2b", "llama-3.2-vision-11b", "rwkv6-7b",
              "moonshot-v1-16b-a3b", "granite-moe-1b-a400m", "gemma-7b",
              "h2o-danube-1.8b", "minitron-8b", "granite-3-8b",
              "hubert-xlarge"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(tag=""):
    cells = {}
    for path in sorted(glob.glob(os.path.join(DRY, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        if (len(parts) > 3 and parts[3] != tag) or (len(parts) == 3 and tag):
            continue
        with open(path) as f:
            c = json.load(f)
        cells[(c["arch"], c["shape"], c["mesh"])] = c
    return cells


def fmt_bytes(b):
    return f"{b / (1 << 30):.2f}"


def main():
    cells = load()
    ok = [c for c in cells.values() if c.get("status") == "ok"]
    skipped = [c for c in cells.values() if c.get("status") == "skipped"]
    errors = [c for c in cells.values() if c.get("status") == "error"]

    lines = []
    lines.append("## §Dry-run — multi-pod lower+compile matrix\n")
    lines.append(f"Cells compiled OK: **{len(ok)}** · skipped by policy: "
                 f"{len(skipped)} (see DESIGN.md §4) · errors: {len(errors)}")
    lines.append("")
    lines.append("Mesh: single-pod 16×16 (`data`,`model`) and multi-pod "
                 "2×16×16 (`pod`,`data`,`model`), 512 placeholder host "
                 "devices. Per-device bytes from "
                 "`compiled.memory_analysis()`; every cell lowers the real "
                 "step function (train = fwd+bwd+AdamW/ZeRO-1, decode = one "
                 "token vs the sharded KV cache).\n")
    lines.append("| arch | shape | mesh | args GiB/dev | temp GiB/dev | "
                 "peak est GiB/dev | compile s | collectives (AG/AR/RS/A2A/CP) |")
    lines.append("|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("single", "multi"):
                c = cells.get((arch, shape, mesh))
                if c is None:
                    lines.append(f"| {arch} | {shape} | {mesh} | — | — | — "
                                 f"| — | MISSING |")
                    continue
                if c["status"] == "skipped":
                    lines.append(f"| {arch} | {shape} | {mesh} "
                                 f"| — | — | — | — | skipped: "
                                 f"{c['reason'][:48]} |")
                    continue
                if c["status"] != "ok":
                    lines.append(f"| {arch} | {shape} | {mesh} | — | — | — "
                                 f"| — | ERROR |")
                    continue
                m = c["memory"]
                coll = c["collectives"]
                counts = "/".join(str(int(coll.get(f"n_{k}", 0))) for k in
                                  ("all-gather", "all-reduce",
                                   "reduce-scatter", "all-to-all",
                                   "collective-permute"))
                lines.append(
                    f"| {arch} | {shape} | {mesh} "
                    f"| {fmt_bytes(m['argument_bytes'])} "
                    f"| {fmt_bytes(m['temp_bytes'])} "
                    f"| {fmt_bytes(m['peak_estimate_bytes'])} "
                    f"| {c['compile_s']:.0f} | {counts} |")
    lines.append("")

    lines.append("## §Roofline — per-cell terms (single-pod, 256 chips)\n")
    lines.append("Constants: 197 TFLOP/s bf16 · 819 GB/s HBM · 50 GB/s/link "
                 "ICI. FLOPs/bytes per chip from `cost_analysis()` of the "
                 "unrolled per-layer-leaf module; collective bytes parsed "
                 "from optimized HLO with ring factors (see "
                 "`launch/roofline.py`). `6ND/HLO` = MODEL_FLOPS ratio; "
                 "`roofline frac` = compute_s / max(terms).\n")
    lines.append("**Measurement caveat**: XLA:CPU fuses elementwise chains "
                 "less aggressively than XLA:TPU, so `bytes accessed` (and "
                 "hence the memory term) is an *upper bound* on TPU HBM "
                 "traffic; terms are comparable across variants because all "
                 "cells share one compilation pipeline.\n")
    lines.append("| arch | shape | mesh | compute ms | memory ms | "
                 "collective ms | dominant | 6ND/HLO | roofline frac | "
                 "one-line diagnosis |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|")

    def diag(c):
        r = c["roofline"]
        d = r["dominant"]
        shape = c["shape"]
        if d == "memory" and "decode" in shape or "long" in shape:
            return ("decode is cache-bandwidth bound; raise batch or "
                    "quantise KV to move it")
        if d == "memory":
            return ("activation traffic; bigger fusion tiles / fewer "
                    "materialised intermediates")
        if d == "collective":
            return ("TP/EP collectives; overlap with compute or widen "
                    "per-shard work")
        return "near compute roof; only kernel-level gains left"

    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            c = cells.get((arch, shape, "single"))
            if c is None or c.get("status") != "ok":
                continue
            r = c["roofline"]
            lines.append(
                f"| {arch} | {shape} | single "
                f"| {r['compute_s'] * 1e3:.2f} | {r['memory_s'] * 1e3:.2f} "
                f"| {r['collective_s'] * 1e3:.2f} | **{r['dominant']}** "
                f"| {r['useful_flops_ratio']:.2f} "
                f"| {r['roofline_fraction']:.3f} | {diag(c)} |")
    lines.append("")
    # multi-pod deltas (collective scaling proof)
    lines.append("### Multi-pod (2×16×16) collective deltas\n")
    lines.append("| arch | shape | coll ms single | coll ms multi | "
                 "cross-pod growth |")
    lines.append("|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            cs = cells.get((arch, shape, "single"))
            cm = cells.get((arch, shape, "multi"))
            if not cs or not cm or cs.get("status") != "ok" \
                    or cm.get("status") != "ok":
                continue
            a = cs["roofline"]["collective_s"] * 1e3
            b = cm["roofline"]["collective_s"] * 1e3
            lines.append(f"| {arch} | {shape} | {a:.2f} | {b:.2f} "
                         f"| {b / a if a else float('nan'):.2f}x |")
    lines.append("")

    out = "\n".join(lines)
    gen_path = os.path.join(ROOT, "experiments", "generated_sections.md")
    with open(gen_path, "w") as f:
        f.write(out)
    print(f"wrote {gen_path} ({len(ok)} ok, {len(skipped)} skipped, "
          f"{len(errors)} errors)")
    missing = [(a, s, m) for a in ARCH_ORDER for s in SHAPE_ORDER
               for m in ("single", "multi") if (a, s, m) not in cells]
    if missing:
        print(f"missing {len(missing)} cells: {missing[:6]} ...")


if __name__ == "__main__":
    main()
