"""Fits-per-device proof on the PRODUCTION (scan) form for the biggest
cells: the dry-run measures cost on the unrolled form (whose liveness is
inflated); this checks peak memory on the form that actually runs."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys; sys.path.insert(0, "src")
import json
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.api import Session
from repro.configs import SHAPES, get_config
from repro.models import forward, set_mesh_context
from repro.launch import shardings as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.train import (TrainConfig, jit_train_step, zero1_shardings)
from repro.optim import AdamWConfig, adamw_init

out = {}
for arch, shape_name in [("granite-3-8b", "train_4k"),
                         ("llama-3.2-vision-11b", "train_4k"),
                         ("granite-3-8b", "prefill_32k"),
                         ("moonshot-v1-16b-a3b", "train_4k")]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    set_mesh_context(mesh)
    plan = Session(cfg).default_plan(seq=shape.seq_len).plan
    specs = shd.input_specs(cfg, shape, mesh)
    params_sds, p_sh = shd.params_for(cfg, mesh)      # STACKED (scan form)
    if shape.mode == "train":
        o_sh = zero1_shardings(params_sds, p_sh, mesh, True)
        opt_sds = shd.shaped(jax.eval_shape(lambda p: adamw_init(p),
                                            params_sds), o_sh)
        fn = jit_train_step(cfg, plan, AdamWConfig(), mesh,
                            TrainConfig(remat=True, unroll=False, zero1=True,
                                        donate=True),
                            batch_specs=specs, p_shardings=p_sh,
                            o_shardings=o_sh)
        compiled = fn.lower(params_sds, opt_sds, specs).compile()
    else:
        def prefill(params, batch):
            return forward(params, cfg, plan, batch["tokens"],
                           frames=batch.get("frames"), img=batch.get("img"),
                           mode="prefill")[0]
        b_sh = jax.tree.map(lambda s: s.sharding, specs)
        compiled = jax.jit(
            prefill, in_shardings=(p_sh, b_sh),
            out_shardings=NamedSharding(mesh, P(None, None, "model"))
        ).lower(params_sds, specs).compile()
    m = compiled.memory_analysis()
    peak = (m.argument_size_in_bytes + m.output_size_in_bytes
            + m.temp_size_in_bytes - m.alias_size_in_bytes)
    out[f"{arch}/{shape_name}"] = {
        "args_gib": round(m.argument_size_in_bytes / 2**30, 2),
        "temp_gib": round(m.temp_size_in_bytes / 2**30, 2),
        "peak_gib": round(peak / 2**30, 2),
        "fits_16gib_hbm": peak < 16 * 2**30,
    }
    print(f"{arch}/{shape_name}: {out[f'{arch}/{shape_name}']}", flush=True)
with open("experiments/scan_memory_check.json", "w") as f:
    json.dump(out, f, indent=1)
