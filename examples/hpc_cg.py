"""Conjugate Gradient through the CELLO co-designer, end to end.

Builds the paper's headline HPC workload (skewed ``(n×n)·(n,)`` matvec
chains with cross-iteration reuse of the operator ``A``), runs the
schedule × buffer co-design, prints the decision (including the kernel
selected per fusion group), then executes the co-designed schedule through
both execution backends — the ``reference`` jax.numpy oracle and the
``pallas`` tile-streaming kernels — and validates them against
natural-order evaluation.

    python examples/hpc_cg.py --n 4096 --iters 4
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.api import Session
from repro.frontends import evaluate, make_feeds


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=4096,
                    help="operator size (n x n); at 4096 the fp64 operator "
                         "is exactly the 128 MiB on-chip capacity")
    ap.add_argument("--iters", type=int, default=4,
                    help="unrolled CG iterations")
    ap.add_argument("--workload", default="cg",
                    help="any registered workload that takes n/iters "
                         "(cg, bicgstab, power_iteration)")
    args = ap.parse_args()

    sess = Session()                    # arch-less: frontend traces only
    traced = sess.trace(workload=args.workload, n=args.n, iters=args.iters)
    print(f"traced   : {traced}")
    analyzed = traced.analyze()
    print(f"analyzed : {analyzed}")
    designed = analyzed.codesign()
    print(f"codesign : {designed}")
    plan = designed.lower()
    print()
    print(plan.explain())

    # numerical validation: scheduled execution vs natural-order reference,
    # on both execution backends
    feeds = make_feeds(traced.program, seed=0)
    want = evaluate(traced.program, feeds)
    print()
    got = None
    for backend in ("reference", "pallas"):
        got = plan.run(feeds, backend=backend)
        worst = max(float(np.max(np.abs(np.asarray(got[k])
                                        - np.asarray(want[k]))))
                    for k in want)
        print(f"numerical check [{backend:9s}] vs natural-order oracle: "
              f"max abs diff = {worst:.3g} over {sorted(want)}")
    if args.workload == "cg":
        r = np.asarray(got[f"r{args.iters}"])
        print(f"final CG residual norm: {np.linalg.norm(r):.4g}")


if __name__ == "__main__":
    main()
