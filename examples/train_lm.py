"""End-to-end training driver: train a granite-family LM on the synthetic
Markov corpus with the CELLO plan, AdamW, checkpointing and straggler
tracking.  Loss should drop from ~log(vocab) toward the source's conditional
entropy (~log(branching)).

    python examples/train_lm.py                 # ~10M params
    python examples/train_lm.py --preset 100m   # ~100M params
"""
import argparse
import dataclasses

import jax

from repro.api import Session
from repro.checkpoint import AsyncCheckpointer
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMData
from repro.launch.train import AdamWConfig
from repro.runtime import StragglerDetector

PRESETS = {
    # name: (n_layers, d_model, n_heads, kv, d_ff, vocab, batch, seq)
    "tiny": (2, 64, 4, 2, 128, 512, 8, 64),
    "10m": (4, 256, 8, 4, 640, 4096, 8, 128),
    "100m": (8, 640, 10, 5, 1706, 16384, 8, 256),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/cello_train_ckpt")
    args = ap.parse_args()

    L, D, H, KV, F, V, B, S = PRESETS[args.preset]
    cfg = dataclasses.replace(
        get_config("granite-3-8b"), n_layers=L, d_model=D, n_heads=H,
        n_kv_heads=KV, head_dim=D // H, d_ff=F, vocab=V,
        name=f"granite-{args.preset}")
    print(f"model: {cfg.name}  params≈{cfg.total_params() / 1e6:.1f}M")

    compiled = Session(cfg).default_plan(seq=S)
    data = SyntheticLMData(DataConfig(vocab=V, seq_len=S, global_batch=B,
                                      seed=0))
    print(f"data: markov synthetic, loss floor ≈ {data.entropy_floor():.3f} "
          f"nats (uniform would be {float(jax.numpy.log(V)):.3f})")

    straggler = StragglerDetector()
    ck = AsyncCheckpointer(args.ckpt_dir, keep=2)
    out = compiled.train(
        data_iter=iter(data), n_steps=args.steps,
        opt_cfg=AdamWConfig(lr=args.lr, warmup_steps=20,
                            total_steps=args.steps, weight_decay=0.01),
        checkpointer=ck, checkpoint_every=max(50, args.steps // 4),
        straggler=straggler, log_every=10)

    hist = out["history"]
    print(f"\nloss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"(floor ≈ {data.entropy_floor():.3f})")
    print(f"median step time: {straggler.median_step_s * 1e3:.0f} ms")
    print(f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
