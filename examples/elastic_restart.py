"""Fault-tolerance demo: training with injected node failures — every
failure restores the latest committed checkpoint, re-partitions the data
stream for the surviving capacity (elastic), and continues.

    pip install -e . && python examples/elastic_restart.py
"""
import argparse

import jax
import jax.numpy as jnp

from repro.api import Session
from repro.checkpoint import AsyncCheckpointer, latest_step, load_checkpoint
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMData
from repro.launch.train import AdamWConfig, TrainConfig, make_train_step
from repro.models import init_params
from repro.optim import adamw_init
from repro.runtime import ElasticScaler, run_with_restarts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[7, 15])
    ap.add_argument("--ckpt-dir", default="/tmp/cello_elastic_ckpt")
    args = ap.parse_args()

    cfg = get_config("granite-3-8b").reduced()
    plan = Session(cfg).default_plan(seq=32).plan
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=4, total_steps=args.steps)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw_init(params)
    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=8, seed=0))
    step_fn = jax.jit(make_train_step(cfg, plan, opt_cfg,
                                      TrainConfig(donate=False)))
    ck = AsyncCheckpointer(args.ckpt_dir, keep=3)
    scaler = ElasticScaler(model_axis=16, pod_chips=256)
    state = {"params": params, "opt": opt_state}
    fleet = {"devices": 768}           # three pods; each failure drops one
    to_fail = set(args.fail_at)

    def train_one(step: int) -> None:
        if step in to_fail:
            to_fail.discard(step)
            fleet["devices"] -= 256            # a whole pod drops out
            raise RuntimeError(f"pod failure at step {step}")
        x, y = data.batch_at(step)
        batch = {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)}
        state["params"], state["opt"], m = step_fn(state["params"],
                                                   state["opt"], batch)
        print(f"  step {step:3d}  loss {float(m['loss']):.4f}  "
              f"devices={fleet['devices']}")
        if (step + 1) % 4 == 0:
            ck.save(step + 1, state, extra={"step": step + 1})
            ck.wait()

    def restore(failed_step: int) -> int:
        last = latest_step(args.ckpt_dir) or 0
        plan_ = scaler.plan(fleet["devices"], restore_step=last)
        print(f"  !! restoring step {last} onto mesh {plan_.mesh_shape} "
              f"({plan_.n_devices} chips)")
        if last > 0:
            restored, _ = load_checkpoint(args.ckpt_dir, last, state)
            state.update(restored)
        # elastic data repartition (single host here: shard 0 of 1)
        return last

    stats = run_with_restarts(train_one, restore, n_steps=args.steps,
                              max_restarts=5)
    ck.wait()
    print(f"\ncompleted {stats['completed']} steps with "
          f"{stats['restarts']} restarts; final capacity "
          f"{fleet['devices']} chips")


if __name__ == "__main__":
    main()
