"""Batched serving example: greedy decoding against a ring-buffered KV cache
with throughput stats, driven through the Session API.

    python examples/serve_batch.py --arch h2o-danube-1.8b
"""
import argparse
import time

import jax

from repro.api import Session
from repro.configs import get_config, list_archs
from repro.launch.serve import ServeStats
from repro.models import init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()        # CPU-scale weights
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    compiled = Session(cfg).default_plan(seq=args.prompt_len
                                         + args.new_tokens)
    bundle = compiled.serve()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)

    t0 = time.perf_counter()
    out = bundle.generate(params, prompt, n_new=args.new_tokens)
    wall = time.perf_counter() - t0
    stats = ServeStats(tokens_generated=args.batch * args.new_tokens,
                       steps=args.prompt_len + args.new_tokens, wall_s=wall)
    print(f"arch          : {cfg.name}")
    print(f"generated     : {out.shape} "
          f"({stats.tokens_generated} new tokens)")
    print(f"throughput    : {stats.tok_per_s:,.1f} tok/s "
          f"(CPU, reduced config)")
    print(f"sample row    : {out[0].tolist()}")


if __name__ == "__main__":
    main()
