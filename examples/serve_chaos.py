"""Chaos demo: the serving stack absorbing injected failures, end to end.

Drives one :class:`repro.serve.Server` through three incidents using the
deterministic fault-injection harness (``repro.testing.faults``,
``docs/robustness.md``) and prints what the failure-handling layer did
about each:

1. **Broken backend** — every pallas compile fails: the per-bucket
   retry policy runs, the circuit breaker opens, and every request is
   still answered *exactly* via the reference fallback (the reference
   interpreter is the bitwise oracle, so degraded mode loses speed, not
   precision).
2. **Overload** — open-loop arrivals at several times capacity against a
   bounded queue with ``overload="reject"``: excess load fails fast and
   typed, served latency stays bounded.
3. **Worker crash** — the worker thread dies mid-batch: in-flight
   futures fail with :class:`~repro.serve.WorkerCrashed`, the supervisor
   restarts the worker, and the very next submit succeeds.

Faults can also be armed without touching code via the environment::

    CELLO_FAULTS='exec.compile@pallas=fail:x2' python examples/serve_chaos.py

    python examples/serve_chaos.py --n 64 --iters 2
"""
from __future__ import annotations

import argparse
import time

from repro.serve import (Overloaded, RetryPolicy, ServeConfig, Server,
                         WorkerCrashed,
                         request)
from repro.testing import faults


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=64, help="operator size "
                    "(perfect square: the cg_sparse grid needs one)")
    ap.add_argument("--iters", type=int, default=2,
                    help="unrolled CG iterations")
    ap.add_argument("--requests", type=int, default=8,
                    help="requests per incident")
    args = ap.parse_args()

    srv = Server(config=ServeConfig(
        max_batch_size=4, max_wait_us=500.0,
        max_queue=8, overload="reject",
        retry=RetryPolicy(max_retries=1, backoff_s=0.001),
        fallback="reference", breaker_failures=2))

    # -- incident 1: the pallas backend cannot compile ------------------
    print("# incident 1: pallas compile fails -> reference fallback")
    with faults.inject("exec.compile@pallas", kind="fail"):
        for seed in range(args.requests):
            res = srv.solve(request("cg", n=args.n, iters=args.iters,
                                    seed=seed, backend="pallas"))
            assert res.degraded and res.backend == "reference"
    st = srv.stats()
    lb = next(k for k in st["buckets"] if "/pallas" in k)
    print(f"  served={args.requests} degraded, fallbacks="
          f"{st['fallbacks']}, retries={st['retries']}, "
          f"breaker[{lb}]={st['buckets'][lb]['breaker']}")
    print(f"  health: {srv.health()['status']}")

    # -- incident 2: sustained overload against a bounded queue --------
    print("# incident 2: overload with a bounded queue (reject)")
    srv.solve(request("cg", n=args.n, iters=args.iters))    # warm plan
    futs, rejected = [], 0
    with faults.inject("serve.dispatch", kind="slow", delay_s=0.02):
        for seed in range(6 * args.requests):
            try:
                futs.append(srv.submit(
                    request("cg", n=args.n, iters=args.iters,
                            seed=seed % 7),
                    deadline_s=5.0))
            except Overloaded:
                rejected += 1
            time.sleep(0.001)
        served = [f.result(timeout=60) for f in futs]
    assert rejected > 0 and served
    print(f"  offered={6 * args.requests} served={len(served)} "
          f"rejected fast+typed={rejected} "
          f"queue_depth={srv.stats()['queue_depth']}")

    # -- incident 3: the worker thread crashes mid-batch ----------------
    print("# incident 3: worker crash -> supervised restart")
    with faults.inject("serve.worker", kind="fail", times=1):
        fut = srv.submit(request("cg", n=args.n, iters=args.iters,
                                 seed=99))
        try:
            fut.result(timeout=60)
            raise AssertionError("expected WorkerCrashed")
        except WorkerCrashed as e:
            print(f"  in-flight future failed typed: {type(e).__name__}")
    res = srv.solve(request("cg", n=args.n, iters=args.iters, seed=100))
    h = srv.health()
    print(f"  next solve served (batch={res.batch_size}), health="
          f"{h['status']}, worker_restarts={h['worker_restarts']}")

    srv.close()
    print("chaos absorbed: fallback exact, overload typed, crash "
          "supervised")


if __name__ == "__main__":
    main()
