"""Trace one instrumented CG pipeline run end to end with ``repro.obs``.

Runs the staged pipeline explicitly — ``trace → analyze → codesign →
lower → run`` — with span tracing enabled, so the exported trace carries
all four ``session.*`` stage spans, the nested ``codesign.search`` span
with its per-pass children, and the ``exec.compile`` / ``exec.dispatch``
spans. Writes a Chrome ``trace_event`` file you can load directly in
https://ui.perfetto.dev (or render with ``scripts/obs_report.py``), then
prints the span timeline and the metrics-registry table.

    python examples/observe_cg.py --n 256 --iters 8 --backend pallas \
        --trace /tmp/cello.trace.json

Equivalently, any entry point can be traced without code changes via the
environment: ``CELLO_OBS=chrome:/tmp/cello.trace.json python ...``
(see docs/observability.md).
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import tempfile

from repro import obs
from repro.api import Session


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=256, help="operator size")
    ap.add_argument("--iters", type=int, default=8, help="CG iterations")
    ap.add_argument("--backend", default="reference",
                    help="execution backend (reference | pallas)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="Chrome trace output (default: a temp file)")
    ap.add_argument("--jsonl", default=None, metavar="PATH",
                    help="also write the JSONL span export to PATH")
    args = ap.parse_args()
    trace_path = args.trace or str(pathlib.Path(tempfile.gettempdir())
                                   / "cello.trace.json")

    obs.enable(chrome=trace_path, jsonl=args.jsonl)

    # the four stages explicitly (Session.compile() would skip analyze),
    # so the exported trace shows the full pipeline shape
    sess = Session()
    traced = sess.trace(workload="cg", n=args.n, iters=args.iters)
    analyzed = traced.analyze()
    designed = analyzed.codesign()
    plan = designed.lower(backend=args.backend)
    with obs.span("example.run", backend=args.backend):
        out = plan.run()

    counts = obs.flush()
    print(f"residual leaves: {sorted(out)}")
    print(f"wrote {counts[trace_path]} spans -> {trace_path} "
          "(load in https://ui.perfetto.dev)\n")

    # render the artifacts with the bundled CLI (same output as
    # `python scripts/obs_report.py FILE`)
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "scripts"))
    import obs_report
    print("# span timeline")
    print("\n".join(obs_report.render_chrome(trace_path)))

    snap_path = pathlib.Path(tempfile.gettempdir()) / "cello.metrics.json"
    import json
    snap_path.write_text(json.dumps(obs.snapshot()))
    print("\n# metrics registry")
    print("\n".join(obs_report.render_metrics(str(snap_path))))

    names = {rec["name"] for rec in obs.tracer().spans()}
    for stage in ("trace", "analyze", "codesign", "lower"):
        assert f"session.{stage}" in names, f"missing session.{stage}"
    print("\nall four pipeline stage spans recorded: verified")


if __name__ == "__main__":
    main()
