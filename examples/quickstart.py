"""Quickstart: run the CELLO schedule × hybrid-buffer co-design on one
transformer block and lower the result to an execution plan.

    PYTHONPATH=src python examples/quickstart.py [--arch granite-3-8b]
"""
import argparse

from repro.configs import get_config, list_archs
from repro.core import co_design, layer_graph, plan_from_codesign
from repro.core.buffer import MiB


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=8192)
    ap.add_argument("--capacity-mib", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    g = layer_graph(cfg, args.batch, args.seq)
    print(f"analysis graph: {g}")

    res = co_design(g, capacity_bytes=args.capacity_mib * MiB)
    best = res.best
    print(f"\n== CELLO co-design result ({args.arch}, "
          f"b{args.batch} s{args.seq}, {args.capacity_mib} MiB) ==")
    print(f"explicit/implicit split : {best.schedule.config.explicit_frac:.3f}")
    print(f"fusion groups           : "
          f"{[grp for grp in best.schedule.groups if len(grp) > 1]}")
    print(f"explicit pins           : {sorted(best.schedule.pins)}")
    print(f"HBM traffic             : {best.metrics.hbm_bytes / 1e6:,.1f} MB")
    print(f"arithmetic intensity    : {best.metrics.ai:,.1f} FLOP/B")
    for name, ev in res.baselines.items():
        print(f"  vs {name:13s}: speedup "
              f"{ev.metrics.time_s / best.metrics.time_s:5.2f}x   energy "
              f"{ev.metrics.energy_j / best.metrics.energy_j:5.2f}x   HBM "
              f"{ev.metrics.hbm_bytes / max(1, best.metrics.hbm_bytes):6.1f}x")

    plan = plan_from_codesign(cfg, res, seq=args.seq)
    print("\n== lowered execution plan ==")
    print(f"flash attention kernel : {plan.use_flash_attention} "
          f"(q_block={plan.q_block}, kv_block={plan.kv_block})")
    print(f"fused MLP kernel       : {plan.use_fused_mlp} "
          f"(m={plan.mlp_block_m}, f={plan.mlp_block_f})")
    print(f"remat save-set         : {plan.remat_save_names}")
    print(f"notes                  : {plan.notes}")


if __name__ == "__main__":
    main()
