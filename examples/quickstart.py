"""Quickstart: run the CELLO schedule × hybrid-buffer co-design through the
staged Session API and lower the result to an execution plan.

    python examples/quickstart.py [--arch granite-3-8b] [--phase train]

(Install with `pip install -e .` first — or prefix with PYTHONPATH=src.)
"""
import argparse

from repro.api import CodesignConfig, Session
from repro.configs import list_archs
from repro.core.buffer import MiB


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list_archs())
    ap.add_argument("--phase", default="train",
                    choices=("train", "prefill", "decode"))
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=8192,
                    help="sequence length (train/prefill) or KV length "
                         "(decode)")
    ap.add_argument("--capacity-mib", type=int, default=128)
    ap.add_argument("--strategy", default="default",
                    choices=("default", "exhaustive", "greedy", "alap"))
    ap.add_argument("--no-cache", action="store_true",
                    help="force a fresh search (skip the disk cache)")
    args = ap.parse_args()

    sess = Session(args.arch, capacity_bytes=args.capacity_mib * MiB,
                   use_cache=not args.no_cache)
    shape = (dict(batch=args.batch, kv_len=args.seq)
             if args.phase == "decode"
             else dict(batch=args.batch, seq=args.seq))

    # stage 1+2: trace the op DAG, analyse its reuse structure
    traced = sess.trace(phase=args.phase, **shape)
    analyzed = traced.analyze()
    print(traced)
    print(analyzed)
    top = analyzed.pin_candidates()[:3]
    if top:
        print("top pin candidates   :",
              ", ".join(f"{t.name} (saves {t.pin_value():.1f} B/B)"
                        for t in top))

    # stage 3: the joint schedule × buffer-split search
    designed = analyzed.codesign(CodesignConfig(strategy=args.strategy))
    print(f"\n{designed}")
    best = designed.best.metrics
    for name, ev in designed.baselines.items():
        print(f"  vs {name:13s}: speedup "
              f"{ev.metrics.time_s / best.time_s:5.2f}x   energy "
              f"{ev.metrics.energy_j / best.energy_j:5.2f}x   HBM "
              f"{ev.metrics.hbm_bytes / max(1, best.hbm_bytes):6.1f}x")

    # stage 4: lower onto kernels + remat policy
    plan = designed.lower()
    print("\n" + plan.explain())


if __name__ == "__main__":
    main()
