"""Serve a batch of CG solves through ``repro.serve``, end to end.

Spins up a :class:`repro.serve.Server`, submits a burst of mixed-bucket
requests (dense ``cg`` + CSR-sparse ``cg_sparse``, each with its own
right-hand side), and shows the serving pipeline at work: the router
canonicalizes requests into bucket keys, a bounded LRU keeps one vmapped
:class:`~repro.serve.BatchedPlan` resident per bucket, and the worker
coalesces same-bucket requests so each batch is answered in **one device
dispatch** — which ``stats()`` then proves.

    python examples/serve_cg.py --n 256 --requests 32 --max-batch 16
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.serve import ServeConfig, Server, request


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=256, help="operator size")
    ap.add_argument("--iters", type=int, default=4,
                    help="unrolled CG iterations")
    ap.add_argument("--requests", type=int, default=32,
                    help="requests per workload")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="coalesce up to this many same-bucket requests")
    ap.add_argument("--max-wait-us", type=float, default=2000.0,
                    help="close a batch after its head waited this long")
    ap.add_argument("--backend", default="reference",
                    help="execution backend (reference | pallas)")
    args = ap.parse_args()

    # autostart=False + submit-all + start(): every request is queued
    # before the first batch closes, so coalescing is deterministic —
    # ceil(requests / max_batch) batches per bucket
    srv = Server(config=ServeConfig(max_batch_size=args.max_batch,
                                    max_wait_us=args.max_wait_us,
                                    autostart=False))
    futs = []
    for seed in range(args.requests):
        futs.append(srv.submit(request(
            "cg", n=args.n, iters=args.iters, seed=seed,
            backend=args.backend)))
        futs.append(srv.submit(request(
            "cg_sparse", n=args.n, iters=args.iters, seed=seed,
            backend=args.backend)))
    # an explicit right-hand side rides along as a feeds overlay (input
    # leaves only — the operator is the bucket's shared one)
    futs.append(srv.submit(request(
        "cg", n=args.n, iters=args.iters, backend=args.backend,
        feeds={"b": np.ones(args.n, np.float32)})))

    srv.start()
    results = [f.result() for f in futs]
    srv.close()

    for res in results[:3] + results[-1:]:
        print(f"{res.bucket:60s} batch={res.batch_size:2d} "
              f"latency={res.latency_s * 1e3:7.2f}ms "
              f"residual={res.residual:.3g}")
    print(f"... {len(results)} results total\n")

    st = srv.stats()
    print(f"requests={st['requests']} batches={st['batches']} "
          f"plans_cached={st['plans_cached']}")
    for label, b in st["buckets"].items():
        print(f"  {label}")
        print(f"    requests={b['requests']} batches={b['batches']} "
              f"sizes={b['batch_sizes']} cache={b['cache_hits']}h/"
              f"{b['cache_misses']}m")
        # the serving guarantee: every coalesced batch was ONE dispatch
        assert b["dispatches"] == b["batches"], (b["dispatches"],
                                                 b["batches"])
    print("one dispatch per coalesced batch: verified")


if __name__ == "__main__":
    main()
