"""Sparse-operand tests: CSR frontend, density-aware co-design, kernels.

Covers the contract end-to-end:

* **CSR lowering goldens** — the sub-leaf triple's shapes/dtypes/bytes are
  nnz-based, spmv carries ``2·nnz`` FLOPs, and kernel selection lowers
  spmv groups to ``spmv-stream`` passes with the whole operand resident.
* **Generators** — exact nnz counts, valid CSR structure, and the
  promised numerics (laplacian5/banded SPD, random/skewed diagonally
  dominant), all against the scipy-free :func:`csr_to_dense` densifier.
* **Sparse CG** — the residual identity ``r_k = b − A x_k`` against the
  dense reconstruction, plus SPD convergence on the Laplacian.
* **Parity** — reference replays bitwise; pallas matches within the
  documented tolerances, at fp64 under ``jax_enable_x64`` (the modeled
  precision) as well as default fp32.
* **Density-aware pins** — the CSR triple pins all-or-nothing exactly at
  the nnz-footprint capacity boundary, and a paper-shaped sparse solve
  shows the pin in ``plan.explain()``.
"""
import numpy as np
import pytest

from repro.api import Session
from repro.core import select_group_kernels
from repro.core.reuse import analyze
from repro.core.schedule import choose_pins, sparse_operand_groups
from repro.frontends import (Program, build_workload, csr_to_dense,
                             evaluate, make_feeds, pattern_nnz)
from repro.frontends.sparse import row_counts

# float32 reduction-reassociation tolerances (documented policy)
RTOL32, ATOL32 = 2e-4, 1e-5
# fp64: same reassociation, ~2^-29 smaller ulps
RTOL64, ATOL64 = 1e-9, 1e-12

#: every sparse workload in the registry, one row per pattern family
SPARSE_PARITY_SET = [
    ("cg_sparse", dict(n=64, iters=3)),                       # laplacian5
    ("cg_sparse", dict(n=50, iters=2, pattern="banded", bandwidth=3)),
    ("bicgstab_sparse", dict(n=64, iters=2)),
    ("bicgstab_sparse", dict(n=48, iters=2, pattern="random",
                             density=0.1)),
    ("jacobi_sparse", dict(n=64, sweeps=3)),
    ("jacobi_sparse", dict(n=40, sweeps=2, pattern="skewed",
                           density=0.15)),
]
_IDS = [f"{w}-{p.get('pattern', 'laplacian5')}"
        for w, p in SPARSE_PARITY_SET]


def _dense_A(feeds, n):
    return csr_to_dense(feeds["A.indptr"], feeds["A.indices"],
                        feeds["A.data"], (n, n))


def _lowered(tmp_path, workload, **params):
    traced = Session(cache_dir=tmp_path).trace(workload=workload, **params)
    return traced, traced.analyze().codesign().lower()


# ---------------------------------------------------------------------------
# CSR lowering goldens
# ---------------------------------------------------------------------------

class TestCsrLowering:
    def test_sub_leaf_shapes_and_nnz_annotations(self):
        p = Program("lower")
        A = p.sparse_operator("A", (16, 16))           # laplacian5, g=4
        x = p.input("x", (16,))
        y = p.spmv(A, x, name="y")
        p.output(y)
        nnz = 5 * 16 - 4 * 4
        assert A.nnz == nnz == pattern_nnz("laplacian5", 16)
        assert p.nodes["A.indptr"].shape == (17,)
        assert p.nodes["A.indices"].shape == (nnz,)
        assert p.nodes["A.data"].shape == (nnz,)
        assert y.node.flops == 2 * nnz                 # nnz-based FLOPs
        g = p.to_graph()
        # byte annotations are nnz-based: int32 indices, fp64 data
        assert g.tensors["A.indptr"].bytes == 17 * 4
        assert g.tensors["A.indices"].bytes == nnz * 4
        assert g.tensors["A.data"].bytes == nnz * 8
        assert g.ops["y"].spec == "spmv" and not g.ops["y"].irregular

    def test_spmv_group_selects_spmv_stream_kernel(self):
        p = Program("sel")
        A = p.sparse_operator("A", (64, 64))
        x = p.input("x", (64,))
        y = p.spmv(A, x, name="y")
        p.output(p.dot(y, y, name="yy"))
        g = p.to_graph()
        (gk,) = select_group_kernels(g, [["y", "yy"]], 16 << 20)
        assert gk.kind == "spmv-stream"
        (sp,) = gk.passes
        # the whole operand (CSR triple + gathered x) is resident
        assert set(sp.resident) == {"A.indptr", "A.indices", "A.data", "x"}
        assert sp.reductions == ("yy",)
        assert "pallas-spmv" in gk.describe()

    def test_spmv_reading_in_pass_vector_splits_passes(self):
        p = Program("split")
        A = p.sparse_operator("A", (16, 16))
        x = p.input("x", (16,))
        y1 = p.spmv(A, x, name="y1")
        y2 = p.spmv(A, y1, name="y2")                  # y1 must materialize
        p.output(y2)
        (gk,) = select_group_kernels(p.to_graph(), [["y1", "y2"]], 16 << 20)
        assert gk.kind == "spmv-stream" and len(gk.passes) == 2

    def test_spmv_validation(self):
        p = Program("bad")
        A = p.sparse_operator("A", (16, 16))
        with pytest.raises(ValueError, match="square"):
            p.sparse_operator("B", (16, 8))
        with pytest.raises(TypeError, match="SparseOperand"):
            p.spmv(p.input("d", (16, 16)), p.input("x", (16,)))
        with pytest.raises(ValueError, match="shape"):
            p.spmv(A, p.input("x2", (8,)))
        with pytest.raises(ValueError, match="perfect square"):
            p.sparse_operator("C", (12, 12))           # laplacian5 needs g²
        with pytest.raises(ValueError, match="density"):
            p.sparse_operator("D", (16, 16), pattern="random")
        with pytest.raises(ValueError, match="bandwidth"):
            p.sparse_operator("E", (16, 16), pattern="banded")
        with pytest.raises(ValueError, match="unknown sparse pattern"):
            p.sparse_operator("F", (16, 16), pattern="hypercube")


# ---------------------------------------------------------------------------
# deterministic generators
# ---------------------------------------------------------------------------

class TestGenerators:
    @pytest.mark.parametrize("pattern,kw,n", [
        ("laplacian5", {}, 64),
        ("banded", {"bandwidth": 3}, 50),
        ("random", {"density": 0.1}, 48),
        ("skewed", {"density": 0.1}, 48),
    ])
    def test_csr_structure_and_nnz(self, pattern, kw, n):
        p = Program(f"gen_{pattern}")
        A = p.sparse_operator("A", (n, n), pattern=pattern, **kw)
        p.output(p.spmv(A, p.input("x", (n,))))
        feeds = make_feeds(p, seed=4)
        ip, ix, dv = (feeds["A.indptr"], feeds["A.indices"],
                      feeds["A.data"])
        nnz = pattern_nnz(pattern, n, **kw)
        assert nnz == int(row_counts(pattern, n, **kw).sum())
        assert ip.dtype == np.int32 and ix.dtype == np.int32
        assert ip.shape == (n + 1,) and ip[0] == 0 and ip[-1] == nnz
        assert np.all(np.diff(ip) >= 1)                # diagonal present
        assert ix.shape == dv.shape == (nnz,)
        assert ix.min() >= 0 and ix.max() < n
        # columns sorted & unique within every row
        for r in range(n):
            cols = ix[ip[r]:ip[r + 1]]
            assert np.all(np.diff(cols) > 0)
            assert r in cols                           # diagonal entry

    @pytest.mark.parametrize("pattern,kw", [
        ("laplacian5", {}), ("banded", {"bandwidth": 4})])
    def test_symmetric_patterns_are_spd(self, pattern, kw):
        n = 49 if pattern == "laplacian5" else 40
        p = Program(f"spd_{pattern}")
        A = p.sparse_operator("A", (n, n), pattern=pattern, **kw)
        p.output(p.spmv(A, p.input("x", (n,))))
        feeds = make_feeds(p, seed=0, dtype=np.float64)
        D = _dense_A(feeds, n)
        np.testing.assert_allclose(D, D.T)
        assert np.linalg.eigvalsh(D).min() > 0

    @pytest.mark.parametrize("pattern", ["random", "skewed"])
    def test_dominant_diagonal(self, pattern):
        n = 32
        p = Program(f"dom_{pattern}")
        A = p.sparse_operator("A", (n, n), pattern=pattern, density=0.2)
        p.output(p.spmv(A, p.input("x", (n,))))
        D = _dense_A(make_feeds(p, seed=9, dtype=np.float64), n)
        off = np.abs(D - np.diag(np.diag(D))).sum(axis=1)
        assert np.all(np.diag(D) > off - 1e-9)

    def test_dinv_matches_diagonal(self):
        n = 36
        prog = build_workload("jacobi_sparse", n=n, sweeps=1)
        feeds = make_feeds(prog, seed=2, dtype=np.float64)
        D = _dense_A(feeds, n)
        np.testing.assert_allclose(feeds["A.dinv"], 1.0 / np.diag(D))

    def test_deterministic_and_seed_sensitive(self):
        prog = build_workload("cg_sparse", n=36, iters=1,
                              pattern="random", density=0.2)
        a = make_feeds(prog, seed=1)
        b = make_feeds(prog, seed=1)
        c = make_feeds(prog, seed=2)
        np.testing.assert_array_equal(a["A.data"], b["A.data"])
        assert not np.array_equal(a["A.data"], c["A.data"])
        # same pattern+value stream across dtypes (cast at the end)
        d = make_feeds(prog, seed=1, dtype=np.float64)
        np.testing.assert_array_equal(a["A.indices"], d["A.indices"])
        np.testing.assert_allclose(a["A.data"],
                                   d["A.data"].astype(np.float32))


# ---------------------------------------------------------------------------
# sparse CG numerics vs the scipy-free dense reference
# ---------------------------------------------------------------------------

class TestSparseCG:
    def test_residual_identity_and_convergence(self):
        import jax
        prog = build_workload("cg_sparse", n=64, iters=4)
        feeds = make_feeds(prog, seed=1, dtype=np.float64)
        with jax.experimental.enable_x64():
            vals = evaluate(prog, feeds, return_all=True)
        D = _dense_A(feeds, 64)
        x4, r4 = np.asarray(vals["x4"]), np.asarray(vals["r4"])
        np.testing.assert_allclose(r4, feeds["b"] - D @ x4, atol=1e-8)
        norms = [float(np.linalg.norm(np.asarray(vals[f"r{k}"])))
                 for k in range(5)]
        assert norms[-1] < 0.2 * norms[0]       # SPD Laplacian: converges

    def test_spmv_matches_dense_matvec(self):
        import jax
        for pattern, kw in [("laplacian5", {}),
                            ("banded", {"bandwidth": 5}),
                            ("random", {"density": 0.15})]:
            n = 49
            p = Program(f"mv_{pattern}")
            A = p.sparse_operator("A", (n, n), pattern=pattern, **kw)
            x = p.input("x", (n,))
            p.output(p.spmv(A, x, name="y"))
            feeds = make_feeds(p, seed=5, dtype=np.float64)
            with jax.experimental.enable_x64():
                out = evaluate(p, feeds)
            np.testing.assert_allclose(
                np.asarray(out["y"]), _dense_A(feeds, n) @ feeds["x"],
                rtol=1e-12, atol=1e-12, err_msg=pattern)


# ---------------------------------------------------------------------------
# reference <-> pallas parity for every sparse workload
# ---------------------------------------------------------------------------

class TestSparseParity:
    @pytest.mark.parametrize("workload,params", SPARSE_PARITY_SET,
                             ids=_IDS)
    def test_parity_fp32(self, workload, params, tmp_path):
        traced, plan = _lowered(tmp_path, workload, **params)
        feeds = make_feeds(traced.program, seed=7)
        want = evaluate(traced.program, feeds)
        ref = plan.run(feeds, backend="reference")
        for k in want:                    # same pure ops => bitwise
            np.testing.assert_array_equal(np.asarray(ref[k]),
                                          np.asarray(want[k]), err_msg=k)
        pal = plan.run(feeds, backend="pallas")
        for k in want:
            np.testing.assert_allclose(np.asarray(pal[k]),
                                       np.asarray(want[k]),
                                       rtol=RTOL32, atol=ATOL32,
                                       err_msg=k)

    @pytest.mark.parametrize("workload,params", SPARSE_PARITY_SET,
                             ids=_IDS)
    def test_parity_fp64(self, workload, params, tmp_path):
        """The modeled precision: fp64 feeds under jax_enable_x64."""
        import jax
        traced, plan = _lowered(tmp_path, workload, **params)
        feeds = make_feeds(traced.program, seed=11, dtype=np.float64)
        with jax.experimental.enable_x64():
            want = evaluate(traced.program, feeds)
            pal = plan.run(feeds, backend="pallas")
        for k in want:
            assert np.asarray(pal[k]).dtype == np.float64, k
            np.testing.assert_allclose(np.asarray(pal[k]),
                                       np.asarray(want[k]),
                                       rtol=RTOL64, atol=ATOL64,
                                       err_msg=k)

    def test_sparse_cg_rolls_iterations(self, tmp_path):
        traced, plan = _lowered(tmp_path, "cg_sparse", n=64, iters=4)
        assert plan.exec_plan.roll is not None
        assert plan.exec_plan.roll.n_iters >= 2


# ---------------------------------------------------------------------------
# density-aware pinning
# ---------------------------------------------------------------------------

def _two_spmv_graph(n=64, bandwidth=2):
    """A is read twice (reuse!); the only pin candidates are its triple."""
    p = Program("pin_boundary")
    A = p.sparse_operator("A", (n, n), pattern="banded",
                          bandwidth=bandwidth)
    x = p.input("x", (n,))
    y1 = p.spmv(A, x, name="y1")
    p.output(p.spmv(A, y1, name="y2"))
    g = p.to_graph()
    csr_bytes = sum(g.tensors[t].bytes
                    for t in ("A.indptr", "A.indices", "A.data"))
    return g, csr_bytes


class TestDensityAwarePins:
    def test_nnz_footprint_boundary(self):
        g, csr_bytes = _two_spmv_graph()
        an = analyze(g)
        groups = [[o] for o in g.topo_order()]
        assert sparse_operand_groups(g) == [("A.indptr", "A.indices",
                                             "A.data")]
        # nnz footprint exactly fits -> the whole triple pins
        pins = choose_pins(g, groups, an, csr_bytes)
        assert {"A.indptr", "A.indices", "A.data"} <= set(pins)
        # one byte short -> nothing of the operand pins (no partial pin)
        pins = choose_pins(g, groups, an, csr_bytes - 1)
        assert not ({"A.indptr", "A.indices", "A.data"} & set(pins))

    def test_pin_is_all_or_nothing_even_when_members_fit(self):
        g, csr_bytes = _two_spmv_graph()
        # indptr+indices alone would fit this budget; the unit must not
        ip_ix = (g.tensors["A.indptr"].bytes
                 + g.tensors["A.indices"].bytes)
        pins = choose_pins(g, [[o] for o in g.topo_order()], analyze(g),
                           ip_ix)
        assert not ({"A.indptr", "A.indices", "A.data"} & set(pins))

    def test_session_plan_shows_density_aware_pin(self, tmp_path):
        """Acceptance: a sparse A whose nnz footprint fits capacity is
        pinned, visibly, where the dense A of the same n might not be."""
        traced, plan = _lowered(tmp_path, "cg_sparse", n=64, iters=3)
        pins = plan.codesigned.best.schedule.pins
        assert {"A.indptr", "A.indices", "A.data"} <= set(pins)
        text = plan.explain()
        assert "A.data[g" in text and "A.indices[g" in text
        assert "pinned-by-nnz-footprint=1" in text
        assert "pallas-spmv" in text

    def test_dense_vs_sparse_footprint_crossover(self, tmp_path):
        """At a capacity far below the dense n² silhouette the sparse
        operand still pins — the density-aware co-design's whole point."""
        n = 256    # dense A = 512 KiB fp64; CSR footprint ~15.6 KiB
        sess = Session(capacity_bytes=256 << 10, cache_dir=tmp_path)
        dense = sess.trace(workload="cg", n=n, iters=2)
        dplan = dense.analyze().codesign().lower()
        assert "A" not in dplan.codesigned.best.schedule.pins
        sparse = sess.trace(workload="cg_sparse", n=n, iters=2)
        splan = sparse.analyze().codesign().lower()
        spins = splan.codesigned.best.schedule.pins
        assert {"A.indptr", "A.indices", "A.data"} <= set(spins)


# ---------------------------------------------------------------------------
# overbooked pins: fractional residency
# ---------------------------------------------------------------------------

class TestOverbookedPins:
    def test_prefix_boundary(self):
        """The fractional boundary: at the overbook window edge an
        indptr-aligned row prefix pins; one byte below it the operand
        streams entirely."""
        g, csr_bytes = _two_spmv_graph()
        an = analyze(g)
        groups = [[o] for o in g.topo_order()]
        edge = -(-csr_bytes * 4 // 5)        # ceil(csr_bytes / 1.25)
        pins = choose_pins(g, groups, an, edge, overbook=0.25)
        assert {"A.indptr", "A.indices", "A.data"} <= set(pins)
        pp = pins.partial["A.data"]
        assert 0 < pp.rows < pp.total_rows
        assert pp.resident_bytes <= edge
        counts = row_counts("banded", 64, bandwidth=2)
        # prefix cut sits on an indptr row boundary, never mid-row
        assert pp.entries == int(counts[: pp.rows].sum())
        pins = choose_pins(g, groups, an, edge - 1, overbook=0.25)
        assert not ({"A.indptr", "A.indices", "A.data"} & set(pins))
        assert not pins.partial

    def test_full_fit_never_prefixes(self):
        g, csr_bytes = _two_spmv_graph()
        pins = choose_pins(g, [[o] for o in g.topo_order()], analyze(g),
                           csr_bytes, overbook=0.25)
        assert {"A.indptr", "A.indices", "A.data"} <= set(pins)
        assert not pins.partial

    def test_overbook_zero_reproduces_all_or_nothing(self):
        """``overbook=0`` must be bit-for-bit the pre-overbook rule."""
        g, csr_bytes = _two_spmv_graph()
        an = analyze(g)
        groups = [[o] for o in g.topo_order()]
        for budget in (csr_bytes, csr_bytes - 1,
                       -(-csr_bytes * 4 // 5)):
            base = choose_pins(g, groups, an, budget)
            zero = choose_pins(g, groups, an, budget, overbook=0.0)
            assert dict(zero) == dict(base)
            assert not zero.partial and not base.partial

    def test_session_prefix_pin_end_to_end(self, tmp_path):
        """A winning prefix pin reaches explain(), the lowered kernels,
        and the pallas backend — which stays parity-correct."""
        sess = Session(cache_dir=tmp_path)
        traced = sess.trace(workload="cg_sparse", n=64, iters=3,
                            pattern="banded", bandwidth=2)
        plan = traced.analyze().codesign(capacity_bytes=4500,
                                         overbook=0.25).lower()
        text = plan.explain()
        assert "pinned=prefix(rows=" in text
        assert "pin overbook" in text
        assert any("prefix(" in gk.describe()
                   for gk in plan.group_kernels)
        feeds = make_feeds(traced.program, seed=3)
        want = evaluate(traced.program, feeds)
        ref = plan.run(feeds, backend="reference")
        for k in want:                    # residency never touches numerics
            np.testing.assert_array_equal(np.asarray(ref[k]),
                                          np.asarray(want[k]), err_msg=k)
        pal = plan.run(feeds, backend="pallas")
        for k in want:
            np.testing.assert_allclose(np.asarray(pal[k]),
                                       np.asarray(want[k]),
                                       rtol=RTOL32, atol=ATOL32,
                                       err_msg=k)
