"""End-to-end integration: training convergence, checkpoint-restart
equivalence, serving, fault-tolerant driver, dry-run pipeline in-process."""
import json
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, latest_step, load_checkpoint
from repro.configs import get_config
from repro.core.policy import default_plan
from repro.data import DataConfig, SyntheticLMData
from repro.launch.serve import greedy_generate
from repro.launch.train import AdamWConfig, train_loop
from repro.models import init_params

from repro.runtime import StragglerDetector


def tiny_cfg():
    return get_config("granite-3-8b").reduced()


def data_iter(cfg, B=4, S=16, seed=0):
    return iter(SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=S,
                                           global_batch=B, seed=seed)))


@pytest.mark.slow
def test_training_reduces_loss():
    cfg = tiny_cfg()
    plan = default_plan(cfg, seq=16)
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                      weight_decay=0.01)
    out = train_loop(cfg, plan, opt, data_iter=data_iter(cfg),
                     n_steps=60, log_every=0)
    first = np.mean([h["loss"] for h in out["history"][:5]])
    last = np.mean([h["loss"] for h in out["history"][-5:]])
    # markov source: conditional entropy ~ log(4)=1.39 << log(128)=4.85
    assert last < first - 0.5, (first, last)


@pytest.mark.slow
def test_checkpoint_restart_matches_continuous(tmp_path):
    cfg = tiny_cfg()
    plan = default_plan(cfg, seq=16)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)

    # continuous run: 8 steps
    cont = train_loop(cfg, plan, opt, data_iter=data_iter(cfg),
                      n_steps=8, log_every=0, seed=3)

    # interrupted run: 4 steps + checkpoint + restore + 4 more
    ck = AsyncCheckpointer(str(tmp_path))
    part = train_loop(cfg, plan, opt, data_iter=data_iter(cfg),
                      n_steps=4, log_every=0, seed=3,
                      checkpointer=ck, checkpoint_every=4)
    step = latest_step(str(tmp_path))
    assert step == 4
    target = {"params": part["params"], "opt": part["opt_state"]}
    restored, _ = load_checkpoint(str(tmp_path), 4, target)
    ds = data_iter(cfg)                     # same stream as cont/part (seed 0)
    for _ in range(4):                      # data stream replays to step 4
        next(ds)
    resumed = train_loop(cfg, plan, opt, data_iter=ds, n_steps=8,
                         start_step=4, log_every=0,
                         params=restored["params"],
                         opt_state=restored["opt"])
    a = jax.tree.leaves(cont["params"])
    b = jax.tree.leaves(resumed["params"])
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   atol=2e-4, rtol=2e-3)


@pytest.mark.slow
def test_greedy_generate_shapes_and_determinism():
    cfg = tiny_cfg()
    plan = default_plan(cfg, seq=16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    out1 = greedy_generate(params, cfg, plan, prompt, n_new=6)
    out2 = greedy_generate(params, cfg, plan, prompt, n_new=6)
    assert out1.shape == (1, 10)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.max()) < cfg.padded_vocab


@pytest.mark.slow
def test_straggler_detection_in_loop():
    cfg = tiny_cfg()
    plan = default_plan(cfg, seq=16)
    sd = StragglerDetector(threshold=3.0)
    train_loop(cfg, plan, AdamWConfig(), data_iter=data_iter(cfg),
               n_steps=8, log_every=0, straggler=sd)
    assert sd.median_step_s is not None


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """Full dry-run pipeline on a small 8-device mesh in a subprocess
    (keeps this test process at 1 device)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, json
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
import numpy as np
from repro.configs import get_config
from repro.core.policy import default_plan
from repro.models import forward, set_mesh_context
from repro.launch import shardings as shd
from repro.launch.roofline import parse_collectives, roofline, model_flops
from repro.configs.base import SHAPES

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
cfg = get_config("granite-3-8b").reduced()
set_mesh_context(mesh)
plan = default_plan(cfg, seq=64)
params_sds, p_sh = shd.params_for_split(cfg, mesh)
tok = jax.ShapeDtypeStruct((4, 64), jnp.int32,
                           sharding=NamedSharding(mesh, P("data", None)))
def fwd(params, tokens):
    return forward(params, cfg, plan, tokens, mode="prefill", unroll=True)[0]
lowered = jax.jit(fwd, in_shardings=(p_sh, tok.sharding),
                  out_shardings=NamedSharding(mesh, P("data", None, "model"))
                  ).lower(params_sds, tok)
compiled = lowered.compile()
ma = compiled.memory_analysis()
ca = compiled.cost_analysis()
ca = ca[0] if isinstance(ca, list) else ca
coll = parse_collectives(compiled.as_text())
terms = roofline(ca.get("flops", 0.0), ca.get("bytes accessed", 0.0),
                 coll["total"], 8, model_flops(cfg, SHAPES["train_4k"]))
print(json.dumps({"ok": True, "flops": ca.get("flops", 0.0),
                  "coll_total": coll["total"],
                  "dominant": terms.dominant,
                  "temp": ma.temp_size_in_bytes}))
"""
    res = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    payload = json.loads(res.stdout.strip().splitlines()[-1])
    assert payload["ok"]
    assert payload["flops"] > 0
    assert payload["coll_total"] > 0        # TP matmuls must communicate


def test_parse_collectives_synthetic():
    from repro.launch.roofline import parse_collectives
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[64,64]{1,0} all-gather(%y), replica_groups=[8,2]<=[16], dimensions={0}
  %cp = f32[32]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    out = parse_collectives(hlo)
    ar = 128 * 256 * 4 * 2 * 3 / 4          # 2(N-1)/N × bytes
    ag = 64 * 64 * 2 * 1 / 2                # (N-1)/N × bytes, N=2
    cp = 32 * 4
    assert out["all-reduce"] == pytest.approx(ar)
    assert out["all-gather"] == pytest.approx(ag)
    assert out["collective-permute"] == pytest.approx(cp)
    assert out["total"] == pytest.approx(ar + ag + cp)
