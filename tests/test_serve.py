"""Serving-layer tests: batched parity, routing, coalescing, concurrency.

Batched parity policy (docs/serving.md):

* ``reference`` + gather/segment programs (``cg_sparse``): a vmapped batch
  matches a loop of jitted single-request solves (``BatchedPlan.run_one``)
  **bit-for-bit** at fp32 and fp64 — every vmap lane lowers to the same
  per-lane gather/segment arithmetic.
* ``reference`` + dense programs (``cg``): the batched matvec lowers to a
  batched contraction whose summation order may differ from the unbatched
  one in the last ulps — parity within SERVE_RTOL/SERVE_ATOL (fp32) and
  SERVE_RTOL64/SERVE_ATOL64 (fp64), orders of magnitude tighter than any
  algorithmic difference.
* ``pallas``: within the backend's documented reduction-reassociation
  tolerances (rtol=2e-4 / atol=1e-5 float32) against the reference oracle.
"""
import json
import pathlib
import threading
import time

import numpy as np
import pytest

from repro.api import Session
from repro.api.cache import CodesignCache
from repro.exec import Executor
from repro.frontends import make_feeds
from repro.serve import (BatchedPlan, Overloaded, PlanRouter, ServeConfig,
                         Server, ServerClosed, SolveRequest, density_bucket,
                         request)
from repro.testing import faults

# batched-vs-single reference tolerances (see module docstring)
SERVE_RTOL, SERVE_ATOL = 1e-4, 1e-5
SERVE_RTOL64, SERVE_ATOL64 = 1e-9, 1e-12
# pallas documented float32 policy (docs/execution_backends.md)
PALLAS_RTOL, PALLAS_ATOL = 2e-4, 1e-5


def _plan(tmp_path, workload, **params):
    traced = Session(cache_dir=tmp_path).trace(workload=workload, **params)
    return traced, traced.codesign().lower()


def _batch_feeds(bp, program, n, dtype=None):
    shared = make_feeds(program, seed=0, dtype=dtype,
                        only=bp.shared_leaves)
    per_req = [make_feeds(program, seed=s, dtype=dtype,
                          only=bp.batched_leaves) for s in range(n)]
    return shared, per_req


# ---------------------------------------------------------------------------
# BatchedPlan parity (satellite: dense CG + cg_sparse at fp32 and fp64)
# ---------------------------------------------------------------------------

class TestBatchedParity:
    def test_sparse_bitwise_fp32(self, tmp_path):
        traced, plan = _plan(tmp_path, "cg_sparse", n=64, iters=2)
        bp = plan.batched()
        shared, per_req = _batch_feeds(bp, traced.program, 4)
        outs = bp.run_many(per_req, shared)
        for r, out in zip(per_req, outs):
            one = bp.run_one({**shared, **r})
            for k in one:
                np.testing.assert_array_equal(np.asarray(one[k]),
                                              np.asarray(out[k]))

    def test_sparse_bitwise_fp64(self, tmp_path):
        import jax
        with jax.experimental.enable_x64():
            traced, plan = _plan(tmp_path, "cg_sparse", n=64, iters=2)
            bp = plan.batched()
            shared, per_req = _batch_feeds(bp, traced.program, 4,
                                           dtype=np.float64)
            outs = bp.run_many(per_req, shared)
            assert np.asarray(outs[0]["x2"]).dtype == np.float64
            for r, out in zip(per_req, outs):
                one = bp.run_one({**shared, **r})
                for k in one:
                    np.testing.assert_array_equal(np.asarray(one[k]),
                                                  np.asarray(out[k]))

    @pytest.mark.parametrize("fp64", [False, True], ids=["fp32", "fp64"])
    def test_dense_cg_close(self, tmp_path, fp64):
        import jax
        import contextlib
        ctx = (jax.experimental.enable_x64() if fp64
               else contextlib.nullcontext())
        dtype = np.float64 if fp64 else None
        rtol, atol = ((SERVE_RTOL64, SERVE_ATOL64) if fp64
                      else (SERVE_RTOL, SERVE_ATOL))
        with ctx:
            traced, plan = _plan(tmp_path, "cg", n=96, iters=2)
            bp = plan.batched()
            shared, per_req = _batch_feeds(bp, traced.program, 4,
                                           dtype=dtype)
            outs = bp.run_many(per_req, shared)
            for r, out in zip(per_req, outs):
                # vs the jitted single-request twin of one vmap lane...
                one = bp.run_one({**shared, **r})
                # ...and vs the eager per-request plan.run() loop
                eager = plan.run({**shared, **r})
                for k in one:
                    np.testing.assert_allclose(
                        np.asarray(out[k]), np.asarray(one[k]),
                        rtol=rtol, atol=atol)
                    np.testing.assert_allclose(
                        np.asarray(out[k]), np.asarray(eager[k]),
                        rtol=rtol, atol=atol)

    @pytest.mark.parametrize("workload,params",
                             [("cg", dict(n=96, iters=2)),
                              ("cg_sparse", dict(n=64, iters=2))],
                             ids=["cg", "cg_sparse"])
    def test_pallas_batched_within_tolerance(self, tmp_path, workload,
                                             params):
        traced = Session(cache_dir=tmp_path).trace(workload=workload,
                                                   **params)
        plan = traced.codesign().lower(backend="pallas")
        bp = plan.batched()
        assert bp.backend == "pallas"
        shared, per_req = _batch_feeds(bp, traced.program, 4)
        outs = bp.run_many(per_req, shared)
        ref = traced.codesign().lower(backend="reference")
        for r, out in zip(per_req, outs):
            want = ref.run({**shared, **r})
            for k in want:
                np.testing.assert_allclose(
                    np.asarray(out[k]), np.asarray(want[k]),
                    rtol=PALLAS_RTOL, atol=PALLAS_ATOL)


class TestBatchedPlanMechanics:
    def test_one_dispatch_per_batch_and_trace_reuse(self, tmp_path):
        traced, plan = _plan(tmp_path, "cg", n=64, iters=2)
        bp = plan.batched()
        shared, per_req = _batch_feeds(bp, traced.program, 8)
        bp.run_many(per_req, shared)
        assert bp.stats == {"traces": 1, "dispatches": 1}
        bp.run_many(per_req, shared)       # same batch size: no retrace
        assert bp.stats == {"traces": 1, "dispatches": 2}
        bp.run_many(per_req[:4], shared)   # new padded size: one retrace
        assert bp.stats == {"traces": 2, "dispatches": 3}

    def test_padding_matches_unpadded(self, tmp_path):
        traced, plan = _plan(tmp_path, "cg_sparse", n=64, iters=2)
        bp = plan.batched()
        shared, per_req = _batch_feeds(bp, traced.program, 5)
        padded = bp.run_many(per_req, shared)            # 5 -> 8 lanes
        assert len(padded) == 5
        unpadded = bp.run_many(per_req, shared, pad=False)
        for p, u in zip(padded, unpadded):
            for k in p:
                np.testing.assert_array_equal(p[k], u[k])

    def test_shape_validation(self, tmp_path):
        traced, plan = _plan(tmp_path, "cg", n=64, iters=2)
        bp = plan.batched()
        shared, per_req = _batch_feeds(bp, traced.program, 2)
        feeds = dict(shared)
        for n in bp.batched_leaves:
            feeds[n] = np.stack([r[n] for r in per_req])
        with pytest.raises(ValueError, match="unbatched"):
            bad = dict(feeds)
            bad["A"] = np.stack([shared["A"]] * 2)     # batched operator
            bp.run_batch(bad)
        with pytest.raises(ValueError, match="must be batched"):
            bad = dict(feeds)
            bad["b"] = per_req[0]["b"]                 # unbatched input
            bp.run_batch(bad)
        with pytest.raises(ValueError, match="inconsistent batch"):
            bad = dict(feeds)
            bad["x0"] = np.stack([per_req[0]["x0"]] * 3)
            bp.run_batch(bad)
        with pytest.raises(KeyError, match="missing leaf"):
            bad = dict(feeds)
            del bad["b"]
            bp.run_batch(bad)

    def test_batched_convenience_and_leaf_split(self, tmp_path):
        traced, plan = _plan(tmp_path, "cg_sparse", n=64, iters=2)
        bp = plan.batched()
        assert isinstance(bp, BatchedPlan)
        # CSR sub-leaves are operator (shared); b/x0 are inputs (batched)
        assert set(bp.batched_leaves) == {"b", "x0"}
        assert all(n.startswith("A.") for n in bp.shared_leaves)


# ---------------------------------------------------------------------------
# router: bucket keys, density decades, LRU
# ---------------------------------------------------------------------------

class TestRouter:
    def test_default_params_canonicalize(self, tmp_path):
        r = PlanRouter(session=Session(cache_dir=tmp_path))
        k1 = r.bucket(request("cg_sparse", n=64))
        k2 = r.bucket(request("cg_sparse", n=64, pattern="laplacian5",
                              iters=4))
        assert k1 == k2
        assert k1.density == "laplacian5"
        assert "laplacian5" in k1.label

    def test_density_decade_bucketing(self, tmp_path):
        r = PlanRouter(session=Session(cache_dir=tmp_path))
        ks = [r.bucket(request("cg_sparse", n=64, pattern="random",
                               density=d))
              for d in (0.0008, 0.001, 0.0012)]
        assert len(set(ks)) == 1
        assert dict(ks[0].params)["density"] == 0.001
        far = r.bucket(request("cg_sparse", n=64, pattern="random",
                               density=0.01))
        assert far != ks[0]

    def test_density_bucket_values(self):
        assert density_bucket(0.001) == 0.001
        assert density_bucket(0.0008) == 0.001
        assert density_bucket(0.5) == 1.0
        for bad in (0.0, -1.0, 1.5):
            with pytest.raises(ValueError):
                density_bucket(bad)

    def test_invalid_requests_raise(self, tmp_path):
        r = PlanRouter(session=Session(cache_dir=tmp_path))
        with pytest.raises(KeyError, match="unknown HPC workload"):
            r.bucket(request("nope"))
        with pytest.raises(TypeError):
            r.bucket(request("cg", n=64, bogus=1))
        with pytest.raises(ValueError, match="float dtype"):
            request("cg", n=64, dtype="int32")

    def test_lru_bounded_and_counted(self, tmp_path):
        r = PlanRouter(session=Session(cache_dir=tmp_path), max_plans=2)
        keys = [r.bucket(request("cg", n=n, iters=2)) for n in (32, 48, 64)]
        r.plan_for(keys[0])
        r.plan_for(keys[0])                      # hit
        r.plan_for(keys[1])
        r.plan_for(keys[2])                      # evicts keys[0]
        st = r.stats()
        assert st["plans_cached"] == 2
        assert st["evictions"] == 1
        assert st["buckets"][keys[0].label]["cache_hits"] == 1
        assert st["buckets"][keys[0].label]["cache_misses"] == 1
        r.plan_for(keys[0])                      # cold again: miss
        assert r.stats()["buckets"][keys[0].label]["cache_misses"] == 2

    def test_request_feeds_overlay(self, tmp_path):
        r = PlanRouter(session=Session(cache_dir=tmp_path))
        entry = r.plan_for(r.bucket(request("cg", n=64, iters=2)))
        b = np.ones(64, np.float64)
        feeds = r.request_feeds(entry, request("cg", n=64, iters=2,
                                               feeds={"b": b}))
        assert feeds["b"].dtype == np.float32        # cast to bucket dtype
        np.testing.assert_array_equal(feeds["b"], np.ones(64, np.float32))
        with pytest.raises(KeyError, match="shared operator"):
            r.request_feeds(entry, request(
                "cg", n=64, iters=2,
                feeds={"A": np.eye(64, dtype=np.float32)}))
        with pytest.raises(ValueError, match="expected shape"):
            r.request_feeds(entry, request("cg", n=64, iters=2,
                                           feeds={"b": np.ones(5)}))


# ---------------------------------------------------------------------------
# server: coalescing, one dispatch per batch, stats, errors
# ---------------------------------------------------------------------------

class TestServer:
    def test_smoke_32_mixed_buckets_one_dispatch_per_batch(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path),
                     max_batch_size=16, autostart=False)
        futs = []
        for s in range(16):
            futs.append(srv.submit(request("cg", n=64, iters=2, seed=s)))
            futs.append(srv.submit(request("cg_sparse", n=64, iters=2,
                                           seed=s)))
        srv.start()
        results = [f.result(timeout=300) for f in futs]
        srv.close()
        assert all(r.batch_size == 16 for r in results)
        assert all(r.residual is not None and np.isfinite(r.residual)
                   for r in results)
        st = srv.stats()
        assert st["requests"] == 32
        assert st["batches"] == 2
        assert st["queue_depth"] == 0
        assert st["plans_cached"] == 2
        assert len(st["buckets"]) == 2
        for b in st["buckets"].values():
            assert b["requests"] == 16
            assert b["batches"] == 1
            # the one-dispatch-per-coalesced-batch guarantee, via the
            # PR-4-style executable counters
            assert b["dispatches"] == b["batches"] == 1
            assert b["traces"] == 1
            assert b["batch_sizes"] == {16: 1}
            assert b["cache_misses"] == 1

    def test_max_batch_size_splits_bursts(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path),
                     max_batch_size=8, autostart=False)
        futs = [srv.submit(request("cg", n=64, iters=2, seed=s))
                for s in range(20)]
        srv.start()
        sizes = sorted(f.result(timeout=300).batch_size for f in futs)
        srv.close()
        assert sizes == [4] * 4 + [8] * 16
        (bucket,) = srv.stats()["buckets"].values()
        assert bucket["batches"] == 3
        assert bucket["dispatches"] == 3
        assert bucket["batch_sizes"] == {8: 2, 4: 1}

    def test_max_wait_coalesces_trickle(self, tmp_path):
        # 4 requests submitted while the worker is already waiting: far
        # below max_batch_size, so only the (generous) max-wait deadline
        # can close the batch — all 4 must ride in one dispatch
        srv = Server(session=Session(cache_dir=tmp_path),
                     max_batch_size=16, max_wait_us=500_000)
        futs = [srv.submit(request("cg", n=64, iters=2, seed=s))
                for s in range(4)]
        results = [f.result(timeout=300) for f in futs]
        srv.close()
        assert [r.batch_size for r in results] == [4, 4, 4, 4]
        (bucket,) = srv.stats()["buckets"].values()
        assert bucket["batches"] == 1

    def test_round_robin_no_starvation(self, tmp_path):
        """Two hot buckets + one cold bucket all make progress: under
        ``policy="round_robin"`` the cold bucket is served within the
        first scheduling cycle instead of waiting out both hot backlogs
        (which is what ``oldest`` does when the hot requests were queued
        first)."""
        srv = Server(session=Session(cache_dir=tmp_path),
                     max_batch_size=2, max_wait_us=0, autostart=False,
                     policy="round_robin")
        order, lock = [], threading.Lock()

        def tag(label):
            def cb(_f, label=label):
                with lock:
                    order.append(label)
            return cb

        futs = []
        for s in range(6):                             # hot bucket 1
            f = srv.submit(request("cg", n=64, iters=2, seed=s))
            f.add_done_callback(tag("h1"))
            futs.append(f)
        for s in range(6):                             # hot bucket 2
            f = srv.submit(request("cg", n=128, iters=2, seed=s))
            f.add_done_callback(tag("h2"))
            futs.append(f)
        cold = srv.submit(request("cg_sparse", n=64, iters=2, seed=0))
        cold.add_done_callback(tag("cold"))
        futs.append(cold)
        srv.start()
        results = [f.result(timeout=300) for f in futs]
        srv.close()
        assert all(np.isfinite(r.residual) for r in results)
        # one full cycle = one batch (2 requests) per hot bucket, then the
        # cold one; under "oldest" the cold request would complete last
        assert order.index("cold") <= 4, order
        assert {"h1", "h2", "cold"} <= set(order[:5]), order

    def test_policy_validation(self, tmp_path):
        with pytest.raises(ValueError, match="unknown policy"):
            Server(session=Session(cache_dir=tmp_path),
                   autostart=False, policy="fifo")

    def test_execution_error_propagates_to_futures(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path), autostart=False)
        fut = srv.submit(request("cg", n=64, iters=2,
                                 feeds={"b": np.ones(3)}))   # bad shape
        ok = srv.submit(request("cg", n=64, iters=2, seed=1))
        srv.start()
        # the bad feed poisons only its own batch
        with pytest.raises(ValueError, match="expected shape"):
            fut.result(timeout=300)
        with pytest.raises(ValueError):
            ok.result(timeout=300)     # same batch: shares the failure
        srv.close()
        after = Server(session=Session(cache_dir=tmp_path))
        res = after.solve(request("cg", n=64, iters=2, seed=1))
        after.close()
        assert np.isfinite(res.residual)

    def test_submit_side_validation_and_close(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path), autostart=False)
        with pytest.raises(KeyError):
            srv.submit(request("nope"))          # raises in the caller
        pending = srv.submit(request("cg", n=64, iters=2))
        srv.close(flush=False)                   # never started: dropped
        with pytest.raises(RuntimeError, match="closed"):
            pending.result(timeout=10)
        with pytest.raises(RuntimeError, match="closed"):
            srv.submit(request("cg", n=64, iters=2))

    def test_context_manager_solves(self, tmp_path):
        with Server(session=Session(cache_dir=tmp_path)) as srv:
            res = srv.solve(request("cg_sparse", n=64, iters=2, seed=3))
        assert res.batch_size == 1
        assert "cg_sparse" in res.bucket
        assert set(res.outputs) == {"x2", "r2"}
        assert res.residual == pytest.approx(
            float(np.linalg.norm(res.outputs["r2"])))


# ---------------------------------------------------------------------------
# concurrency: disk-cache writers, compile cache, trace memo
# ---------------------------------------------------------------------------

class TestConcurrency:
    def test_codesign_cache_racing_writers(self, tmp_path):
        sess = Session(cache_dir=tmp_path)
        res = sess.trace(workload="cg", n=32, iters=2).codesign().result
        cache = CodesignCache(tmp_path / "race")
        key = cache.key(probe="race")
        seen, errors = [], []

        def racer():
            try:
                for _ in range(20):
                    cache.put(key, res)
                    got = cache.get(key)
                    # readers see a complete entry or a miss — never torn
                    if got is not None:
                        seen.append(got.best.metrics.time_s)
            except Exception as e:              # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=racer) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert seen and set(seen) == {res.best.metrics.time_s}
        final = cache.get(key)
        assert final is not None
        assert final.best.schedule.order == res.best.schedule.order

    def test_executor_compiles_once_under_race(self, tmp_path):
        traced, plan = _plan(tmp_path, "cg", n=32, iters=2)
        feeds = make_feeds(traced.program, seed=0)
        compiles = []

        class Counting(Executor):
            name = "counting-test"

            def compile(self, p):
                compiles.append(threading.get_ident())
                time.sleep(0.05)        # widen the race window
                from repro.exec.reference import execute_plan
                return lambda f: execute_plan(p.trace.program, feeds=f)

        ex = Counting()
        barrier = threading.Barrier(6)

        def run():
            barrier.wait()
            ex.run(plan, feeds)

        threads = [threading.Thread(target=run) for _ in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(compiles) == 1

    def test_session_trace_memo_race(self, tmp_path):
        sess = Session(cache_dir=tmp_path)
        barrier = threading.Barrier(8)
        got = []

        def tracer():
            barrier.wait()
            got.append(sess.trace(workload="cg", n=48, iters=2))

        threads = [threading.Thread(target=tracer) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(got) == 8
        assert all(g is got[0] for g in got)     # one memoized build


# ---------------------------------------------------------------------------
# observability: stats() snapshot consistency under concurrent submitters
# ---------------------------------------------------------------------------

class TestServerObservability:
    def test_concurrent_submit_totals_reconcile(self, tmp_path):
        # many client threads race the worker; the documented invariant —
        # requests == queued + in_flight + errors + Σ size·count — must
        # hold for every stats() snapshot, including ones taken mid-flight
        srv = Server(session=Session(cache_dir=tmp_path),
                     max_batch_size=8, max_wait_us=2000.0)
        n_threads, per = 4, 10
        futs, flock = [], threading.Lock()

        def client(t):
            for i in range(per):
                f = srv.submit(request("cg", n=64, iters=2,
                                       seed=t * per + i))
                with flock:
                    futs.append(f)

        def reconciles(st):
            served = sum(size * cnt
                         for b in st["buckets"].values()
                         for size, cnt in b["batch_sizes"].items())
            return (st["requests"] == st["queue_depth"] + st["in_flight"]
                    + st["errors"] + served), served

        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(n_threads)]
        for th in threads:
            th.start()
        for _ in range(10):
            ok, _ = reconciles(srv.stats())
            assert ok
            time.sleep(0.002)
        for th in threads:
            th.join()
        results = [f.result(timeout=300) for f in futs]
        srv.close()
        st = srv.stats()
        total = n_threads * per
        assert st["requests"] == total
        assert st["errors"] == 0
        assert st["queue_depth"] == 0 and st["in_flight"] == 0
        ok, served = reconciles(st)
        assert ok and served == total
        (bucket,) = st["buckets"].values()
        assert st["batches"] == sum(bucket["batch_sizes"].values())
        assert len(results) == total
        assert all(np.isfinite(r.residual) for r in results)

    def test_errors_counted_in_reconciliation(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path), autostart=False)
        bad = srv.submit(request("cg", n=64, iters=2,
                                 feeds={"b": np.ones(3)}))   # bad shape
        srv.start()
        with pytest.raises(ValueError):
            bad.result(timeout=300)
        srv.close()
        st = srv.stats()
        assert st["requests"] == 1 and st["errors"] == 1
        served = sum(size * cnt for b in st["buckets"].values()
                     for size, cnt in b["batch_sizes"].items())
        assert served == 0
        assert st["requests"] == st["queue_depth"] + st["in_flight"] \
            + st["errors"] + served

    def test_latency_quantiles_match_streaming_histogram(self, tmp_path):
        # acceptance: stats() p50/p99 come from the streaming histogram
        # and must sit within the documented ±5% (HIST_REL_ERROR) of the
        # nearest-rank sample quantile of the latencies the clients saw
        from repro.obs import HIST_REL_ERROR
        srv = Server(session=Session(cache_dir=tmp_path),
                     max_batch_size=4)
        lat = [srv.solve(request("cg", n=64, iters=2, seed=s)).latency_s
               for s in range(12)]
        srv.close()
        (bucket,) = srv.stats()["buckets"].values()
        summ = bucket["latency"]
        assert summ["count"] == 12
        assert summ["sum"] == pytest.approx(sum(lat))
        assert summ["min"] == pytest.approx(min(lat))
        assert summ["max"] == pytest.approx(max(lat))
        for q, p in (("p50", 50), ("p99", 99)):
            exact = float(np.percentile(lat, p, method="inverted_cdf"))
            assert abs(summ[q] - exact) / exact <= HIST_REL_ERROR + 1e-9
        wait = bucket["queue_wait"]
        assert wait["count"] == 12 and wait["max"] <= summ["max"]


# ---------------------------------------------------------------------------
# bench_compare: per-metric direction in one invocation
# ---------------------------------------------------------------------------

def _bench_compare():
    import importlib.util
    path = pathlib.Path(__file__).resolve().parent.parent / "scripts" \
        / "bench_compare.py"
    spec = importlib.util.spec_from_file_location("bench_compare", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _dump(rps, p99):
    return {"TABLE 9": [{"name": "hpc/cg/batch16", "us_per_call": 1.0,
                         "backend": "reference",
                         "derived": {"requests_per_s": rps,
                                     "p99_ms": p99}}]}


class TestBenchCompareMultiMetric:
    def test_parse_metrics(self):
        bc = _bench_compare()
        assert bc.parse_metrics("us_per_call") == [("us_per_call", False)]
        assert bc.parse_metrics("x", True) == [("x", True)]
        assert bc.parse_metrics("requests_per_s:higher,p99_ms:lower") == \
            [("requests_per_s", True), ("p99_ms", False)]
        assert bc.parse_metrics("a:lower, b", True) == \
            [("a", False), ("b", True)]
        with pytest.raises(ValueError):
            bc.parse_metrics("a:sideways")
        with pytest.raises(ValueError):
            bc.parse_metrics(" , ")

    def test_two_directions_gate_in_one_pass(self):
        bc = _bench_compare()
        base = _dump(rps=1000.0, p99=5.0)
        spec = dict(backend="", max_regress=0.25,
                    metric="requests_per_s:higher,p99_ms:lower")

        _, failures, gated = bc.compare(_dump(900.0, 5.5), base, **spec)
        assert gated == 2 and not failures          # both within bound

        _, failures, _ = bc.compare(_dump(500.0, 5.0), base, **spec)
        assert len(failures) == 1                   # throughput collapsed
        assert "requests_per_s" in failures[0]

        _, failures, _ = bc.compare(_dump(1000.0, 9.0), base, **spec)
        assert len(failures) == 1                   # latency blew up
        assert "p99_ms" in failures[0]

        _, failures, _ = bc.compare(_dump(500.0, 9.0), base, **spec)
        assert len(failures) == 2                   # both gates fire

    def test_failure_detail_carries_values_and_params(self):
        """A tripped gate names the operating point: raw baseline vs
        current values plus the row's capacity/density-class params."""
        bc = _bench_compare()
        base = _dump(1000.0, 5.0)
        new = _dump(500.0, 5.0)
        for d in (base, new):
            d["TABLE 9"][0]["derived"].update(
                {"density": 0.01, "capacity_kib": 1792, "overbook": 0.25})
        base["TABLE 9"][0]["derived"]["overbook"] = 0.0
        _, failures, _ = bc.compare(
            new, base, backend="", max_regress=0.25,
            metric="requests_per_s", higher_is_better=True)
        assert len(failures) == 1
        assert "baseline=1000" in failures[0]
        assert "current=500" in failures[0]
        assert "density=0.01" in failures[0]
        assert "capacity_kib=1792" in failures[0]
        assert "overbook=0.25 (baseline 0.0)" in failures[0]

    def test_single_metric_unchanged(self):
        bc = _bench_compare()
        base = _dump(1000.0, 5.0)
        lines, failures, gated = bc.compare(
            _dump(1000.0, 20.0), base, backend="", max_regress=0.25,
            metric="requests_per_s", higher_is_better=True)
        assert gated == 1 and not failures
        # single-metric labels keep the bare row name (no suffix)
        assert any("hpc/cg/batch16 " in ln and "[" not in ln.split()[1]
                   for ln in lines if "ok" in ln)

    def test_cli_round_trip(self, tmp_path, capsys):
        bc = _bench_compare()
        new = tmp_path / "new.json"
        baseline = tmp_path / "base.json"
        new.write_text(json.dumps(_dump(500.0, 9.0)))
        baseline.write_text(json.dumps(_dump(1000.0, 5.0)))
        rc = bc.main([str(new), "--baseline", str(baseline),
                      "--backend", "",
                      "--metric", "requests_per_s:higher,p99_ms:lower"])
        assert rc == 1
        out = capsys.readouterr().out
        assert "REGRESSION" in out
        rc = bc.main([str(new), "--baseline", str(baseline),
                      "--backend", "", "--metric", "p99_ms:sideways"])
        assert rc == 2


# ---------------------------------------------------------------------------
# shutdown races (satellite: close() vs in-flight / queued / poisoned work)
# ---------------------------------------------------------------------------

class TestShutdownRaces:
    @pytest.fixture(autouse=True)
    def _clean_rules(self):
        faults.clear()
        yield
        faults.clear()

    def test_close_flush_waits_for_in_flight_batch(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path), max_batch_size=2,
                     max_wait_us=200)
        srv.solve(request("cg", n=32, iters=2))       # warm the plan
        with faults.inject("serve.dispatch", kind="slow", delay_s=0.3,
                           times=1):
            fut = srv.submit(request("cg", n=32, iters=2, seed=1))
            time.sleep(0.05)                          # batch is in flight
            srv.close(flush=True)                     # racing the dispatch
        assert fut.result(timeout=1).batch_size == 1  # served, not dropped
        with pytest.raises(ServerClosed):
            srv.submit(request("cg", n=32, iters=2, seed=2))

    def test_close_noflush_fails_queued_futures_typed(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path), max_batch_size=4,
                     max_wait_us=200, autostart=False)
        futs = [srv.submit(request("cg", n=32, iters=2, seed=s))
                for s in range(3)]
        srv.close(flush=False)
        for f in futs:
            with pytest.raises(ServerClosed, match="closed"):
                f.result(timeout=1)
        st = srv.stats()
        assert st["errors"] == 3 and st["queue_depth"] == 0

    def test_poisoned_batch_does_not_poison_the_bucket(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path), max_batch_size=2,
                     max_wait_us=200, autostart=False)
        futs = [srv.submit(request("cg", n=32, iters=2, seed=s))
                for s in range(4)]                    # two batches of 2
        with faults.inject("serve.dispatch", kind="fail", times=1):
            srv.start()
            for f in futs[:2]:                        # poisoned batch only
                with pytest.raises(faults.InjectedFault):
                    f.result(timeout=60)
            for f in futs[2:]:                        # same bucket, served
                assert f.result(timeout=60).batch_size == 2
        st = srv.stats()
        assert st["errors"] == 2
        assert st["requests"] == 4
        srv.close()


# ---------------------------------------------------------------------------
# client cancel() races (PR 9 review): no settle site may raise
# InvalidStateError into the worker or an unrelated submitter
# ---------------------------------------------------------------------------

class TestClientCancelRaces:
    def test_cancelled_future_does_not_crash_the_batch(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path), max_batch_size=4,
                     max_wait_us=200, autostart=False)
        futs = [srv.submit(request("cg", n=32, iters=2, seed=s))
                for s in range(3)]
        assert futs[1].cancel()              # still queued: the cancel wins
        srv.start()
        # the other members of the batch are served normally — un-fixed,
        # set_result on the cancelled future raised InvalidStateError,
        # crashed the worker, and failed the whole batch WorkerCrashed
        assert futs[0].result(timeout=60).batch_size == 2
        assert futs[2].result(timeout=60).batch_size == 2
        assert futs[1].cancelled()
        h = srv.health()
        assert h["status"] == "ok" and h["worker_restarts"] == 0
        st = srv.stats()
        assert st["requests"] == 3
        assert st["errors"] == 1             # the cancelled request
        srv.close()

    def test_cancel_racing_shed_does_not_raise_in_submitter(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path), max_batch_size=8,
                     max_wait_us=50_000, autostart=False,
                     max_queue=1, overload="shed_oldest")
        f1 = srv.submit(request("cg", n=32, iters=2, seed=1))
        assert f1.cancel()
        # the queue is full, so this submit sheds the (already-cancelled)
        # head — un-fixed, set_exception raised InvalidStateError here,
        # in an unrelated submitter's thread
        f2 = srv.submit(request("cg", n=32, iters=2, seed=2))
        assert f1.cancelled()
        srv.start()
        assert f2.result(timeout=60).batch_size == 1
        srv.close()

    def test_shed_head_does_not_restart_the_wait_window(self, tmp_path):
        # the coalescing window is anchored at batch open: losing the head
        # mid-wait (shed here; an expiring deadline is the same path) must
        # not re-open the max_wait window from the new head's t_submit
        srv = Server(session=Session(cache_dir=tmp_path), max_batch_size=8,
                     max_wait_us=500_000, max_queue=1,
                     overload="shed_oldest")
        srv.solve(request("cg", n=32, iters=2))           # warm the plan
        t0 = time.monotonic()
        f1 = srv.submit(request("cg", n=32, iters=2, seed=1))
        time.sleep(0.25)               # worker is mid-wait on f1's batch
        f2 = srv.submit(request("cg", n=32, iters=2, seed=2))  # sheds f1
        with pytest.raises(Overloaded):
            f1.result(timeout=1)
        assert f2.result(timeout=60).batch_size == 1
        closed_after = time.monotonic() - t0
        # fixed: batch closes ~0.5s after open; un-fixed the window
        # restarts from f2.t_submit and closes at ~0.75s
        assert closed_after < 0.68, closed_after
        srv.close()


class TestTypedRequestsAndConfig:
    """0.10 surface: ServeConfig, SolveRequest.bucket/deadline_s, fp64."""

    def test_request_bucket_method_is_the_canonicalization(self, tmp_path):
        req = request("cg_sparse", n=64, iters=2, density=0.0011)
        router = PlanRouter(session=Session(cache_dir=tmp_path))
        assert req.bucket() == router.bucket(req)
        assert req.bucket().density == "d0.001"     # bucketed, not raw

    def test_deadline_rides_on_the_request(self, tmp_path):
        srv = Server(None, ServeConfig(max_batch_size=4, autostart=False),
                     session=Session(cache_dir=tmp_path))
        # an already-expired per-request deadline fails fast at submit
        with pytest.raises(ValueError, match="deadline_s"):
            srv.submit(request("cg", n=32, iters=2, deadline_s=-1.0))
        fut = srv.submit(request("cg", n=32, iters=2, deadline_s=60.0))
        srv.start()
        assert fut.result(timeout=120).batch_size == 1
        srv.close()

    def test_submit_dict_deprecated_but_works(self, tmp_path):
        import warnings
        srv = Server(config=ServeConfig(max_batch_size=4),
                     session=Session(cache_dir=tmp_path))
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            fut = srv.submit({"workload": "cg", "n": 32, "iters": 2})
            assert any(issubclass(x.category, DeprecationWarning)
                       for x in w)
        assert fut.result(timeout=120).batch_size == 1
        srv.close()

    def test_legacy_server_kwargs_warn_and_conflict_raises(self, tmp_path):
        import warnings
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            srv = Server(session=Session(cache_dir=tmp_path),
                         max_batch_size=4, autostart=False)
            assert any(issubclass(x.category, DeprecationWarning)
                       for x in w)
        assert srv.max_batch_size == 4
        srv.close()
        with pytest.raises(TypeError, match="not both"):
            Server(config=ServeConfig(), max_batch_size=4)

    def test_mixed_fp32_fp64_buckets_one_server(self, tmp_path):
        """float64 requests build and dispatch under thread-local x64:
        the outputs really are float64, fp32 buckets are untouched, and
        the two dtypes land in separate buckets of one server."""
        srv = Server(config=ServeConfig(max_batch_size=8),
                     session=Session(cache_dir=tmp_path))
        try:
            f32 = srv.submit(request("cg", n=64, iters=3, seed=1))
            f64 = srv.submit(request("cg", n=64, iters=3, seed=1,
                                     dtype="float64"))
            r32 = f32.result(timeout=300)
            r64 = f64.result(timeout=300)
            x32 = next(v for k, v in sorted(r32.outputs.items())
                       if k.startswith("x"))
            x64 = next(v for k, v in sorted(r64.outputs.items())
                       if k.startswith("x"))
            assert np.asarray(x32).dtype == np.float32
            assert np.asarray(x64).dtype == np.float64
            # same seed, same solver: fp64 refines fp32, not replaces it
            np.testing.assert_allclose(np.asarray(x32),
                                       np.asarray(x64, np.float32),
                                       rtol=1e-3, atol=1e-5)
            labels = set(srv.stats()["buckets"])
            assert any("float64" in lb for lb in labels)
            assert any("float32" in lb for lb in labels)
        finally:
            srv.close()
