"""Direct coverage for ``repro.runtime.fault_tolerance`` (seed-era code
that previously had none): heartbeat timeout edges, straggler
strike/reset/evict (including the fixed cold-start window), and
``run_with_restarts`` exhaustion semantics."""
import pytest

from repro.runtime.fault_tolerance import (ElasticScaler, HeartbeatMonitor,
                                           StragglerDetector,
                                           run_with_restarts)


class _Clock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


class TestHeartbeatMonitor:
    def test_exactly_at_timeout_is_alive(self):
        clk = _Clock()
        mon = HeartbeatMonitor([0, 1], timeout_s=10.0, clock=clk)
        clk.t = 10.0                      # now - last == timeout: not dead
        assert mon.dead_hosts() == []
        clk.t = 10.0 + 1e-9               # strictly past: dead
        assert mon.dead_hosts() == [0, 1]

    def test_beat_resets_only_that_host(self):
        clk = _Clock()
        mon = HeartbeatMonitor([0, 1, 2], timeout_s=5.0, clock=clk)
        clk.t = 4.0
        mon.beat(1)
        clk.t = 6.0
        assert mon.dead_hosts() == [0, 2]

    def test_remove_forgets_host(self):
        clk = _Clock()
        mon = HeartbeatMonitor([0, 1], timeout_s=1.0, clock=clk)
        clk.t = 2.0
        mon.remove(0)
        assert mon.dead_hosts() == [1]
        mon.remove(7)                     # unknown host: no-op


class TestStragglerDetector:
    def test_cold_start_flags_early_straggler(self):
        # regression: a 5-sample warm-up used to mask an obvious straggler
        # in the first handful of steps
        det = StragglerDetector(threshold=2.0)
        for _ in range(det.MIN_HISTORY):
            assert not det.record(1.0)    # building history: never judged
        assert det.record(10.0)           # 10x the median: flagged

    def test_normal_jitter_not_flagged(self):
        det = StragglerDetector(threshold=2.0)
        for d in (1.0, 1.1, 0.9, 1.05, 1.0, 1.1):
            assert not det.record(d)

    def test_strikes_accumulate_and_reset(self):
        det = StragglerDetector(threshold=2.0, patience=3)
        for _ in range(10):
            det.record(1.0, host=0)
        det.record(5.0, host=0)
        det.record(5.0, host=0)
        assert not det.should_evict(0)    # 2 strikes < patience
        det.record(1.0, host=0)           # normal step resets the count
        det.record(5.0, host=0)
        assert not det.should_evict(0)

    def test_evict_after_patience_strikes(self):
        det = StragglerDetector(threshold=2.0, patience=3)
        for _ in range(10):
            det.record(1.0, host=3)
        for _ in range(3):
            det.record(6.0, host=3)
        assert det.should_evict(3)
        assert not det.should_evict(4)    # other hosts unaffected

    def test_median_window(self):
        det = StragglerDetector(window=4)
        assert det.median_step_s is None
        for d in (1.0, 1.0, 1.0, 9.0, 9.0, 9.0, 9.0):
            det.record(d)
        assert det.median_step_s == 9.0   # old fast steps rolled out


class TestRunWithRestarts:
    def test_clean_run_counts(self):
        steps = []
        out = run_with_restarts(steps.append, lambda s: s, 5)
        assert out == {"completed": 5, "restarts": 0}
        assert steps == [0, 1, 2, 3, 4]

    def test_restores_and_resumes(self):
        failed = {2: True}
        log = []

        def step(s):
            log.append(s)
            if failed.pop(s, False):
                raise RuntimeError("step died")

        out = run_with_restarts(step, lambda s: s - 1, 4, max_restarts=2)
        assert out["restarts"] == 1
        assert log == [0, 1, 2, 1, 2, 3]  # resumed from restore_fn's step

    def test_exhaustion_reraises(self):
        def step(_s):
            raise RuntimeError("always dies")

        with pytest.raises(RuntimeError, match="always dies"):
            run_with_restarts(step, lambda s: s, 3, max_restarts=2)

    def test_unlisted_failure_type_propagates_immediately(self):
        calls = []

        def step(s):
            calls.append(s)
            raise ValueError("not a failure_types member")

        with pytest.raises(ValueError):
            run_with_restarts(step, lambda s: s, 3, max_restarts=5,
                              failure_types=(RuntimeError,))
        assert calls == [0]               # no restart consumed


class TestElasticScaler:
    def test_multi_pod_keeps_model_axis(self):
        plan = ElasticScaler(model_axis=16, pod_chips=256).plan(512, 7)
        assert plan.mesh_shape == (2, 16, 16)
        assert plan.n_devices == 512
        assert plan.restore_step == 7

    def test_sub_pod_shrinks_data_axis(self):
        plan = ElasticScaler(model_axis=16, pod_chips=256).plan(
            48, None, dropped_hosts=[3])
        assert plan.mesh_shape == (3, 16)
        assert plan.dropped_hosts == (3,)
