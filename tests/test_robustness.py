"""Chaos suite: the serving stack under injected faults.

Drives every failure-handling layer end-to-end with the deterministic
fault-injection harness (``repro.testing.faults``, docs/robustness.md)
and proves the ISSUE's acceptance criteria without process restarts:

(a) a bucket whose pallas compile always fails serves *correct* results
    via the reference fallback (the reference interpreter is the bitwise
    oracle, so fallback output is exact), with its breaker open and the
    transition visible in ``stats()``;
(b) at sustained overload with ``reject`` the server stays responsive
    (bounded queue depth, overloaded p99 within 10x the unloaded p99)
    and every rejected/expired request fails fast with a typed error —
    no future ever hangs;
(c) a worker crash mid-batch fails exactly the in-flight futures and
    subsequent submits succeed after a supervised restart.
"""
import time

import numpy as np
import pytest

from repro.api import Session
from repro.serve import (CircuitBreaker, DeadlineExceeded, Overloaded,
                         RetryPolicy, Server, ServerClosed, WorkerCrashed,
                         request)
from repro.testing import faults


@pytest.fixture(autouse=True)
def _clean_rules():
    faults.clear()
    yield
    faults.clear()


def _reconciles(st):
    served = sum(size * cnt for b in st["buckets"].values()
                 for size, cnt in b["batch_sizes"].items())
    return st["requests"] == (st["queue_depth"] + st["in_flight"]
                              + st["errors"] + served)


# ---------------------------------------------------------------------------
# resilience primitives
# ---------------------------------------------------------------------------

class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class TestCircuitBreaker:
    def test_opens_after_threshold_consecutive_failures(self):
        br = CircuitBreaker(failure_threshold=3, reset_timeout_s=10.0)
        br.record_failure()
        br.record_failure()
        br.record_success()               # consecutive count resets
        br.record_failure()
        br.record_failure()
        assert br.state == "closed" and br.allow()
        br.record_failure()
        assert br.state == "open" and not br.allow()

    def test_half_open_probe_success_closes(self):
        clk = _Clock()
        br = CircuitBreaker(2, reset_timeout_s=5.0, clock=clk)
        br.record_failure()
        br.record_failure()
        assert not br.allow()
        clk.t = 5.0                       # cooldown elapsed
        assert br.allow()                 # the single probe
        assert br.state == "half_open"
        assert not br.allow()             # no second probe while pending
        br.record_success()
        assert br.state == "closed" and br.allow()

    def test_half_open_probe_failure_reopens(self):
        clk = _Clock()
        br = CircuitBreaker(1, reset_timeout_s=1.0, clock=clk)
        br.record_failure()
        clk.t = 1.0
        assert br.allow()
        br.record_failure()
        assert br.state == "open" and not br.allow()
        clk.t = 1.5                       # cooldown restarts from reopen
        assert not br.allow()
        clk.t = 2.0
        assert br.allow()
        assert br.stats()["opens"] == 2

    def test_transition_counter(self):
        from repro import obs
        c = obs.registry().counter("serve.breaker.transitions")
        before = c.value(**{"name": "t.bucket", "from": "closed",
                            "to": "open", "scope": "t"})
        br = CircuitBreaker(1, name="t.bucket", scope="t")
        br.record_failure()
        assert c.value(**{"name": "t.bucket", "from": "closed",
                          "to": "open", "scope": "t"}) == before + 1


class TestRetryPolicy:
    def test_backoff_schedule(self):
        p = RetryPolicy(max_retries=4, backoff_s=0.1, multiplier=2.0,
                        max_backoff_s=0.3)
        assert [p.delay_s(k) for k in (1, 2, 3, 4)] == \
            [0.1, 0.2, 0.3, 0.3]          # capped

    def test_validation(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_retries=-1)
        with pytest.raises(ValueError):
            RetryPolicy(multiplier=0.5)


# ---------------------------------------------------------------------------
# (a) fallback chain: pallas compile always fails -> reference serves
# ---------------------------------------------------------------------------

class TestFallbackChain:
    def test_broken_pallas_bucket_serves_exact_reference_answers(
            self, tmp_path):
        seeds = list(range(4))

        def serve(backend, ctx):
            srv = Server(session=Session(cache_dir=tmp_path),
                         max_batch_size=4, max_wait_us=500,
                         autostart=False, breaker_failures=2,
                         retry=RetryPolicy(max_retries=1, backoff_s=0.001))
            with ctx:
                futs = [srv.submit(request("cg", n=32, iters=2, seed=s,
                                           backend=backend))
                        for s in seeds]
                srv.start()
                res = [f.result(timeout=120) for f in futs]
            st = srv.stats()
            srv.close()
            return res, st

        import contextlib
        # oracle: the same seeds served natively on the reference backend,
        # same batch composition (autostart=False -> one batch of 4)
        oracle, _ = serve("reference", contextlib.nullcontext())
        broken, st = serve(
            "pallas", faults.inject("exec.compile@pallas", kind="fail"))

        for o, b in zip(oracle, broken):
            assert b.degraded and b.backend == "reference"
            assert set(b.outputs) == set(o.outputs)
            for k in o.outputs:
                # the fallback runs the identical reference BatchedPlan:
                # bitwise equality, not a tolerance
                np.testing.assert_array_equal(np.asarray(b.outputs[k]),
                                              np.asarray(o.outputs[k]))

        lb = [k for k in st["buckets"] if "/pallas" in k][0]
        b = st["buckets"][lb]
        assert b["fallbacks"] == len(seeds)
        assert b["errors"] == 0           # every future got an answer
        assert b["retries"] >= 1          # the retry policy ran first
        assert _reconciles(st)

    def test_breaker_opens_and_is_visible_in_stats(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path), max_batch_size=2,
                     max_wait_us=200, breaker_failures=2,
                     breaker_reset_s=60.0)
        with faults.inject("exec.compile@pallas", kind="fail") as rule:
            # each solve is its own failed batch: 2 failures open the
            # breaker; later batches skip pallas entirely
            for s in range(4):
                res = srv.solve(request("cg", n=32, iters=2, seed=s,
                                        backend="pallas"))
                assert res.degraded
        st = srv.stats()
        lb = [k for k in st["buckets"] if "/pallas" in k][0]
        assert st["buckets"][lb]["breaker"] == "open"
        assert st["buckets"][lb]["breaker_opens"] == 1
        assert srv.health()["status"] == "degraded"
        assert srv.health()["breakers"][lb] == "open"
        srv.close()
        # with the breaker open the primary is not attempted: the compile
        # fault fired only for the pre-open batches (one try each, no
        # retry policy configured)
        assert rule.fired == 2

    def test_breaker_open_no_fallback_fails_typed(self, tmp_path):
        from repro.serve import CircuitOpen
        srv = Server(session=Session(cache_dir=tmp_path), max_batch_size=1,
                     max_wait_us=100, breaker_failures=1,
                     breaker_reset_s=60.0, fallback=None)
        with faults.inject("exec.compile@pallas", kind="fail"):
            with pytest.raises(faults.InjectedFault):
                srv.solve(request("cg", n=32, iters=2, backend="pallas"))
            with pytest.raises(CircuitOpen):
                srv.solve(request("cg", n=32, iters=2, seed=1,
                                  backend="pallas"))
        srv.close()

    def test_transient_failure_recovered_by_retry_not_fallback(
            self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path), max_batch_size=2,
                     max_wait_us=200,
                     retry=RetryPolicy(max_retries=2, backoff_s=0.001))
        with faults.inject("serve.dispatch", kind="fail", times=1):
            res = srv.solve(request("cg", n=32, iters=2))
        assert not res.degraded and res.backend == "reference"
        st = srv.stats()
        assert st["retries"] == 1 and st["fallbacks"] == 0
        assert st["errors"] == 0
        srv.close()


# ---------------------------------------------------------------------------
# (b) overload: bounded queue, fast typed failures, responsive p99
# ---------------------------------------------------------------------------

class TestOverload:
    def test_sustained_overload_with_reject_stays_responsive(self,
                                                             tmp_path):
        dispatch_s = 0.05
        srv = Server(session=Session(cache_dir=tmp_path), max_batch_size=4,
                     max_wait_us=500, max_queue=8, overload="reject")
        # warm the plan so compile time doesn't pollute latencies
        srv.solve(request("cg", n=32, iters=2))
        with faults.inject("serve.dispatch", kind="slow",
                           delay_s=dispatch_s):
            # unloaded: sequential closed-loop requests
            unloaded = []
            for s in range(6):
                t0 = time.monotonic()
                srv.solve(request("cg", n=32, iters=2, seed=s))
                unloaded.append(time.monotonic() - t0)
            unloaded_p99 = float(np.percentile(unloaded, 99))

            # overloaded: open-loop arrivals at ~4x capacity
            # (capacity ~ max_batch/dispatch_s = 80 rps -> 320 rps)
            period = dispatch_s / (4 * srv.max_batch_size)
            futs, rejected, depths = [], 0, []
            t_end = time.monotonic() + 0.6
            while time.monotonic() < t_end:
                try:
                    futs.append(srv.submit(
                        request("cg", n=32, iters=2,
                                seed=len(futs) % 17),
                        deadline_s=5.0))
                except Overloaded:
                    rejected += 1
                if len(futs) % 8 == 0:
                    depths.append(srv.stats()["queue_depth"])
                time.sleep(period)

            served, expired = [], 0
            for f in futs:
                try:
                    # generous wall timeout: the assertion is that no
                    # future hangs, not that service is fast here
                    f.result(timeout=30)
                    served.append(f)
                except DeadlineExceeded:
                    expired += 1
                # nothing else may come out of an overloaded server

        assert rejected > 0               # overload actually happened
        assert len(served) > 0            # and the server kept serving
        assert max(depths) <= srv.max_queue
        loaded_p99 = float(np.percentile(
            [f.result().latency_s for f in served], 99))
        assert loaded_p99 <= 10 * unloaded_p99, \
            f"p99 {loaded_p99:.3f}s vs unloaded {unloaded_p99:.3f}s"
        st = srv.stats()
        assert st["rejected"] == rejected
        assert st["deadline_missed"] == expired
        assert _reconciles(st)
        srv.close()

    def test_shed_oldest_fails_head_serves_tail(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path), max_batch_size=4,
                     max_wait_us=500, max_queue=2, overload="shed_oldest",
                     autostart=False)
        f1 = srv.submit(request("cg", n=32, iters=2, seed=1))
        f2 = srv.submit(request("cg", n=32, iters=2, seed=2))
        f3 = srv.submit(request("cg", n=32, iters=2, seed=3))
        with pytest.raises(Overloaded, match="shed"):
            f1.result(timeout=5)          # failed at submit time of f3
        srv.start()
        assert f2.result(timeout=60).batch_size == 2
        assert f3.result(timeout=60).batch_size == 2
        st = srv.stats()
        assert st["shed"] == 1 and _reconciles(st)
        srv.close()

    def test_block_policy_waits_for_space(self, tmp_path):
        import threading
        srv = Server(session=Session(cache_dir=tmp_path), max_batch_size=1,
                     max_wait_us=100, max_queue=1, overload="block",
                     autostart=False)
        f1 = srv.submit(request("cg", n=32, iters=2, seed=1))
        blocked = {}

        def submitter():
            blocked["fut"] = srv.submit(request("cg", n=32, iters=2,
                                                seed=2))

        t = threading.Thread(target=submitter)
        t.start()
        t.join(timeout=0.2)
        assert t.is_alive()               # genuinely blocked on admission
        srv.start()                       # worker drains -> space frees
        t.join(timeout=60)
        assert not t.is_alive()
        assert f1.result(timeout=60).batch_size == 1
        assert blocked["fut"].result(timeout=60).batch_size == 1
        srv.close()

    def test_block_policy_honours_deadline(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path), max_batch_size=1,
                     max_wait_us=100, max_queue=1, overload="block",
                     autostart=False)
        srv.submit(request("cg", n=32, iters=2, seed=1))
        t0 = time.monotonic()
        with pytest.raises(DeadlineExceeded, match="admission"):
            srv.submit(request("cg", n=32, iters=2, seed=2),
                       deadline_s=0.1)
        assert time.monotonic() - t0 < 5.0
        srv.close(flush=False)


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------

class TestDeadlines:
    def test_deadline_caps_coalescing_wait(self, tmp_path):
        # max_wait is 10s, but the lone request's 1s deadline closes the
        # batch early — it is dispatched, not expired
        srv = Server(session=Session(cache_dir=tmp_path),
                     max_batch_size=16, max_wait_us=10_000_000)
        t0 = time.monotonic()
        res = srv.submit(request("cg", n=32, iters=2),
                         deadline_s=1.0).result(timeout=30)
        assert res.batch_size == 1
        assert time.monotonic() - t0 < 5.0
        assert srv.stats()["deadline_missed"] == 0
        srv.close()

    def test_expiry_fails_only_the_affected_future(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path), max_batch_size=1,
                     max_wait_us=100)
        # warm both buckets so the slow phase is dispatch-dominated
        srv.solve(request("cg", n=32, iters=2))
        srv.solve(request("cg", n=48, iters=2))
        with faults.inject("serve.dispatch", kind="slow", delay_s=0.5,
                           times=1):
            f_busy = srv.submit(request("cg", n=32, iters=2, seed=1))
            time.sleep(0.05)              # worker is now mid-dispatch
            f_live = srv.submit(request("cg", n=48, iters=2, seed=2))
            f_dead = srv.submit(request("cg", n=48, iters=2, seed=3),
                                deadline_s=0.1)
            with pytest.raises(DeadlineExceeded):
                f_dead.result(timeout=30)
            assert f_busy.result(timeout=30).batch_size == 1
            assert f_live.result(timeout=30).batch_size == 1
        st = srv.stats()
        assert st["deadline_missed"] == 1
        assert st["errors"] == 1 and _reconciles(st)
        srv.close()

    def test_submit_validates_deadline(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path), autostart=False)
        with pytest.raises(ValueError, match="deadline_s"):
            srv.submit(request("cg", n=32, iters=2), deadline_s=0.0)
        srv.close()


# ---------------------------------------------------------------------------
# (c) worker supervision
# ---------------------------------------------------------------------------

class TestWorkerSupervision:
    def test_crash_fails_exactly_in_flight_then_recovers(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path), max_batch_size=4,
                     max_wait_us=500, autostart=False,
                     max_worker_restarts=2)
        # bucket A's batch will be in flight when the crash fires; bucket
        # B's requests are queued-but-not-in-flight and must survive
        doomed = [srv.submit(request("cg", n=32, iters=2, seed=s))
                  for s in range(4)]
        queued = [srv.submit(request("cg", n=48, iters=2, seed=s))
                  for s in range(2)]
        with faults.inject("serve.worker", kind="fail", times=1):
            srv.start()
            for f in doomed:
                with pytest.raises(WorkerCrashed):
                    f.result(timeout=60)
            for f in queued:
                assert f.result(timeout=60).batch_size == 2
        # the restarted worker keeps serving new submits
        res = srv.submit(request("cg", n=32, iters=2, seed=9)) \
                 .result(timeout=60)
        assert res.batch_size == 1
        h = srv.health()
        assert h["status"] == "degraded" and h["worker_restarts"] == 1
        st = srv.stats()
        assert st["errors"] == len(doomed)
        assert st["worker_restarts"] == 1
        assert _reconciles(st)
        srv.close()

    def test_restart_exhaustion_goes_down_and_fails_fast(self, tmp_path):
        srv = Server(session=Session(cache_dir=tmp_path), max_batch_size=1,
                     max_wait_us=100, max_worker_restarts=0,
                     autostart=False)
        f1 = srv.submit(request("cg", n=32, iters=2, seed=1))
        f2 = srv.submit(request("cg", n=32, iters=2, seed=2))
        with faults.inject("serve.worker", kind="fail"):
            srv.start()
            with pytest.raises(WorkerCrashed):
                f1.result(timeout=60)
            with pytest.raises(WorkerCrashed):   # queued: dropped un-served
                f2.result(timeout=60)
        assert srv.health()["status"] == "down"
        with pytest.raises(ServerClosed, match="down"):
            srv.submit(request("cg", n=32, iters=2, seed=3))
        st = srv.stats()
        assert st["errors"] == 2 and _reconciles(st)
        srv.close()


# ---------------------------------------------------------------------------
# codesign cache corruption (satellite bugfix)
# ---------------------------------------------------------------------------

class TestCacheCorruption:
    def _corrupt_count(self):
        from repro import obs
        return obs.registry().counter("codesign.cache.corrupt").value()

    def test_truncated_entry_is_deleted_and_re_derived(self, tmp_path):
        sess = Session(cache_dir=tmp_path)
        first = sess.trace(workload="cg", n=32, iters=2).codesign()
        assert not first.from_cache
        (entry,) = tmp_path.glob("*.json")
        entry.write_text(entry.read_text()[:40])      # truncate on disk

        before = self._corrupt_count()
        again = Session(cache_dir=tmp_path).trace(
            workload="cg", n=32, iters=2).codesign()
        assert not again.from_cache                   # re-derived, no raise
        assert self._corrupt_count() == before + 1
        assert again.best.schedule.groups == first.best.schedule.groups
        # the re-derived result was re-published over the deleted entry
        third = Session(cache_dir=tmp_path).trace(
            workload="cg", n=32, iters=2).codesign()
        assert third.from_cache

    def test_garbage_json_counts_corrupt_not_plain_miss(self, tmp_path):
        from repro.api.cache import CodesignCache
        cache = CodesignCache(tmp_path)
        (tmp_path / "deadbeef.json").write_text("{not json at all")
        before = self._corrupt_count()
        assert cache.get("deadbeef") is None
        assert self._corrupt_count() == before + 1
        assert not (tmp_path / "deadbeef.json").exists()
        # a genuinely absent key is a plain miss: no corrupt bump
        assert cache.get("0000") is None
        assert self._corrupt_count() == before + 1

    def test_injected_corruption_site(self, tmp_path):
        sess = Session(cache_dir=tmp_path)
        sess.trace(workload="cg", n=32, iters=2).codesign()
        before = self._corrupt_count()
        with faults.inject("codesign.cache", kind="corrupt", times=1):
            res = Session(cache_dir=tmp_path).trace(
                workload="cg", n=32, iters=2).codesign()
        assert not res.from_cache
        assert self._corrupt_count() == before + 1


# ---------------------------------------------------------------------------
# supervision internals (PR 9 review): crash accounting, the restart
# window in health(), and close() boundedness
# ---------------------------------------------------------------------------

class TestSupervisionInternals:
    def test_crash_after_accounting_does_not_double_count(self, tmp_path):
        from concurrent.futures import Future

        from repro.serve.server import _InFlightBatch, _Item
        srv = Server(session=Session(cache_dir=tmp_path), autostart=False)
        req = request("cg", n=32, iters=2)
        key = srv.router.bucket(req)
        fut = Future()
        # simulate a crash landing AFTER _serve_batch settled the
        # counters (accounted=True): the future still gets the typed
        # error, but serve.errors must NOT be bumped a second time
        srv._current = _InFlightBatch(key, [_Item(req, fut,
                                                  time.monotonic())],
                                      accounted=True)
        srv._on_worker_crash(RuntimeError("boom"))
        with pytest.raises(WorkerCrashed):
            fut.result(timeout=1)
        st = srv.stats()
        assert st["errors"] == 0            # already accounted; no double
        assert st["worker_restarts"] == 1
        srv.close()

    def test_health_degraded_not_down_during_restart_window(self, tmp_path):
        import threading
        srv = Server(session=Session(cache_dir=tmp_path))
        assert srv.health()["status"] == "ok"
        # the supervisor's window: the replacement thread is registered
        # under the lock, start() has not run yet (ident is None) — a
        # restarting server must read degraded, not down
        with srv._cv:
            real = srv._worker
            srv._worker = threading.Thread(target=lambda: None, daemon=True)
            srv._worker_restarts = 1
        h = srv.health()
        assert h["status"] == "degraded" and not h["worker_alive"]
        with srv._cv:
            srv._worker = real
            srv._worker_restarts = 0
        assert srv.health()["status"] == "ok"
        srv.close()

    def test_close_bounded_when_replacement_never_starts(self, tmp_path):
        import threading
        srv = Server(session=Session(cache_dir=tmp_path))
        # a replacement that was registered but whose start() never ran:
        # close() must give up on its ident instead of spinning forever
        with srv._cv:
            srv._worker = threading.Thread(target=lambda: None, daemon=True)
        t0 = time.monotonic()
        srv.close()
        assert time.monotonic() - t0 < 5.0
