"""Assignment-table conformance for the 10 configs + launch-layer units."""
import pytest

from repro.configs import SHAPES, get_config, list_archs

from repro.launch.mesh import make_local_mesh
from repro.launch.roofline import model_flops, roofline

# (family, L, d_model, H, KV, d_ff, vocab) — verbatim from the assignment
ASSIGNED = {
    "recurrentgemma-2b": ("hybrid", 26, 2560, 10, 1, 7680, 256000),
    "llama-3.2-vision-11b": ("vlm", 40, 4096, 32, 8, 14336, 128256),
    "rwkv6-7b": ("ssm", 32, 4096, 64, 64, 14336, 65536),
    "moonshot-v1-16b-a3b": ("moe", 48, 2048, 16, 16, 1408, 163840),
    "granite-moe-1b-a400m": ("moe", 24, 1024, 16, 8, 512, 49155),
    "gemma-7b": ("dense", 28, 3072, 16, 16, 24576, 256000),
    "h2o-danube-1.8b": ("dense", 24, 2560, 32, 8, 6912, 32000),
    "minitron-8b": ("dense", 32, 4096, 32, 8, 16384, 256000),
    "granite-3-8b": ("dense", 40, 4096, 32, 8, 12800, 49155),
    "hubert-xlarge": ("audio", 48, 1280, 16, 16, 5120, 504),
}

MOE = {"moonshot-v1-16b-a3b": (64, 6), "granite-moe-1b-a400m": (32, 8)}


def test_all_ten_archs_registered():
    assert sorted(list_archs()) == sorted(ASSIGNED)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_config_matches_assignment(arch):
    fam, L, d, H, KV, F, V = ASSIGNED[arch]
    cfg = get_config(arch)
    assert (cfg.family, cfg.n_layers, cfg.d_model, cfg.n_heads,
            cfg.n_kv_heads, cfg.d_ff, cfg.vocab) == (fam, L, d, H, KV, F, V)
    if arch in MOE:
        assert (cfg.n_experts, cfg.top_k) == MOE[arch]
    # special structure
    if arch == "recurrentgemma-2b":
        assert cfg.hybrid_period == 3 and cfg.window == 2048
        kinds = cfg.layer_kinds()
        assert kinds.count("attn") == 8 and kinds.count("rglru") == 18
    if arch == "llama-3.2-vision-11b":
        assert cfg.layer_kinds().count("xattn") == 8
    if arch == "rwkv6-7b":
        assert cfg.attention_free and cfg.resolved_head_dim == 64
    if arch == "hubert-xlarge":
        assert cfg.encoder_only
    if arch == "h2o-danube-1.8b":
        assert cfg.window == 4096
    if arch == "gemma-7b":
        assert cfg.resolved_head_dim == 256


def test_shape_cells_match_assignment():
    assert (SHAPES["train_4k"].seq_len, SHAPES["train_4k"].global_batch) \
        == (4096, 256)
    assert (SHAPES["prefill_32k"].seq_len,
            SHAPES["prefill_32k"].global_batch) == (32768, 32)
    assert (SHAPES["decode_32k"].seq_len,
            SHAPES["decode_32k"].global_batch) == (32768, 128)
    assert (SHAPES["long_500k"].seq_len,
            SHAPES["long_500k"].global_batch) == (524288, 1)


def test_padded_vocab_shards_over_tp():
    for arch in list_archs():
        assert get_config(arch).padded_vocab % 256 == 0


def test_roofline_terms_math():
    t = roofline(flops_per_chip=197e12, bytes_per_chip=819e9,
                 coll_bytes_per_chip=0.0, n_chips=256,
                 model_flops_total=197e12 * 256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.dominant in ("compute", "memory")
    assert t.useful_flops_ratio == pytest.approx(1.0)


def test_model_flops_modes():
    cfg = get_config("granite-3-8b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    dc = model_flops(cfg, SHAPES["decode_32k"])
    assert tr == pytest.approx(6 * cfg.total_params() * 4096 * 256)
    assert pf == pytest.approx(2 * cfg.total_params() * 32768 * 32)
    assert dc == pytest.approx(2 * cfg.total_params() * 128)
    moe = get_config("moonshot-v1-16b-a3b")
    assert model_flops(moe, SHAPES["train_4k"]) < \
        6 * moe.total_params() * 4096 * 256   # active < total


def test_local_mesh_and_context():
    from repro.models import set_mesh_context, pspec
    mesh = make_local_mesh(1, 1)
    set_mesh_context(mesh)
    try:
        spec = pspec("batch", None, "model")
        assert spec[0] in (("data",), "data")    # P may canonicalise 1-tuples
        assert spec[2] == "model"
    finally:
        set_mesh_context(None)


def test_production_mesh_requires_512(monkeypatch):
    """make_production_mesh needs 512 host devices — on this 1-device test
    process it must raise rather than silently mis-shape."""
    from repro.launch.mesh import make_production_mesh
    with pytest.raises(Exception):
        make_production_mesh(multi_pod=True)
