"""Distributed-optimisation mechanics: microbatch accumulation equivalence
and the compressed cross-pod all-reduce under shard_map."""
import json
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.policy import default_plan
from repro.data import DataConfig, SyntheticLMData
from repro.launch.train import (AdamWConfig, TrainConfig, make_train_step)
from repro.models import init_params
from repro.optim import adamw_init


@pytest.mark.slow
def test_accum_steps_matches_full_batch():
    """accum=2 over a split batch == accum=1 over the full batch (the
    gradient mean must be identical up to f32 reduction order)."""
    cfg = get_config("granite-3-8b").reduced()
    plan = default_plan(cfg, seq=16)
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, weight_decay=0.0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=16,
                                    global_batch=8, seed=1))
    x, y = next(ds)
    batch = {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)}

    s1 = jax.jit(make_train_step(cfg, plan, opt, TrainConfig(donate=False)))
    s2 = jax.jit(make_train_step(cfg, plan, opt,
                                 TrainConfig(accum_steps=2, donate=False)))
    p1, _, m1 = s1(params, adamw_init(params), batch)
    p2, _, m2 = s2(params, adamw_init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        # bf16 grads through Adam's normalisation: rare ulp-level flips
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-3, rtol=5e-3)


@pytest.mark.slow
def test_compressed_crosspod_allreduce_subprocess():
    """int8+EF compressed psum over a manual 'pod' axis (shard_map) on 8
    placeholder devices: the compressed mean must track the exact mean and
    the EF residual must carry the difference."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.optim import (CompressionState, compress_int8, decompress_int8,
                         error_feedback_compress)

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))

def sync(grads, err):
    # per-pod grads (already reduced over fast in-pod links) → compress →
    # cross-pod psum of the dequantised tensor + error feedback
    corrected = grads + err
    q, scale = compress_int8(corrected)
    sent = decompress_int8(q, scale)
    new_err = corrected - sent
    total = jax.lax.psum(sent, "pod") / jax.lax.psum(1.0, "pod")
    return total, new_err

import inspect
try:
    from jax import shard_map
except ImportError:                      # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map
# the replication-check kwarg was renamed check_rep -> check_vma; pick
# whichever this shard_map actually accepts
sig = inspect.signature(shard_map).parameters
kw = {"check_vma": False} if "check_vma" in sig else {"check_rep": False}
f = shard_map(sync, mesh=mesh, in_specs=(P("pod"), P("pod")),
              out_specs=(P(None), P("pod")), **kw)

rng = np.random.default_rng(0)
g = jnp.asarray(rng.standard_normal((2, 1024)) * 0.01, jnp.float32)
err = jnp.zeros((2, 1024), jnp.float32)
drift = []
for step in range(10):
    gs = g * (1 + 0.1 * step)
    mean_true = np.asarray(gs).mean(axis=0)
    total, err = f(gs, err)
    approx = np.asarray(total)[0]
    drift.append(float(np.abs(approx - mean_true).max()))
# instantaneous error bounded by the quantisation step; EF keeps it flat
print(json.dumps({"max_drift": max(drift), "last_drift": drift[-1]}))
"""
    res = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["max_drift"] < 5e-4, out     # ~int8 step of 0.01-scale grads
