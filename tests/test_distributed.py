"""Distributed-optimisation mechanics: microbatch accumulation equivalence
and the compressed cross-pod all-reduce under shard_map — plus the HPC
side: co-designed DAGs partitioned across a device mesh
(``Session.lower(mesh=...)``, ``core.lowering.partition_plan``)."""
import json
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.policy import default_plan
from repro.data import DataConfig, SyntheticLMData
from repro.launch.train import (AdamWConfig, TrainConfig, make_train_step)
from repro.models import init_params
from repro.optim import adamw_init


@pytest.mark.slow
def test_accum_steps_matches_full_batch():
    """accum=2 over a split batch == accum=1 over the full batch (the
    gradient mean must be identical up to f32 reduction order)."""
    cfg = get_config("granite-3-8b").reduced()
    plan = default_plan(cfg, seq=16)
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, weight_decay=0.0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=16,
                                    global_batch=8, seed=1))
    x, y = next(ds)
    batch = {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)}

    s1 = jax.jit(make_train_step(cfg, plan, opt, TrainConfig(donate=False)))
    s2 = jax.jit(make_train_step(cfg, plan, opt,
                                 TrainConfig(accum_steps=2, donate=False)))
    p1, _, m1 = s1(params, adamw_init(params), batch)
    p2, _, m2 = s2(params, adamw_init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        # bf16 grads through Adam's normalisation: rare ulp-level flips
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-3, rtol=5e-3)


@pytest.mark.slow
def test_compressed_crosspod_allreduce_subprocess():
    """int8+EF compressed psum over a manual 'pod' axis (shard_map) on 8
    placeholder devices: the compressed mean must track the exact mean and
    the EF residual must carry the difference."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.optim import (CompressionState, compress_int8, decompress_int8,
                         error_feedback_compress)

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))

def sync(grads, err):
    # per-pod grads (already reduced over fast in-pod links) → compress →
    # cross-pod psum of the dequantised tensor + error feedback
    corrected = grads + err
    q, scale = compress_int8(corrected)
    sent = decompress_int8(q, scale)
    new_err = corrected - sent
    total = jax.lax.psum(sent, "pod") / jax.lax.psum(1.0, "pod")
    return total, new_err

import inspect
try:
    from jax import shard_map
except ImportError:                      # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map
# the replication-check kwarg was renamed check_rep -> check_vma; pick
# whichever this shard_map actually accepts
sig = inspect.signature(shard_map).parameters
kw = {"check_vma": False} if "check_vma" in sig else {"check_rep": False}
f = shard_map(sync, mesh=mesh, in_specs=(P("pod"), P("pod")),
              out_specs=(P(None), P("pod")), **kw)

rng = np.random.default_rng(0)
g = jnp.asarray(rng.standard_normal((2, 1024)) * 0.01, jnp.float32)
err = jnp.zeros((2, 1024), jnp.float32)
drift = []
for step in range(10):
    gs = g * (1 + 0.1 * step)
    mean_true = np.asarray(gs).mean(axis=0)
    total, err = f(gs, err)
    approx = np.asarray(total)[0]
    drift.append(float(np.abs(approx - mean_true).max()))
# instantaneous error bounded by the quantisation step; EF keeps it flat
print(json.dumps({"max_drift": max(drift), "last_drift": drift[-1]}))
"""
    res = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["max_drift"] < 5e-4, out     # ~int8 step of 0.01-scale grads

# ---------------------------------------------------------------------------
# HPC plan partitioning: Session.lower(mesh=...) over partition_plan
# ---------------------------------------------------------------------------

from repro.api import CodesignConfig, ExecConfig, Session
from repro.core.buffer import MiB
from repro.core.lowering import PlanPartitionError, partition_plan
from repro.frontends.reference import make_feeds


def _jnp_feeds(program, seed=0):
    # bitwise contract holds for jax-array feeds: numpy feeds route the
    # unsharded oracle's matmuls through numpy BLAS, which need not match
    # XLA bit-for-bit (see docs/distributed.md)
    return {k: jnp.asarray(v) for k, v in make_feeds(program, seed).items()}


def _bitwise(a, b):
    assert set(a) == set(b)
    for k in a:
        assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), k


def test_partition_csr_entry_windows_golden():
    """cg_sparse splits its CSR triple on indptr-aligned entry windows:
    the shard boundaries must equal the cumulative row_counts of the
    deterministic pattern meta, and windows must cover nnz exactly."""
    from repro.frontends.sparse import row_counts
    sess = Session()
    t = sess.trace(workload="cg_sparse", n=256, iters=2)
    plan = sess.lower(sess.codesign(t), mesh=8)
    sp = plan.sharded
    assert sp.n_shards == 8 and sp.rows == 256
    (lay,) = sp.csr
    leaf = t.program.nodes[lay.indptr]
    counts = row_counts(leaf.param("pattern"), 256,
                        density=leaf.param("density"),
                        bandwidth=leaf.param("bandwidth"))
    cum = [0]
    for c in counts:
        cum.append(cum[-1] + int(c))
    assert list(lay.entry_starts) == [cum[k * 32] for k in range(9)]
    assert lay.entry_starts[-1] == lay.nnz
    widest = max(b - a for a, b in zip(lay.entry_starts,
                                       lay.entry_starts[1:]))
    assert lay.pad_entries >= widest and lay.pad_entries % 8 == 0
    for k, sl in enumerate(lay.slices):
        assert sl.rows == 32 and sl.row0 == k * 32
        assert sl.entries == lay.entry_starts[k + 1] - lay.entry_starts[k]


def test_partition_rejections():
    """Everything the contiguous row-block story cannot express fails
    loudly at lower time, never at dispatch."""
    sess = Session()
    t = sess.trace(workload="cg", n=256, iters=2)
    plan = sess.lower(sess.codesign(t))
    # ragged: 256 rows over 3 shards
    with pytest.raises(PlanPartitionError, match="do not split evenly"):
        partition_plan(plan.exec_plan, 3, program=t.program)
    # mttkrp's "abc,cb->ab"-style einsums are not row-block shardable
    tm = sess.trace(workload="mttkrp", rank=16)
    pm = sess.lower(sess.codesign(tm))
    with pytest.raises(PlanPartitionError):
        partition_plan(pm.exec_plan, 4, program=tm.program)
    # overbooked partial pins and sharding both claim the row dimension
    ts = sess.trace(workload="cg_sparse", n=256, iters=2, density=0.3)
    cds = sess.codesign(ts, CodesignConfig(
        overbook=0.25, capacity_bytes=int(0.05 * MiB)))
    partial = dict(getattr(cds.best.schedule.pins, "partial", None) or {})
    if partial:        # overbook only triggers when the searcher takes it
        ps = sess.lower(cds)
        with pytest.raises(PlanPartitionError, match="overbook"):
            partition_plan(ps.exec_plan, 4, program=ts.program)


def test_mesh_k1_degenerates_bitwise():
    """A one-shard mesh is the single-device plan: same outputs, bit for
    bit, and the executors take the plain (unsharded) path."""
    sess = Session()
    t = sess.trace(workload="cg", n=128, iters=3)
    cd = sess.codesign(t)
    feeds = _jnp_feeds(t.program)
    plain = sess.lower(cd).run(feeds)
    k1 = sess.lower(cd, mesh=1)
    assert k1.sharded is not None and k1.sharded.n_shards == 1
    _bitwise(plain, k1.run(feeds))


@pytest.mark.parametrize("wl,params", [
    ("cg", dict(n=256, iters=4)),
    ("cg_sparse", dict(n=256, iters=4)),
    ("jacobi2d", dict(n=64, sweeps=3)),
    ("power_iteration", dict(n=256, iters=3)),
])
def test_sharded_reference_bitwise(wl, params):
    """The sharded reference oracle simulates the mesh on host (eager
    per-op dispatch over K row blocks) — no devices needed, and bitwise
    against the unsharded oracle by construction."""
    sess = Session()
    t = sess.trace(workload=wl, **params)
    cd = sess.codesign(t)
    feeds = _jnp_feeds(t.program)
    ref = sess.lower(cd).run(feeds)
    for k in (4, 8):
        sharded = sess.lower(cd, mesh=k).run(feeds)
        _bitwise(ref, sharded)


def test_mesh_exchange_sets_golden():
    """The partition derives the paper-shaped exchange structure: spmv/
    matmul operands gather, reductions psum, stencils halo-exchange."""
    sess = Session()
    t = sess.trace(workload="cg", n=256, iters=4)
    sp = sess.lower(sess.codesign(t), mesh=8).sharded
    assert set(sp.gathered) == {"x0", "r0", "p1", "p2", "p3"}
    assert "rs0" in sp.reduced and "pAp0" in sp.reduced
    assert not sp.halo
    tj = sess.trace(workload="jacobi2d", n=64, sweeps=3)
    spj = sess.lower(sess.codesign(tj), mesh=4).sharded
    assert set(spj.halo) == {"u1", "u2", "u3"}
    assert not spj.gathered


def test_per_shard_pins_aggregate_capacity():
    """TABLE 11's crossover: an operator too large for one device's
    explicit region pins once the mesh is wide enough — the sharded
    lowering re-codesigns the global graph at aggregate capacity K·C."""
    sess = Session()
    t = sess.trace(workload="cg", n=512, iters=4)      # A = 1 MiB fp32
    cap = int(0.4 * MiB)
    cd = sess.codesign(t, CodesignConfig(capacity_bytes=cap))
    assert "A" not in cd.best.schedule.pins            # does not fit C
    p8 = sess.lower(cd, mesh=8)
    assert p8.codesigned.capacity_bytes == 8 * cap
    assert "A" in p8.codesigned.best.schedule.pins     # fits K·C
    # and the per-shard plan still degenerates bitwise on the oracle
    feeds = _jnp_feeds(t.program)
    _bitwise(sess.lower(cd).run(feeds), p8.run(feeds))


def test_exec_config_and_deprecation_shims():
    """The consolidated typed-config surface: config= and the legacy
    kwargs produce identical plans; mixing them raises; legacy warns."""
    import warnings
    sess = Session()
    t = sess.trace(workload="cg", n=128, iters=2)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = sess.codesign(t, strategy="default", overbook=0.0)
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
    typed = sess.codesign(t, CodesignConfig(strategy="default"))
    assert legacy.best.schedule.pins == typed.best.schedule.pins
    with pytest.raises(TypeError, match="not both"):
        sess.codesign(t, CodesignConfig(), strategy="default")
    with pytest.raises(TypeError, match="not both"):
        sess.lower(typed, ExecConfig(backend="reference"),
                   backend="reference")
    plan = sess.lower(typed, ExecConfig(mesh=(  # named axis round-trips
        "blocks", 4)))
    assert plan.sharded.axis == "blocks"
    assert "mesh=blocks:4" in plan.plan.notes
    # run(config=) picks the backend; a mesh there is rejected (fixed at
    # lower time)
    feeds = _jnp_feeds(t.program)
    out = plan.run(feeds, config=ExecConfig(backend="reference"))
    _bitwise(out, sess.lower(typed).run(feeds))
    with pytest.raises(ValueError, match="re-lower"):
        plan.run(feeds, config=ExecConfig(mesh=2))


@pytest.mark.slow
def test_sharded_pallas_parity_subprocess():
    """The real distributed path: jit(shard_map) around the single-program
    pallas executable on 8 forced host devices — one trace, one dispatch,
    parity with the unsharded oracle within the documented float32
    tolerance (collectives reassociate reductions)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["CELLO_NO_CACHE"] = "1"
import sys; sys.path.insert(0, "src")
import json
import numpy as np
import jax.numpy as jnp
from repro.api import ExecConfig, Session
from repro.frontends.reference import make_feeds

out = {}
for wl, params in [("cg", dict(n=256, iters=4)),
                   ("cg_sparse", dict(n=256, iters=4)),
                   ("jacobi2d", dict(n=64, sweeps=3))]:
    sess = Session()
    t = sess.trace(workload=wl, **params)
    cd = sess.codesign(t)
    feeds = {k: jnp.asarray(v) for k, v in make_feeds(t.program, 0).items()}
    ref = sess.lower(cd).run(feeds)
    plan = sess.lower(cd, config=ExecConfig(backend="pallas", mesh=8))
    from repro.exec.base import get_backend
    prog = get_backend("pallas").compile(plan)   # the stats live per program
    got = prog(feeds)
    rel = max(float(np.max(np.abs(np.asarray(got[k]) - np.asarray(ref[k]))
                           / (np.abs(np.asarray(ref[k])) + 1e-6)))
              for k in ref)
    out[wl] = {"rel": rel, "stats": prog.stats}
print(json.dumps(out))
"""
    res = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for wl, r in out.items():
        assert r["rel"] < 2e-3, (wl, r)
        assert r["stats"]["dispatches"] == 1, (wl, r)
        assert r["stats"]["traces"] == 1, (wl, r)
