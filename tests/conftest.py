"""Shared test helpers: the optional-hypothesis shim.

`hypothesis` is an optional test dependency (the `test` extra installs it).
When present, `given`/`settings`/`st` below are the real thing; when absent,
`@given(...)` replaces the test body with a skip stub so property tests
report as skipped instead of failing at collection.  Test modules import
these names from here instead of each carrying its own try/except copy.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    import pytest

    class _AnyStrategy:
        """Accepts any ``st.<name>(...)`` call; the values are never used
        because ``given`` skips the test before they would be drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        def deco(fn):
            # varargs signature: pytest must not treat the hypothesis
            # parameters as fixture requests
            def _skipped(*args, **kwargs):
                pytest.skip("hypothesis not installed")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco
