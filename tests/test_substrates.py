"""Data pipeline, optimizer (AdamW/ZeRO-1), compression, checkpoint,
fault-tolerance runtime."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import given, settings, st

from repro.data import DataConfig, SyntheticLMData
from repro.optim import (AdamWConfig, adamw_init, adamw_update, cosine_lr,
                         CompressionState, compress_int8, decompress_int8,
                         error_feedback_compress, zero1_pspecs)
from repro.checkpoint import (AsyncCheckpointer, latest_step, load_checkpoint,
                              save_checkpoint)
from repro.runtime import (ElasticScaler, HeartbeatMonitor, StragglerDetector,
                           run_with_restarts)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

class TestData:
    CFG = DataConfig(vocab=64, seq_len=16, global_batch=8, seed=7)

    def test_deterministic_restart(self):
        a = SyntheticLMData(self.CFG)
        for _ in range(3):
            next(a)
        state = a.state_dict()
        want = next(a)
        b = SyntheticLMData(self.CFG)
        b.load_state_dict(state)
        got = next(b)
        np.testing.assert_array_equal(want[0], got[0])
        np.testing.assert_array_equal(want[1], got[1])

    def test_shards_disjoint_streams(self):
        a = SyntheticLMData(self.CFG, shard=0, n_shards=2)
        b = SyntheticLMData(self.CFG, shard=1, n_shards=2)
        xa, _ = next(a)
        xb, _ = next(b)
        assert xa.shape == (4, 16)
        assert not np.array_equal(xa, xb)

    def test_labels_are_shifted_inputs(self):
        x, y = next(SyntheticLMData(self.CFG))
        np.testing.assert_array_equal(x[:, 1:], y[:, :-1])

    def test_markov_tokens_follow_table(self):
        ds = SyntheticLMData(self.CFG)
        x, y = next(ds)
        # every transition must be one of the `branching` successors
        for row_x, row_y in zip(x, y):
            for cur, nxt in zip(row_x, row_y):
                assert nxt in ds._table[cur]

    def test_elastic_reshard_keeps_step(self):
        ds = SyntheticLMData(self.CFG, shard=0, n_shards=2)
        next(ds)
        ds2 = ds.reshard(shard=0, n_shards=4)
        assert ds2.step == ds.step
        assert ds2.local_batch == 2


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=200, clip_norm=10.0)
        target = jnp.asarray([3.0, -2.0, 0.5])
        params = {"w": jnp.zeros(3)}
        state = adamw_init(params)
        for _ in range(200):
            grads = {"w": 2 * (params["w"] - target)}
            params, state, _ = adamw_update(cfg, grads, state, params)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=0.05)

    def test_clipping_bounds_update(self):
        cfg = AdamWConfig(lr=1.0, clip_norm=1e-3, warmup_steps=0,
                          weight_decay=0.0)
        params = {"w": jnp.ones(4)}
        state = adamw_init(params)
        _, _, info = adamw_update(cfg, {"w": jnp.full(4, 1e6)}, state, params)
        assert float(info["grad_norm"]) > 1e5     # reported pre-clip

    def test_cosine_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
        assert float(cosine_lr(cfg, jnp.float32(0))) == 0.0
        assert abs(float(cosine_lr(cfg, jnp.float32(10))) - 1.0) < 1e-6
        assert float(cosine_lr(cfg, jnp.float32(100))) == pytest.approx(
            0.1, rel=1e-3)

    def test_zero1_spec_adds_data_axis(self):
        specs = {"w": (None, "model")}
        shapes = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)}
        out = zero1_pspecs(specs, shapes, data_size=16)
        assert out["w"] == ("data", "model")

    def test_zero1_skips_indivisible(self):
        specs = {"w": (None,)}
        shapes = {"w": jax.ShapeDtypeStruct((7,), jnp.float32)}
        out = zero1_pspecs(specs, shapes, data_size=16)
        assert out["w"] == (None,)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

class TestCompression:
    def test_roundtrip_error_bounded(self):
        g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                        jnp.float32)
        q, s = compress_int8(g)
        err = np.abs(np.asarray(decompress_int8(q, s) - g))
        assert err.max() <= float(s) / 2 + 1e-6

    def test_error_feedback_accumulates_residual(self):
        """Sum of (dequantised + residual) equals sum of true grads —
        the EF invariant that preserves convergence."""
        rng = np.random.default_rng(1)
        grads = {"w": jnp.zeros(64)}
        state = CompressionState.init(grads)
        total_true = np.zeros(64)
        total_sent = np.zeros(64)
        for _ in range(20):
            g = {"w": jnp.asarray(rng.standard_normal(64) * 0.01,
                                  jnp.float32)}
            total_true += np.asarray(g["w"])
            q, s, state = error_feedback_compress(g, state)
            total_sent += np.asarray(decompress_int8(q["w"], s["w"]))
        resid = np.asarray(state.error["w"])
        np.testing.assert_allclose(total_sent + resid, total_true,
                                   atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(scale=st.floats(1e-4, 1e3))
    def test_compression_scale_invariance(self, scale):
        g = jnp.asarray([0.5, -1.0, 0.25]) * scale
        q, s = compress_int8(g)
        back = decompress_int8(q, s)
        np.testing.assert_allclose(np.asarray(back), np.asarray(g),
                                   rtol=0.02, atol=float(s))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def tree(self):
        return {"params": {"w": jnp.arange(12, dtype=jnp.float32)
                           .reshape(3, 4)},
                "opt": {"m": jnp.ones(5), "count": jnp.int32(7)}}

    def test_roundtrip(self, tmp_path):
        t = self.tree()
        save_checkpoint(str(tmp_path), 3, t, extra={"note": "hi"})
        assert latest_step(str(tmp_path)) == 3
        restored, extra = load_checkpoint(str(tmp_path), 3, t)
        assert extra == {"note": "hi"}
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_uncommitted_checkpoint_ignored(self, tmp_path):
        t = self.tree()
        save_checkpoint(str(tmp_path), 1, t)
        # simulate a crash mid-write of step 2
        broken = tmp_path / "step_00000002"
        (broken / "arrays").mkdir(parents=True)
        (broken / "meta.json").write_text("{}")
        assert latest_step(str(tmp_path)) == 1

    def test_shape_mismatch_raises(self, tmp_path):
        t = self.tree()
        save_checkpoint(str(tmp_path), 1, t)
        bad = {"params": {"w": jnp.zeros((4, 4))},
               "opt": {"m": jnp.ones(5), "count": jnp.int32(0)}}
        with pytest.raises(ValueError):
            load_checkpoint(str(tmp_path), 1, bad)

    def test_async_checkpointer_gc(self, tmp_path):
        ck = AsyncCheckpointer(str(tmp_path), keep=2)
        t = self.tree()
        for s in (1, 2, 3, 4):
            ck.save(s, t)
        ck.wait()
        steps = sorted(os.listdir(tmp_path))
        assert steps == ["step_00000003", "step_00000004"]


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

class TestRuntime:
    def test_heartbeat_detects_dead(self):
        clock = [0.0]
        hb = HeartbeatMonitor([0, 1, 2], timeout_s=10,
                              clock=lambda: clock[0])
        clock[0] = 5.0
        hb.beat(0)
        hb.beat(1)
        clock[0] = 12.0
        assert hb.dead_hosts() == [2]

    def test_straggler_flags_outlier(self):
        sd = StragglerDetector(threshold=2.0, patience=2)
        for _ in range(10):
            assert not sd.record(1.0, host=0)
        assert sd.record(5.0, host=1)
        assert not sd.should_evict(1)
        sd.record(5.0, host=1)
        assert sd.should_evict(1)

    def test_elastic_plans(self):
        sc = ElasticScaler(model_axis=16, pod_chips=256)
        p2 = sc.plan(512, restore_step=100)
        assert p2.mesh_shape == (2, 16, 16)
        # one chip short of two pods: falls back to the largest single-pod
        # mesh with the TP axis intact
        p1 = sc.plan(511, restore_step=100)
        assert p1.mesh_shape == (31, 16)
        assert p1.n_devices == 496

    def test_run_with_restarts_recovers(self):
        completed = []
        fail_at = {3, 5}

        def step(i):
            if i in fail_at:
                fail_at.discard(i)
                raise RuntimeError("node died")
            completed.append(i)

        def restore(failed_step):
            return max(0, failed_step - 1)        # resume from checkpoint

        stats = run_with_restarts(step, restore, n_steps=8)
        assert stats["restarts"] == 2
        assert completed[-1] == 7

    def test_run_with_restarts_gives_up(self):
        def step(i):
            raise RuntimeError("always dies")
        with pytest.raises(RuntimeError):
            run_with_restarts(step, lambda s: s, n_steps=2, max_restarts=2)
