"""End-to-end tests for the staged `repro.api.Session` pipeline.

The golden values below were captured from the pre-refactor monolithic
`co_design` loop; the pass-pipeline engine and the Session front-end must
reproduce them bit-for-bit (same enumeration order, same arithmetic), and
the disk cache must round-trip them exactly.
"""
import pytest

from repro.api import (CodesignCache, CompiledPlan, Session, STRATEGY_REGISTRY,
                       get_strategy, run_codesign)
from repro.configs import get_config
from repro.core import OpGraph, TensorKind
from repro.core.lowering import layer_graph
from repro.core.policy import lower_codesign

# (arch, phase) -> (speedup, energy_ratio, time_s, energy_j, hbm_bytes)
# captured from the pre-refactor co_design on these exact shapes
GOLDEN = {
    ("gemma-7b", "decode"): (
        1.003349618286212, 1.0030440092922006,
        0.0013318201514041514, 0.0451301392384, 1090760704),
    ("gemma-7b", "prefill"): (
        1.2486041886321035, 1.26697404526524,
        0.02863717476840609, 1.7330852265983998, 654323712),
    ("gemma-7b", "train"): (
        1.0940315833173384, 1.0826967299077987,
        0.00593242783577665, 0.3751184891903999, 578826240),
    ("granite-3-8b", "decode"): (
        1.0100083018171757, 1.0095089920227445,
        0.0006506188424908424, 0.022433469235199996, 532856832),
    ("granite-3-8b", "prefill"): (
        1.7465935562072885, 1.5494505829857115,
        0.022209712606213197, 1.35723548672, 532692992),
    ("granite-3-8b", "train"): (
        1.121831113680173, 1.0812296777215522,
        0.004319600847918782, 0.2738933202944, 432029696),
}

SHAPES = {
    "decode": dict(batch=8, kv_len=4096),
    "prefill": dict(batch=1, seq=8192),
    "train": dict(batch=2, seq=1024),
}


@pytest.fixture(autouse=True)
def _hermetic_cache_env(monkeypatch):
    """The suite must behave the same whether or not the operator has the
    CELLO_NO_CACHE kill-switch or a custom cache dir exported."""
    monkeypatch.delenv("CELLO_NO_CACHE", raising=False)
    monkeypatch.delenv("CELLO_CACHE_DIR", raising=False)


def _measure(designed):
    m = designed.best.metrics
    return (designed.speedup(), designed.energy_ratio(),
            m.time_s, m.energy_j, m.hbm_bytes)


# ---------------------------------------------------------------------------
# golden end-to-end Session runs
# ---------------------------------------------------------------------------

class TestSessionGolden:
    @pytest.mark.parametrize("arch,phase", sorted(GOLDEN))
    def test_stage_pipeline_matches_pre_refactor(self, arch, phase, tmp_path):
        sess = Session(arch, cache_dir=tmp_path)
        designed = (sess.trace(phase=phase, **SHAPES[phase])
                    .analyze().codesign())
        assert not designed.from_cache
        assert _measure(designed) == GOLDEN[(arch, phase)]

    def test_cache_hit_is_bit_identical(self, tmp_path):
        sess = Session("gemma_7b", cache_dir=tmp_path)
        traced = sess.trace(phase="decode", **SHAPES["decode"])
        fresh = traced.codesign()
        cached = Session("gemma_7b", cache_dir=tmp_path).trace(
            phase="decode", **SHAPES["decode"]).codesign()
        assert cached.from_cache
        assert _measure(cached) == _measure(fresh) == \
            GOLDEN[("gemma-7b", "decode")]
        assert cached.best.schedule.pins == fresh.best.schedule.pins
        assert cached.best.schedule.groups == fresh.best.schedule.groups
        assert cached.split_sweep == fresh.split_sweep
        # lowering from a cache hit yields the identical plan
        assert cached.lower().plan == fresh.lower().plan

    def test_underscore_arch_alias(self, tmp_path):
        a = Session("gemma_7b", cache_dir=tmp_path)
        b = Session("gemma-7b", cache_dir=tmp_path)
        assert a.cfg is b.cfg
        # dotted registry names round-trip from identifier spellings too
        assert Session("llama_3_2_vision_11b").cfg.name == \
            "llama-3.2-vision-11b"
        assert Session("h2o_danube_1_8b").cfg.name == "h2o-danube-1.8b"
        with pytest.raises(KeyError):
            Session("gpt5_colossal")

    def test_wrong_shape_kwarg_for_phase_raises(self, tmp_path):
        sess = Session("gemma-7b", cache_dir=tmp_path)
        with pytest.raises(ValueError, match="kv_len"):
            sess.trace(phase="decode", batch=8, seq=1024)
        with pytest.raises(ValueError, match="seq"):
            sess.trace(phase="train", batch=2, kv_len=4096)

    def test_compile_one_shot(self, tmp_path):
        plan = Session("granite-3-8b", cache_dir=tmp_path).compile(
            phase="train", **SHAPES["train"])
        assert isinstance(plan, CompiledPlan)
        assert plan.codesigned is not None
        rep = plan.report()
        assert rep["speedup_vs_implicit"] == GOLDEN[("granite-3-8b",
                                                     "train")][0]
        text = plan.explain()
        assert "buffer split" in text and "remat save-set" in text


# ---------------------------------------------------------------------------
# the 0.2-era deprecation shims are gone (removed in 0.4 as promised)
# ---------------------------------------------------------------------------

class TestShimsRemoved:
    def test_old_flat_entry_points_are_removed(self):
        import repro.core
        import repro.core.policy
        import repro.core.schedule
        for mod, name in [(repro.core, "co_design"),
                          (repro.core, "plan_from_codesign"),
                          (repro.core.schedule, "co_design"),
                          (repro.core.schedule, "candidate_orders"),
                          (repro.core.policy, "plan_from_codesign")]:
            assert not hasattr(mod, name), (mod.__name__, name)

    def test_new_engine_matches_old_goldens(self, tmp_path):
        # the engine the shims delegated to is still golden-locked
        cfg = get_config("granite-3-8b")
        sess = Session(cfg, cache_dir=tmp_path)
        designed = sess.trace(phase="prefill", **SHAPES["prefill"]) \
            .analyze().codesign()
        assert _measure(designed) == GOLDEN[("granite-3-8b", "prefill")]
        assert designed.lower(seq=8192).plan == \
            lower_codesign(cfg, designed.result, seq=8192)


# ---------------------------------------------------------------------------
# pass / strategy registries
# ---------------------------------------------------------------------------

class TestStrategies:
    def test_registry_has_builtins(self):
        for name in ("default", "exhaustive", "greedy", "alap"):
            assert name in STRATEGY_REGISTRY
            assert get_strategy(name).name == name

    def test_unknown_strategy_raises(self):
        with pytest.raises(KeyError):
            get_strategy("simulated-annealing")

    def test_greedy_subset_of_default(self, tmp_path):
        sess = Session("gemma-7b", cache_dir=tmp_path)
        a = sess.trace(phase="train", **SHAPES["train"]).analyze()
        default = a.codesign(strategy="default")
        greedy = a.codesign(strategy="greedy")
        # greedy explores a subset of orders: can never beat the default
        assert greedy.best.metrics.time_s >= default.best.metrics.time_s
        assert greedy.strategy == "greedy"

    def test_strategies_cache_separately(self, tmp_path):
        sess = Session("gemma-7b", cache_dir=tmp_path)
        a = sess.trace(phase="train", **SHAPES["train"]).analyze()
        a.codesign(strategy="default")
        greedy = a.codesign(strategy="greedy")
        assert not greedy.from_cache      # different key: no aliasing


# ---------------------------------------------------------------------------
# graph indices + builder
# ---------------------------------------------------------------------------

class TestGraphBuilder:
    def test_build_context_manager_validates(self):
        with OpGraph.build("t") as b:
            x = b.input("x", (8, 8))
            w = b.weight("w", (8, 8))
            y = b.einsum("mm", "mk,kn->mn", [x, w], "y",
                         out_kind=TensorKind.OUTPUT)
        g = b.graph
        assert y == "y" and g.producer("y").name == "mm"
        assert [op.name for op in g.consumers("x")] == ["mm"]

    def test_producer_consumer_indices_match_scan(self):
        g = layer_graph(get_config("gemma-7b"), 2, 256)
        for t in g.tensors:
            scan_prod = next((op for op in g.ops.values()
                              if op.output == t), None)
            scan_cons = [op for op in g.ops.values() if t in op.inputs]
            assert g.producer(t) is scan_prod
            assert g.consumers(t) == scan_cons

    def test_consumers_copy_is_isolated(self):
        g = layer_graph(get_config("gemma-7b"), 2, 256)
        got = g.consumers("x")
        got.clear()
        assert g.consumers("x")           # internal index untouched


# ---------------------------------------------------------------------------
# cache robustness
# ---------------------------------------------------------------------------

class TestCache:
    def test_corrupt_entry_is_a_miss(self, tmp_path):
        sess = Session("gemma-7b", cache_dir=tmp_path)
        traced = sess.trace(phase="decode", **SHAPES["decode"])
        traced.codesign()
        for f in tmp_path.glob("*.json"):
            f.write_text("{not json")
        again = traced.codesign()
        assert not again.from_cache
        assert _measure(again) == GOLDEN[("gemma-7b", "decode")]

    def test_capacity_changes_key(self, tmp_path):
        sess = Session("gemma-7b", cache_dir=tmp_path)
        traced = sess.trace(phase="decode", **SHAPES["decode"])
        traced.codesign()
        other = traced.codesign(capacity_bytes=64 * (1 << 20))
        assert not other.from_cache

    def test_run_codesign_direct_matches(self, tmp_path):
        g = layer_graph(get_config("gemma-7b"), 2, 1024)
        res = run_codesign(g)
        assert (res.speedup(), res.energy_ratio()) == \
            GOLDEN[("gemma-7b", "train")][:2]
        cache = CodesignCache(tmp_path)
        cache.put("k", res)
        back = cache.get("k")
        assert back.speedup() == res.speedup()
        assert back.split_sweep == res.split_sweep


# ---------------------------------------------------------------------------
# execution integration (CPU-scale reduced config)
# ---------------------------------------------------------------------------

class TestCompiledPlanExecution:
    def test_serve_bundle_generates(self):
        import jax
        import jax.numpy as jnp
        from repro.models import init_params
        cfg = get_config("granite-3-8b").reduced()
        compiled = Session(cfg).default_plan(seq=8)
        bundle = compiled.serve()
        # stable identity: jax.jit(bundle.decode_fn) must hit its cache
        assert bundle.decode_fn is bundle.decode_fn
        assert bundle.prefill_fn is bundle.prefill_fn
        params = init_params(jax.random.PRNGKey(0), cfg)
        prompt = jnp.zeros((1, 2), jnp.int32)
        out = bundle.generate(params, prompt, n_new=2)
        assert out.shape == (1, 4)

    def test_default_plan_report_and_explain(self):
        compiled = Session("granite-3-8b").default_plan(seq=4096)
        assert compiled.codesigned is None
        rep = compiled.report()
        assert rep["arch"] == "granite-3-8b"
        assert "speedup_vs_implicit" not in rep
        assert "default plan" in compiled.explain()
