"""Edge-case coverage for the implicit (LRU) region of the hybrid buffer:
eviction exactly at capacity, the stream-bypass threshold boundary, and
last-use invalidation dropping dirty chunks without writeback traffic.
"""
import pytest

from repro.core.buffer import (BufferConfig, TrafficReport, _ImplicitLRU,
                               simulate)
from repro.core.graph import OpGraph, TensorKind

KiB = 1024


def _lru(cap, chunk=1 * KiB):
    rep = TrafficReport()
    return _ImplicitLRU(cap, chunk, rep), rep


class TestImplicitLRUEdges:
    def test_fill_to_exactly_full_capacity_holds_everything(self):
        lru, rep = _lru(4 * KiB)
        for i in range(4):
            lru.access(f"t{i}", 1 * KiB, write=False)
        assert lru.used == 4 * KiB and len(lru.lines) == 4
        assert rep.implicit_misses == 4 and rep.implicit_hits == 0
        # at exactly-full capacity every line is still resident: all hits
        for i in range(4):
            lru.access(f"t{i}", 1 * KiB, write=False)
        assert rep.implicit_hits == 4
        assert rep.hbm_read == 4 * KiB          # only the compulsory fills

    def test_insert_at_exactly_full_capacity_evicts_exactly_one(self):
        lru, rep = _lru(4 * KiB)
        for i in range(4):
            lru.access(f"t{i}", 1 * KiB, write=False)
        lru.access("t4", 1 * KiB, write=False)
        assert lru.used == 4 * KiB              # still exactly full
        assert ("t0", 0) not in lru.lines       # LRU victim was the oldest
        assert ("t4", 0) in lru.lines
        # clean eviction: no writeback traffic
        assert rep.hbm_write == 0

    def test_dirty_eviction_writes_back(self):
        lru, rep = _lru(2 * KiB)
        lru.access("w", 1 * KiB, write=True)    # write-allocate, no fetch
        assert rep.hbm_read == 0
        lru.access("a", 1 * KiB, write=False)
        lru.access("b", 1 * KiB, write=False)   # evicts dirty "w"
        assert rep.hbm_write == 1 * KiB
        assert rep.per_tensor["w"] == 1 * KiB

    def test_bypass_threshold_boundary(self):
        # exactly capacity-sized: cached (chunked), not bypassed
        lru, rep = _lru(4 * KiB)
        lru.access("big", 4 * KiB, write=False)
        assert lru.used == 4 * KiB and len(lru.lines) == 4
        lru.access("big", 4 * KiB, write=False)
        assert rep.implicit_hits == 4           # resident on re-access
        # one byte over: full stream bypass, nothing allocated
        lru2, rep2 = _lru(4 * KiB)
        lru2.access("huge", 4 * KiB + 1, write=False)
        assert lru2.used == 0 and not lru2.lines
        assert rep2.hbm_read == 4 * KiB + 1
        assert rep2.implicit_misses == 1
        lru2.access("huge", 4 * KiB + 1, write=False)
        assert rep2.hbm_read == 2 * (4 * KiB + 1)   # re-streams every time
        # bypassed writes stream to HBM directly
        lru2.access("huge", 4 * KiB + 1, write=True)
        assert rep2.hbm_write == 4 * KiB + 1

    def test_invalidate_drops_dirty_chunks_without_writeback(self):
        lru, rep = _lru(4 * KiB)
        lru.access("dead", 2 * KiB, write=True)
        assert lru.used == 2 * KiB
        lru.invalidate("dead")
        assert lru.used == 0 and not lru.lines
        lru.flush()
        assert rep.hbm_write == 0               # dead data never moved

    def test_flush_without_invalidate_writes_dirty_back(self):
        lru, rep = _lru(4 * KiB)
        lru.access("d", 2 * KiB, write=True)
        lru.flush()
        assert rep.hbm_write == 2 * KiB


def _chain_graph(elems=512, dtype_bytes=2):
    """x(INPUT) -> t(intermediate) -> y(OUTPUT), all ``elems`` elements."""
    g = OpGraph("chain")
    g.tensor("x", (elems,), dtype_bytes=dtype_bytes, kind=TensorKind.INPUT)
    g.elementwise("mk_t", ["x"], "t", dtype_bytes=dtype_bytes)
    g.elementwise("mk_y", ["t"], "y", dtype_bytes=dtype_bytes,
                  out_kind=TensorKind.OUTPUT)
    g.validate()
    return g


class TestSimulateHints:
    def test_last_use_invalidate_skips_dead_writeback(self):
        g = _chain_graph()
        groups = [["mk_t"], ["mk_y"]]
        t_bytes = g.tensors["t"].bytes
        y_bytes = g.tensors["y"].bytes
        cfg = dict(capacity_bytes=64 * KiB, explicit_frac=0.0)
        with_hint = simulate(g, groups, BufferConfig(
            **cfg, last_use_invalidate=True))
        without = simulate(g, groups, BufferConfig(
            **cfg, last_use_invalidate=False))
        # the dead intermediate's dirty chunks are dropped, not written back
        assert with_hint.hbm_write == y_bytes
        assert without.hbm_write == y_bytes + t_bytes
        assert with_hint.hbm_read == without.hbm_read

    def test_stream_larger_than_implicit_region_bypasses(self):
        g = _chain_graph(elems=64 * KiB, dtype_bytes=2)   # 128 KiB tensors
        groups = [["mk_t"], ["mk_y"]]
        rep = simulate(g, groups, BufferConfig(
            capacity_bytes=64 * KiB, explicit_frac=0.0))
        # t (128 KiB) exceeds the 64 KiB implicit region: its write and its
        # re-read both stream to/from HBM
        t_bytes = g.tensors["t"].bytes
        assert rep.per_tensor["t"] >= 2 * t_bytes

    def test_pin_plan_overflow_rejected_at_exact_boundary(self):
        g = _chain_graph()
        groups = [["mk_t"], ["mk_y"]]
        t_bytes = g.tensors["t"].bytes
        cap = 2 * t_bytes
        # explicit region exactly t: pin fits
        simulate(g, groups, BufferConfig(capacity_bytes=cap,
                                         explicit_frac=0.5),
                 pins={"t": (0, 1)})
        # explicit region one byte short of t: the pin plan is rejected
        with pytest.raises(ValueError, match="pin plan peak"):
            simulate(g, groups,
                     BufferConfig(capacity_bytes=cap - 2, explicit_frac=0.5),
                     pins={"t": (0, 1)})
