"""Unit tests for the deterministic fault-injection harness
(``repro.testing.faults``): spec grammar, site/qualifier matching,
deterministic fire counts, corrupt transforms, env configuration."""
import time

import pytest

from repro.testing import faults
from repro.testing.faults import InjectedFault


@pytest.fixture(autouse=True)
def _clean_rules():
    faults.clear()
    yield
    faults.clear()


class TestSpecParsing:
    def test_minimal_clause(self):
        (r,) = faults.parse_spec("exec.compile=fail")
        assert (r.site, r.kind, r.qualifier, r.times, r.skip) == \
            ("exec.compile", "fail", None, None, 0)

    def test_full_grammar(self):
        rules = faults.parse_spec(
            "exec.compile@pallas=fail:x3, serve.dispatch=slow:0.05:x2,"
            "codesign.cache=corrupt:x1:skip2")
        a, b, c = rules
        assert (a.site, a.qualifier, a.times) == \
            ("exec.compile", "pallas", 3)
        assert (b.kind, b.delay_s, b.times) == ("slow", 0.05, 2)
        assert (c.kind, c.times, c.skip) == ("corrupt", 1, 2)

    def test_empty_spec_is_no_rules(self):
        assert faults.parse_spec("") == []
        assert faults.parse_spec(" , ") == []

    @pytest.mark.parametrize("bad", [
        "exec.compile",               # no kind
        "=fail",                      # no site
        "site=explode",               # unknown kind
        "site=fail:banana",           # unparseable option
    ])
    def test_bad_clauses_raise(self, bad):
        with pytest.raises(ValueError):
            faults.parse_spec(bad)


class TestCheck:
    def test_inactive_is_noop(self):
        assert not faults.active()
        faults.check("exec.compile", backend="pallas")   # no raise

    def test_fail_exact_count(self):
        with faults.inject("exec.compile", times=2) as rule:
            for _ in range(2):
                with pytest.raises(InjectedFault):
                    faults.check("exec.compile")
            faults.check("exec.compile")         # 3rd call unharmed
            faults.check("exec.compile")
            assert rule.fired == 2 and rule.seen == 4
        assert not faults.active()               # context disarmed

    def test_qualifier_must_match_a_context_value(self):
        with faults.inject("exec.compile@pallas"):
            faults.check("exec.compile", backend="reference")   # no match
            with pytest.raises(InjectedFault):
                faults.check("exec.compile", backend="pallas")

    def test_skip_lets_first_calls_through(self):
        with faults.inject("site", times=1, skip=2) as rule:
            faults.check("site")
            faults.check("site")
            with pytest.raises(InjectedFault):
                faults.check("site")
            assert (rule.seen, rule.fired) == (3, 1)

    def test_slow_sleeps(self):
        with faults.inject("serve.dispatch", kind="slow", delay_s=0.05,
                           times=1):
            t0 = time.perf_counter()
            faults.check("serve.dispatch", backend="reference")
            assert time.perf_counter() - t0 >= 0.045
            t0 = time.perf_counter()
            faults.check("serve.dispatch", backend="reference")  # spent
            assert time.perf_counter() - t0 < 0.04

    def test_message_carries_site(self):
        with faults.inject("exec.dispatch"):
            with pytest.raises(InjectedFault, match="exec.dispatch"):
                faults.check("exec.dispatch", backend="pallas")

    def test_injected_counter_bumps(self):
        from repro import obs
        c = obs.registry().counter("faults.injected")
        before = c.value(site="unit.test.site", kind="fail")
        with faults.inject("unit.test.site", times=1):
            with pytest.raises(InjectedFault):
                faults.check("unit.test.site")
        assert c.value(site="unit.test.site", kind="fail") == before + 1


class TestCorrupt:
    def test_corrupt_truncates_to_half(self):
        blob = "x" * 100
        with faults.inject("codesign.cache", kind="corrupt", times=1):
            assert faults.corrupt_text("codesign.cache", blob) == "x" * 50
            # count spent: passthrough afterwards
            assert faults.corrupt_text("codesign.cache", blob) == blob

    def test_corrupt_ignores_other_sites_and_kinds(self):
        blob = b"payload"
        with faults.inject("other.site", kind="corrupt"):
            assert faults.corrupt_bytes("codesign.cache", blob) == blob
        with faults.inject("codesign.cache", kind="fail"):
            # fail rules never mangle payloads (and corrupt_* never raises)
            assert faults.corrupt_bytes("codesign.cache", blob) == blob

    def test_check_ignores_corrupt_rules(self):
        with faults.inject("codesign.cache", kind="corrupt"):
            faults.check("codesign.cache")       # no raise, no sleep


class TestEnvConfig:
    def test_configure_from_env_arms_and_replaces(self):
        armed = faults.configure_from_env(
            {faults.ENV_VAR: "a.site=fail:x1,b.site=slow:0.01"})
        assert len(armed) == 2 and faults.active()
        # re-configure replaces env rules rather than stacking them
        armed2 = faults.configure_from_env({faults.ENV_VAR: "c.site=fail"})
        assert len(armed2) == 1
        assert [r.site for r in faults.rules()] == ["c.site"]

    def test_env_rules_coexist_with_injected(self):
        faults.configure_from_env({faults.ENV_VAR: "env.site=fail"})
        with faults.inject("ctx.site"):
            assert {r.site for r in faults.rules()} == \
                {"env.site", "ctx.site"}
            faults.configure_from_env({})        # drops env rules only
            assert [r.site for r in faults.rules()] == ["ctx.site"]

    def test_inject_spec_context(self):
        with faults.inject_spec("x.site=fail:x1"):
            with pytest.raises(InjectedFault):
                faults.check("x.site")
        assert not faults.active()
