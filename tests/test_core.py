"""Unit tests for the CELLO core: graph IR, reuse analysis, hybrid buffer,
co-design search, cost model, and policy lowering."""

import pytest

from conftest import given, settings, st

from repro.core import (BufferConfig, OpGraph, TensorKind, analyze,
                        build_groups, layer_graph, lower_codesign,
                        decode_graph, default_plan, run_codesign,
                        sequential_groups, simulate, V5E)
from repro.core.buffer import MiB
from repro.configs import get_config


def small_chain(n_ops: int = 3, dim: int = 256) -> OpGraph:
    g = OpGraph("chain")
    g.tensor("x0", (dim, dim), kind=TensorKind.INPUT)
    for i in range(n_ops):
        g.tensor(f"w{i}", (dim, dim), kind=TensorKind.WEIGHT)
        kind = TensorKind.OUTPUT if i == n_ops - 1 else TensorKind.INTERMEDIATE
        g.einsum(f"mm{i}", "mk,kn->mn", [f"x{i}", f"w{i}"], f"x{i+1}",
                 out_kind=kind)
    g.validate()
    return g


# ---------------------------------------------------------------------------
# graph IR
# ---------------------------------------------------------------------------

class TestGraph:
    def test_einsum_shape_inference(self):
        g = OpGraph()
        g.tensor("a", (4, 8), kind=TensorKind.INPUT)
        g.tensor("b", (8, 16), kind=TensorKind.WEIGHT)
        op = g.einsum("mm", "mk,kn->mn", ["a", "b"], "c")
        assert g.tensors["c"].shape == (4, 16)
        assert op.flops == 2 * 4 * 8 * 16

    def test_einsum_mismatch_raises(self):
        g = OpGraph()
        g.tensor("a", (4, 8), kind=TensorKind.INPUT)
        g.tensor("b", (9, 16), kind=TensorKind.WEIGHT)
        with pytest.raises(ValueError):
            g.einsum("mm", "mk,kn->mn", ["a", "b"], "c")

    def test_use_before_def_raises(self):
        g = OpGraph()
        g.tensor("a", (4, 4), kind=TensorKind.INPUT)
        with pytest.raises(KeyError):
            g.einsum("mm", "mk,kn->mn", ["a", "ghost"], "c")

    def test_compulsory_bytes(self):
        g = small_chain(2, 16)
        # inputs: x0 + w0 + w1, output x2; intermediates excluded
        expect = (16 * 16 * 2) * 4
        assert g.compulsory_bytes() == expect

    def test_topo_orders_enumeration(self):
        g = small_chain(3)
        orders = g.all_topo_orders()
        assert orders == [["mm0", "mm1", "mm2"]]   # chain: unique order

    def test_ai_best_matches_formula(self):
        g = OpGraph()
        M, K, N = 64, 32, 16
        g.tensor("a", (M, K), kind=TensorKind.INPUT)
        g.tensor("b", (K, N), kind=TensorKind.INPUT)
        g.einsum("mm", "mk,kn->mn", ["a", "b"], "z",
                 out_kind=TensorKind.OUTPUT)
        ai = g.arithmetic_intensity_best()
        expect = 2 * M * K * N / (2 * (M * K + K * N + M * N))
        assert abs(ai - expect) < 1e-9


# ---------------------------------------------------------------------------
# reuse analysis
# ---------------------------------------------------------------------------

class TestReuse:
    def test_multi_consumer_distances(self):
        g = OpGraph()
        g.tensor("x", (128, 128), kind=TensorKind.INPUT)
        g.tensor("w1", (128, 128), kind=TensorKind.WEIGHT)
        g.tensor("w2", (128, 128), kind=TensorKind.WEIGHT)
        g.einsum("a", "mk,kn->mn", ["x", "w1"], "y1")
        g.elementwise("b", ["y1"], "y1b")                    # gap op
        g.einsum("c", "mk,kn->mn", ["x", "w2"], "y2")        # x reused later
        g.elementwise("d", ["y1b", "y2"], "z", out_kind=TensorKind.OUTPUT)
        info = analyze(g)
        x = info.tensors["x"]
        assert x.frequency == 2
        # inputs have no def anchor: one consecutive-use distance, which
        # counts the bytes touched by the gap op between the two uses
        assert len(x.reuse_distances) == 1
        assert x.reuse_distances[0] > 0
        # an intermediate does get a def→first-use distance
        y1 = info.tensors["y1"]
        assert len(y1.reuse_distances) == y1.frequency

    def test_pin_value_ranking(self):
        g = small_chain(3)
        info = analyze(g)
        # every weight used once: pin value 0; intermediates used once too
        for c in info.ranked_pin_candidates():
            assert c.pin_value() >= 0


# ---------------------------------------------------------------------------
# hybrid buffer simulator
# ---------------------------------------------------------------------------

class TestBuffer:
    def test_sequential_traffic_at_least_compulsory(self):
        g = small_chain(3)
        cfg = BufferConfig(capacity_bytes=4 * MiB, explicit_frac=0.0,
                           last_use_invalidate=False)
        rep = simulate(g, sequential_groups(g), cfg)
        assert rep.hbm_total >= g.compulsory_bytes()

    def test_infinite_cache_hits_compulsory(self):
        g = small_chain(3, dim=64)
        cfg = BufferConfig(capacity_bytes=1 << 30, explicit_frac=0.0,
                           last_use_invalidate=True)
        rep = simulate(g, sequential_groups(g), cfg)
        assert rep.hbm_total == g.compulsory_bytes()

    def test_pinning_removes_rereads(self):
        g = OpGraph()
        g.tensor("x", (256, 256), kind=TensorKind.INPUT)
        g.tensor("w1", (256, 256), kind=TensorKind.WEIGHT)
        g.tensor("w2", (256, 256), kind=TensorKind.WEIGHT)
        g.einsum("a", "mk,kn->mn", ["x", "w1"], "y1")
        g.einsum("b", "mk,kn->mn", ["x", "w2"], "y2")
        g.elementwise("c", ["y1", "y2"], "z", out_kind=TensorKind.OUTPUT)
        tiny = BufferConfig(capacity_bytes=300 * 1024, explicit_frac=0.5,
                            chunk_bytes=4 * 1024)
        nopin = simulate(g, sequential_groups(g), tiny)
        pin = simulate(g, sequential_groups(g), tiny,
                       pins={"x": (0, 1)})
        assert pin.hbm_total <= nopin.hbm_total

    def test_pin_overflow_raises(self):
        g = small_chain(2, dim=1024)
        cfg = BufferConfig(capacity_bytes=1 * MiB, explicit_frac=0.5)
        with pytest.raises(ValueError):
            simulate(g, sequential_groups(g), cfg,
                     pins={"x1": (0, 1), "x0": (0, 1), "w0": (0, 1),
                           "w1": (0, 1)})

    def test_fused_group_hides_intermediate(self):
        g = small_chain(2, dim=512)
        cfg = BufferConfig(capacity_bytes=64 * MiB, explicit_frac=0.5)
        seq = simulate(g, sequential_groups(g), cfg)
        fused = simulate(g, [["mm0", "mm1"]], cfg)
        # x1 (the intermediate) never reaches HBM or the implicit region
        assert fused.per_tensor.get("x1", 0) == 0
        assert fused.onchip > 0
        assert fused.hbm_total <= seq.hbm_total

    def test_bypass_for_giant_stream(self):
        g = OpGraph()
        g.tensor("x", (1 << 13, 1 << 12), kind=TensorKind.INPUT)  # 64 MiB
        g.elementwise("e", ["x"], "y", out_kind=TensorKind.OUTPUT)
        cfg = BufferConfig(capacity_bytes=1 * MiB, explicit_frac=0.0)
        rep = simulate(g, sequential_groups(g), cfg)
        assert rep.hbm_read >= g.tensors["x"].bytes


# ---------------------------------------------------------------------------
# co-design search
# ---------------------------------------------------------------------------

class TestCoDesign:
    def test_cello_not_worse_than_baselines(self):
        for arch in ("granite-3-8b", "moonshot-v1-16b-a3b", "rwkv6-7b"):
            cfg = get_config(arch)
            g = layer_graph(cfg, batch=2, seq=1024)
            res = run_codesign(g)
            for name, base in res.baselines.items():
                assert res.best.metrics.time_s <= base.metrics.time_s * 1.001, \
                    (arch, name)

    def test_memory_bound_case_speedup(self):
        cfg = get_config("granite-3-8b")
        g = layer_graph(cfg, batch=1, seq=32768)
        res = run_codesign(g)
        assert res.speedup() > 1.5          # flash fusion must pay off
        assert res.energy_ratio() > 1.2

    def test_decode_graph_builds_for_all(self):
        for arch in ("granite-3-8b", "rwkv6-7b", "h2o-danube-1.8b"):
            cfg = get_config(arch)
            g = decode_graph(cfg, batch=8, kv_len=4096)
            res = run_codesign(g)
            assert res.best.metrics.time_s > 0

    def test_groups_are_partition(self):
        cfg = get_config("gemma-7b")
        g = layer_graph(cfg, batch=2, seq=2048)
        groups = build_groups(g, g.topo_order(), 64 * MiB)
        flat = [o for grp in groups for o in grp]
        assert sorted(flat) == sorted(g.ops)

    @settings(max_examples=15, deadline=None)
    @given(dim=st.sampled_from([64, 128, 256]),
           n=st.integers(min_value=2, max_value=5),
           frac=st.sampled_from([0.0, 0.25, 0.5, 1.0]))
    def test_property_traffic_bounds(self, dim, n, frac):
        """Any schedule's traffic is >= compulsory and <= fully-missed."""
        g = small_chain(n, dim)
        cfg = BufferConfig(capacity_bytes=2 * MiB, explicit_frac=frac)
        rep = simulate(g, sequential_groups(g), cfg)
        worst = sum(3 * gBytes for gBytes in
                    [sum(g.tensors[t].bytes
                         for t in list(op.inputs) + [op.output])
                     for op in g.ops.values()])
        assert g.compulsory_bytes() <= rep.hbm_total <= worst


# ---------------------------------------------------------------------------
# policy lowering
# ---------------------------------------------------------------------------

class TestPolicy:
    def test_lower_codesign_turns_on_fusion(self):
        cfg = get_config("granite-3-8b")
        g = layer_graph(cfg, batch=1, seq=8192)
        res = run_codesign(g)
        plan = lower_codesign(cfg, res, seq=8192)
        assert plan.use_flash_attention
        assert plan.use_fused_mlp
        assert plan.q_block % 128 == 0 and plan.kv_block % 128 == 0

    def test_default_plan_blocks_fit_vmem(self):
        for arch in ("gemma-7b", "granite-3-8b", "hubert-xlarge"):
            cfg = get_config(arch)
            plan = default_plan(cfg, seq=4096)
            e = cfg.resolved_head_dim
            ws = (plan.q_block * e * 2 + 2 * plan.kv_block * e * 2
                  + plan.q_block * plan.kv_block * 4
                  + plan.q_block * e * 4 + 2 * plan.q_block * 4)
            assert ws <= V5E.vmem_bytes // 2

    def test_checkpoint_policy_builds(self):
        plan = default_plan(get_config("granite-3-8b"))
        assert plan.checkpoint_policy() is not None
