"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis property tests."""
import numpy as np
import pytest
import jax.numpy as jnp

from conftest import given, settings, st

from repro.kernels.flash_attention import flash_attention, mha_reference
from repro.kernels.fused_mlp import fused_mlp, mlp_reference
from repro.kernels.rglru import rglru, rglru_reference
from repro.kernels.rwkv6 import wkv6, wkv6_reference
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_reference

RNG = np.random.default_rng(42)


def rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # B, H, KVH, S, T, E, causal, window
    (2, 4, 4, 128, 128, 64, True, None),
    (2, 4, 2, 128, 128, 64, True, None),       # GQA
    (1, 4, 1, 256, 256, 64, True, None),       # MQA
    (2, 4, 2, 128, 128, 64, False, None),      # encoder (hubert)
    (2, 4, 2, 256, 256, 64, True, 96),         # sliding window (danube)
    (1, 2, 2, 100, 100, 80, True, None),       # unaligned S and E
    (1, 2, 2, 64, 192, 64, True, None),        # T > S (query offset)
    (1, 2, 2, 64, 160, 64, True, 64),          # window + offset
]


@pytest.mark.parametrize("B,H,KVH,S,T,E,causal,window", ATTN_CASES)
def test_flash_attention_matches_reference(B, H, KVH, S, T, E, causal,
                                           window):
    q, k, v = (rand((B, H, S, E)), rand((B, KVH, T, E)), rand((B, KVH, T, E)))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=64, kv_block=64)
    ref = mha_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-3)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, atol):
    q = rand((2, 4, 128, 64), dtype)
    k = rand((2, 2, 128, 64), dtype)
    v = rand((2, 2, 128, 64), dtype)
    out = flash_attention(q, k, v, causal=True, q_block=64, kv_block=64)
    ref = mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=atol, rtol=2e-2)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(2, 6), blocks=st.sampled_from([32, 64, 128]))
def test_flash_attention_block_size_invariance(s, blocks):
    """Output must not depend on the BlockSpec tiling (pure schedule)."""
    S = s * 32
    q, k, v = (rand((1, 2, S, 32)), rand((1, 2, S, 32)),
               rand((1, 2, S, 32)))
    a = flash_attention(q, k, v, causal=True, q_block=32, kv_block=32)
    b = flash_attention(q, k, v, causal=True, q_block=blocks,
                        kv_block=blocks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=3e-5, rtol=1e-3)


# ---------------------------------------------------------------------------
# fused MLP
# ---------------------------------------------------------------------------

MLP_CASES = [
    (64, 128, 256, "silu", True),
    (100, 64, 96, "gelu", True),        # unaligned M and F
    (64, 128, 256, "relu2", False),     # minitron / rwkv channel-mix
    (64, 128, 200, "gelu", False),      # hubert
]


@pytest.mark.parametrize("M,D,F,act,gated", MLP_CASES)
def test_fused_mlp_matches_reference(M, D, F, act, gated):
    x = rand((M, D), scale=0.5)
    wg = rand((D, F), scale=0.1) if gated else None
    wu, wd = rand((D, F), scale=0.1), rand((F, D), scale=0.1)
    out = fused_mlp(x, wg, wu, wd, activation=act, m_block=32, f_block=64)
    ref = mlp_reference(x, wg, wu, wd, activation=act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-5, rtol=1e-4)


def test_fused_mlp_block_invariance():
    x, wg = rand((96, 64), scale=.5), rand((64, 192), scale=.1)
    wu, wd = rand((64, 192), scale=.1), rand((192, 64), scale=.1)
    a = fused_mlp(x, wg, wu, wd, m_block=32, f_block=32)
    b = fused_mlp(x, wg, wu, wd, m_block=96, f_block=192)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=5e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,D,db", [(2, 16, 64, 64), (1, 33, 100, 32),
                                      (3, 8, 256, 128)])
def test_rglru_matches_reference(B, S, D, db):
    x, gr, gi = rand((B, S, D)), rand((B, S, D)), rand((B, S, D))
    ap, h0 = rand((D,)), rand((B, D))
    y, hT = rglru(x, gr, gi, ap, h0, d_block=db)
    yr, hTr = rglru_reference(x, gr, gi, ap, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTr),
                               atol=2e-5, rtol=1e-4)


def test_rglru_state_carry_composes():
    """Running two halves with carried state == running the whole seq."""
    B, S, D = 2, 32, 64
    x, gr, gi = rand((B, S, D)), rand((B, S, D)), rand((B, S, D))
    ap = rand((D,))
    y_full, hT_full = rglru(x, gr, gi, ap, d_block=64)
    y1, h1 = rglru(x[:, :16], gr[:, :16], gi[:, :16], ap, d_block=64)
    y2, h2 = rglru(x[:, 16:], gr[:, 16:], gi[:, 16:], ap, h1, d_block=64)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(hT_full),
                               atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# WKV6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,S,E", [(1, 2, 16, 32), (2, 3, 9, 64),
                                     (1, 1, 40, 16)])
def test_wkv6_matches_reference(B, H, S, E):
    r, k, v = rand((B, H, S, E)), rand((B, H, S, E), scale=.3), rand((B, H, S, E))
    w, u = rand((B, H, S, E), scale=.5), rand((H, E), scale=.3)
    s0 = rand((B, H, E, E), scale=.2)
    y, sT = wkv6(r, k, v, w, u, s0)
    yr, sTr = wkv6_reference(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=3e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sTr),
                               atol=3e-5, rtol=1e-4)


def test_wkv6_state_carry_composes():
    B, H, S, E = 1, 2, 24, 32
    r, k, v = rand((B, H, S, E)), rand((B, H, S, E), scale=.3), rand((B, H, S, E))
    w, u = rand((B, H, S, E), scale=.5), rand((H, E), scale=.3)
    y_full, sT = wkv6(r, k, v, w, u)
    y1, s1 = wkv6(r[:, :, :12], k[:, :, :12], v[:, :, :12], w[:, :, :12], u)
    y2, s2 = wkv6(r[:, :, 12:], k[:, :, 12:], v[:, :, 12:], w[:, :, 12:], u, s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 2)),
                               np.asarray(y_full), atol=3e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(sT),
                               atol=3e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,D", [(64, 128), (100, 96), (7, 512)])
def test_rmsnorm_matches_reference(M, D):
    x, w = rand((M, D)), rand((D,), scale=.1)
    out = rmsnorm(x, w, row_block=32)
    ref = rmsnorm_reference(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 65), d=st.sampled_from([32, 64, 128]))
def test_rmsnorm_property_unit_scale(m, d):
    """rmsnorm output with w=0 has rms ≈ 1 along the feature dim."""
    x = rand((m, d), scale=3.0)
    out = rmsnorm(x, jnp.zeros((d,)), row_block=16)
    rms = np.sqrt(np.mean(np.square(np.asarray(out)), axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)
