"""Observability-layer tests: metrics registry, streaming histograms,
span tracer, export schemas, and the zero-overhead disabled path.

Quantile policy under test (docs/observability.md): streaming histograms
estimate p50/p90/p99 within ``HIST_REL_ERROR`` (±5%) relative error of the
nearest-rank sample quantile, with exact count/sum/min/max.
"""
import json
import math
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs.metrics import (HIST_REL_ERROR, MetricsRegistry,
                               merge_summaries, next_scope)
from repro.obs.tracing import (JSONL_KEYS, SpanTracer, load_jsonl,
                               validate_chrome, validate_jsonl)


# ---------------------------------------------------------------------------
# counters / gauges / label isolation
# ---------------------------------------------------------------------------

class TestCounters:
    def test_counter_counts_and_labels_are_isolated(self):
        reg = MetricsRegistry()
        c = reg.counter("reqs", "requests")
        c.inc(bucket="a")
        c.inc(2.0, bucket="a")
        c.inc(bucket="b")
        assert c.value(bucket="a") == 3.0
        assert c.value(bucket="b") == 1.0
        assert c.value(bucket="never-bumped") == 0.0

    def test_counter_is_monotonic(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError, match="only go up"):
            reg.counter("c").inc(-1.0)

    def test_get_or_define_returns_same_instrument(self):
        reg = MetricsRegistry()
        assert reg.counter("x", "first help") is reg.counter("x")

    def test_kind_mismatch_raises(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError, match="already defined as counter"):
            reg.histogram("x")

    def test_scope_labels_never_alias_across_instances(self):
        # the pattern every instrumented object uses: one shared registry
        # definition, per-object exactness via a unique scope label
        reg = MetricsRegistry()
        c = reg.counter("dispatches")
        s1, s2 = next_scope("t"), next_scope("t")
        assert s1 != s2
        c.inc(scope=s1)
        c.inc(scope=s1)
        c.inc(scope=s2)
        assert c.value(scope=s1) == 2.0
        assert c.value(scope=s2) == 1.0

    def test_gauge_set_and_add(self):
        reg = MetricsRegistry()
        g = reg.gauge("depth")
        g.set(5, q="a")
        g.add(-2, q="a")
        assert g.value(q="a") == 3.0


# ---------------------------------------------------------------------------
# streaming histograms
# ---------------------------------------------------------------------------

class TestHistograms:
    def test_quantiles_within_documented_error_of_numpy(self):
        rng = np.random.default_rng(7)
        samples = rng.lognormal(mean=-6.0, sigma=1.2, size=5000)
        reg = MetricsRegistry()
        h = reg.histogram("lat", unit="s")
        for x in samples:
            h.observe(float(x))
        for q in (0.50, 0.90, 0.99):
            est = h.quantile(q)
            # nearest-rank sample quantile — the documented reference point
            exact = float(np.percentile(samples, q * 100,
                                        method="inverted_cdf"))
            assert abs(est - exact) / exact <= HIST_REL_ERROR + 1e-9, \
                f"p{q * 100:g}: {est} vs {exact}"

    def test_exact_count_sum_min_max(self):
        reg = MetricsRegistry()
        h = reg.histogram("h")
        xs = [0.003, 0.5, 12.0, 0.0001]
        for x in xs:
            h.observe(x)
        s = h.summary()
        assert s["count"] == 4
        assert s["sum"] == pytest.approx(sum(xs))
        assert s["min"] == min(xs) and s["max"] == max(xs)
        assert s["min"] <= s["p50"] <= s["max"]

    def test_empty_summary(self):
        reg = MetricsRegistry()
        s = reg.histogram("h").summary()
        assert s == {"count": 0, "sum": 0.0, "mean": None, "min": None,
                     "max": None, "p50": None, "p90": None, "p99": None}

    def test_zero_and_negative_go_to_underflow(self):
        reg = MetricsRegistry()
        h = reg.histogram("h")
        for x in (0.0, -1.0, 0.0):
            h.observe(x)
        s = h.summary()
        assert s["count"] == 3 and s["min"] == -1.0
        assert s["p50"] == 0.0    # underflow quantile reports "no time"

    def test_quantile_bounds_checked(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError, match=r"\[0, 1\]"):
            reg.histogram("h").quantile(1.5)

    def test_merge_summaries(self):
        reg = MetricsRegistry()
        h = reg.histogram("h")
        for x in (1.0, 2.0):
            h.observe(x, k="a")
        h.observe(10.0, k="b")
        merged = merge_summaries([h.summary(k="a"), h.summary(k="b")])
        assert merged["count"] == 3
        assert merged["sum"] == pytest.approx(13.0)
        assert merged["min"] == 1.0 and merged["max"] == 10.0


# ---------------------------------------------------------------------------
# registry: snapshot shape, scope filter, thread safety
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_snapshot_shape_and_scope_filter(self):
        reg = MetricsRegistry()
        reg.counter("c", "help text", unit="B").inc(3, scope="s1")
        reg.counter("c").inc(5, scope="s2")
        reg.histogram("h").observe(0.25, scope="s1")
        snap = reg.snapshot()
        assert snap["c"]["kind"] == "counter"
        assert snap["c"]["help"] == "help text"
        assert snap["c"]["unit"] == "B"
        assert {c["labels"]["scope"]: c["value"]
                for c in snap["c"]["cells"]} == {"s1": 3.0, "s2": 5.0}
        assert snap["h"]["cells"][0]["value"]["count"] == 1
        only = reg.snapshot("s1")
        assert [c["labels"] for c in only["c"]["cells"]] == [{"scope": "s1"}]
        # snapshots are plain JSON-serializable data
        json.dumps(snap)

    def test_racing_writers_lose_no_updates(self):
        reg = MetricsRegistry()
        c = reg.counter("c")
        h = reg.histogram("h")
        n_threads, per = 8, 2000
        snaps = []

        def writer(t):
            for i in range(per):
                c.inc(k="shared")
                h.observe(1e-3 * (i + 1), k="shared")

        def reader():
            for _ in range(50):
                snaps.append(reg.snapshot())

        threads = [threading.Thread(target=writer, args=(t,))
                   for t in range(n_threads)] + \
                  [threading.Thread(target=reader)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert c.value(k="shared") == n_threads * per
        s = h.summary(k="shared")
        assert s["count"] == n_threads * per
        assert s["sum"] == pytest.approx(n_threads * per * (per + 1) / 2
                                         * 1e-3)
        # every mid-race snapshot was internally sane
        for snap in snaps:
            for cell in snap.get("c", {}).get("cells", ()):
                assert 0 <= cell["value"] <= n_threads * per

    def test_racing_get_or_define_yields_one_instrument(self):
        reg = MetricsRegistry()
        seen = []

        def define():
            seen.append(reg.counter("same"))

        threads = [threading.Thread(target=define) for _ in range(16)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert all(inst is seen[0] for inst in seen)


# ---------------------------------------------------------------------------
# span tracer: no-op path, nesting, exports, validators
# ---------------------------------------------------------------------------

class TestSpans:
    def test_disabled_span_is_shared_noop(self):
        tr = SpanTracer()            # disabled is the default
        a = tr.span("x", k=1)
        b = tr.span("y")
        assert a is b                # one shared object: allocates nothing
        with a as sp:
            sp.annotate(more=2)      # annotate is a no-op, never raises
        assert tr.spans() == []

    def test_nesting_depth_and_args(self):
        tr = SpanTracer(enabled=True)
        with tr.span("outer", stage="a"):
            with tr.span("inner") as sp:
                sp.annotate(cache="hit")
            with tr.span("inner2"):
                pass
        spans = tr.spans()
        assert [(s["name"], s["depth"]) for s in spans] == \
            [("outer", 0), ("inner", 1), ("inner2", 1)]
        outer = spans[0]
        assert outer["args"] == {"stage": "a"}
        assert spans[1]["args"] == {"cache": "hit"}
        # children fall inside the parent interval
        for child in spans[1:]:
            assert child["ts_us"] >= outer["ts_us"]
            assert (child["ts_us"] + child["dur_us"]
                    <= outer["ts_us"] + outer["dur_us"] + 1e-6)

    def test_record_synthetic_spans(self):
        tr = SpanTracer(enabled=True)
        t0 = tr.now()
        tr.record("pass.order", t0, 0.25, points=3)
        (rec,) = tr.spans()
        assert rec["name"] == "pass.order"
        assert rec["dur_us"] == pytest.approx(0.25e6)
        assert rec["args"] == {"points": 3}

    def test_jsonl_roundtrip_and_schema(self, tmp_path):
        tr = SpanTracer(enabled=True)
        with tr.span("a", arch="hpc:cg"):
            with tr.span("b"):
                pass
        path = tmp_path / "spans.jsonl"
        assert tr.export_jsonl(path) == 2
        assert validate_jsonl(path) == 2
        loaded = load_jsonl(path)
        assert sorted(r["name"] for r in loaded) == ["a", "b"]
        for rec in loaded:
            assert tuple(sorted(rec)) == tuple(sorted(JSONL_KEYS))

    def test_chrome_export_and_schema(self, tmp_path):
        tr = SpanTracer(enabled=True)
        with tr.span("session.codesign", strategy="default"):
            with tr.span("codesign.search"):
                pass
        path = tmp_path / "trace.json"
        assert tr.export_chrome(path) == 2
        assert validate_chrome(path) == 2
        with open(path) as f:
            doc = json.load(f)
        assert doc["displayTimeUnit"] == "ms"
        by_name = {ev["name"]: ev for ev in doc["traceEvents"]}
        assert by_name["session.codesign"]["ph"] == "X"
        assert by_name["session.codesign"]["cat"] == "session"
        assert by_name["codesign.search"]["cat"] == "codesign"
        assert by_name["session.codesign"]["args"] == {"strategy": "default"}

    def test_validators_reject_schema_violations(self, tmp_path):
        bad = tmp_path / "bad.jsonl"
        bad.write_text('{"name": "x", "ts_us": 0}\n')
        with pytest.raises(ValueError, match="missing keys"):
            validate_jsonl(bad)
        extra = tmp_path / "extra.jsonl"
        extra.write_text(json.dumps(
            {k: ({} if k == "args" else "x" if k == "name" else 0)
             for k in JSONL_KEYS} | {"rogue": 1}) + "\n")
        with pytest.raises(ValueError, match="unexpected keys"):
            validate_jsonl(extra)
        badc = tmp_path / "bad.json"
        badc.write_text(json.dumps({"traceEvents": [
            {"name": "x", "ph": "B", "ts": 0, "dur": 0,
             "pid": 1, "tid": 1}]}))
        with pytest.raises(ValueError, match="ph must be 'X'"):
            validate_chrome(badc)

    def test_nonjson_args_are_reprd(self):
        tr = SpanTracer(enabled=True)
        with tr.span("x", shape=(4, 4)):
            pass
        (rec,) = tr.spans()
        assert rec["args"]["shape"] == repr((4, 4))

    def test_threads_record_independent_depths(self):
        tr = SpanTracer(enabled=True)

        def work(i):
            with tr.span(f"outer{i}"):
                with tr.span(f"inner{i}"):
                    pass

        threads = [threading.Thread(target=work, args=(i,))
                   for i in range(8)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        spans = tr.spans()
        assert len(spans) == 16
        depth = {s["name"]: s["depth"] for s in spans}
        for i in range(8):
            assert depth[f"outer{i}"] == 0 and depth[f"inner{i}"] == 1


# ---------------------------------------------------------------------------
# the repro.obs facade: env spec parsing, sinks, global instrumentation
# ---------------------------------------------------------------------------

class TestFacade:
    def test_configure_from_env_off_values(self):
        assert obs.configure_from_env("") is False
        assert obs.configure_from_env("0") is False
        assert obs.configure_from_env("off") is False

    def test_configure_from_env_malformed_part_warns(self, tmp_path):
        was_enabled = obs.tracer().enabled
        try:
            with pytest.warns(UserWarning, match="unrecognized part"):
                assert obs.configure_from_env("bogus-spec") is True
        finally:
            if not was_enabled:
                obs.disable()

    def test_enable_flush_jsonl_sink(self, tmp_path):
        path = tmp_path / "out.jsonl"
        was_enabled = obs.tracer().enabled
        obs.enable(jsonl=str(path))
        try:
            with obs.span("facade.test"):
                pass
            counts = obs.flush()
            assert counts[str(path)] >= 1
            assert validate_jsonl(path) >= 1
            assert any(r["name"] == "facade.test"
                       for r in load_jsonl(path))
        finally:
            obs._SINKS[:] = [s for s in obs._SINKS if s[1] != str(path)]
            if not was_enabled:
                obs.disable()

    def test_global_session_stage_instruments_exist(self):
        # the instrumented layers define their metrics at import: one
        # registry, each name defined exactly once, kinds stable
        import repro.api.session  # noqa: F401  (defines the instruments)
        import repro.exec.base    # noqa: F401
        import repro.serve.server  # noqa: F401
        reg = obs.registry()
        names = reg.names()
        for needed in ("session.stage_s", "session.stage_runs",
                       "codesign.search_s", "codesign.points",
                       "codesign.cache.hits", "codesign.cache.misses",
                       "exec.compile_s", "exec.run_s",
                       "serve.requests", "serve.e2e_latency_s"):
            assert needed in names
        with pytest.raises(TypeError):
            reg.histogram("session.stage_runs")   # defined as a counter
