"""Execution-backend tests: registry, kernel selection, and parity.

Parity policy (see docs/execution_backends.md):

* ``reference`` replays the co-designed schedule order through the same
  pure per-op rules as natural-order evaluation — it must match the
  natural-order oracle **bit-for-bit**.
* ``pallas`` tiles reductions (per-tile partials accumulated across the
  grid) and computes leaf-consuming contractions through XLA rather than
  NumPy's BLAS, so it matches within reduction-reassociation tolerances:
  rtol=2e-4 / atol=1e-5 for float32.  Everything elementwise and every
  per-row matvec lane uses the reference rules verbatim.
"""
import numpy as np
import pytest

from repro.api import Session, get_backend, list_backends, register_backend
from repro.core import build_groups, select_group_kernels
from repro.exec import (EXECUTOR_REGISTRY, Executor, ReferenceExecutor,
                        evaluate, plan_order)
from repro.frontends import Program, build_workload, make_feeds

# float32 reduction-reassociation tolerances (documented policy)
RTOL, ATOL = 2e-4, 1e-5

#: every workload in the HPC registry, sized small enough for interpret-mode
#: CI but large enough that streaming passes run multiple row tiles
PARITY_SET = [
    ("cg", dict(n=96, iters=3)),
    ("bicgstab", dict(n=96, iters=2)),
    ("gmres", dict(n=96, restart=3)),
    ("jacobi2d", dict(n=32, sweeps=3)),
    ("power_iteration", dict(n=96, iters=3)),
    ("mttkrp", dict(i=24, j=24, k=24, rank=8)),
]


def _llm_ffn_program(m=64, d=32, f=48) -> Program:
    """One LLM FFN phase (gated MLP over a token block) on the expression
    frontend: the token dimension streams, the weight matrices are the
    resident operands — the same shape class `core.policy` fuses for the
    arch-registry plans."""
    p = Program("llm_ffn_prefill")
    x = p.input("x", (m, d))
    w_up = p.operator("w_up", (d, f))
    w_gate = p.operator("w_gate", (d, f))
    w_down = p.operator("w_down", (f, d))
    h = p.matmul(x, w_up, name="up")
    g = p.matmul(x, w_gate, name="gate")
    a = p.mul(h, g, name="act")
    p.output(p.matmul(a, w_down, name="ffn_out"))
    return p


def _lowered(tmp_path, workload=None, program=None, **params):
    if workload is not None:
        traced = Session(cache_dir=tmp_path).trace(workload=workload,
                                                   **params)
    else:
        traced = Session.from_graph(program, cache_dir=tmp_path)
    return traced, traced.analyze().codesign().lower()


# ---------------------------------------------------------------------------
# backend parity: HPC registry + one LLM phase under both backends
# ---------------------------------------------------------------------------

class TestBackendParity:
    @pytest.mark.parametrize("workload,params",
                             PARITY_SET, ids=[w for w, _ in PARITY_SET])
    def test_hpc_workload_parity(self, workload, params, tmp_path):
        traced, plan = _lowered(tmp_path, workload=workload, **params)
        feeds = make_feeds(traced.program, seed=7)
        want = evaluate(traced.program, feeds)

        ref = plan.run(feeds, backend="reference")
        assert sorted(ref) == sorted(want)
        for k in want:                    # same pure ops => bitwise
            np.testing.assert_array_equal(np.asarray(ref[k]),
                                          np.asarray(want[k]), err_msg=k)

        pal = plan.run(feeds, backend="pallas")
        assert sorted(pal) == sorted(want)
        for k in want:
            np.testing.assert_allclose(np.asarray(pal[k]),
                                       np.asarray(want[k]),
                                       rtol=RTOL, atol=ATOL, err_msg=k)

    def test_llm_ffn_phase_parity(self, tmp_path):
        prog = _llm_ffn_program()
        traced, plan = _lowered(tmp_path, program=prog)
        feeds = make_feeds(prog, seed=5)
        want = evaluate(prog, feeds)
        ref = plan.run(feeds, backend="reference")
        pal = plan.run(feeds, backend="pallas")
        np.testing.assert_array_equal(np.asarray(ref["ffn_out"]),
                                      np.asarray(want["ffn_out"]))
        np.testing.assert_allclose(np.asarray(pal["ffn_out"]),
                                   np.asarray(want["ffn_out"]),
                                   rtol=RTOL, atol=ATOL)
        # weights are resident operands of the streaming passes
        res = {t for gk in plan.group_kernels for p in gk.passes
               for t in p.resident}
        assert res & {"w_up", "w_gate", "w_down"}

    def test_awkward_row_count_still_streams(self, tmp_path):
        # rows=50: only tile divisors 2 and 1 exist — the streamer must
        # still produce correct results at the finest granularity
        p = Program("odd_rows")
        A = p.operator("A", (50, 50), init="spd")
        x = p.input("x", (50,))
        y = p.matmul(A, x, name="y")
        p.output(p.dot(y, y, name="yy"))
        traced, plan = _lowered(tmp_path, program=p)
        feeds = make_feeds(p, seed=2)
        want = evaluate(p, feeds)
        got = plan.run(feeds, backend="pallas")
        np.testing.assert_allclose(np.asarray(got["yy"]),
                                   np.asarray(want["yy"]),
                                   rtol=RTOL, atol=ATOL)

    def test_pallas_runs_codesigned_group_order(self, tmp_path):
        # the scheduled order a backend must honor differs from build
        # order whenever the search reorders; assert the contract on the
        # plan the backends actually execute
        traced, plan = _lowered(tmp_path, workload="cg", n=96, iters=3)
        order = plan_order(plan)
        natural = [n for n in traced.program._order
                   if not traced.program.nodes[n].is_leaf]
        assert sorted(order) == sorted(natural)
        groups = [list(g) for g in plan.codesigned.best.schedule.groups]
        assert order == [o for g in groups for o in g]


# ---------------------------------------------------------------------------
# fp64 validation path (make_feeds dtype satellite)
# ---------------------------------------------------------------------------

class TestFeedsDtype:
    def test_make_feeds_dtype(self):
        prog = build_workload("cg", n=16, iters=1)
        f32 = make_feeds(prog, seed=0)
        f64 = make_feeds(prog, seed=0, dtype=np.float64)
        assert all(v.dtype == np.float32 for v in f32.values())
        assert all(v.dtype == np.float64 for v in f64.values())
        # same generator stream, cast at the end: identical values
        for k in f32:
            np.testing.assert_allclose(f32[k], f64[k].astype(np.float32),
                                       rtol=0, atol=0)

    def test_index_leaves_stay_int32(self):
        p = Program("g")
        x = p.input("x", (8, 4))
        idx = p.input("idx", (3,), init="indices")
        p.output(p.gather(x, idx, name="out"))
        feeds = make_feeds(p, seed=0, dtype=np.float64)
        assert feeds["idx"].dtype == np.int32
        assert feeds["x"].dtype == np.float64

    def test_non_float_dtype_rejected(self):
        prog = build_workload("cg", n=16, iters=1)
        with pytest.raises(ValueError, match="float dtype"):
            make_feeds(prog, dtype=np.int32)

    def test_fp64_evaluation_under_x64(self, tmp_path):
        import jax
        prog = build_workload("cg", n=32, iters=2)
        feeds = make_feeds(prog, seed=1, dtype=np.float64)
        with jax.experimental.enable_x64():
            out = evaluate(prog, feeds)
            assert np.asarray(out["x2"]).dtype == np.float64
            # fp64 CG at n=32 is essentially exact: residual identity holds
            # far beyond fp32 precision
            A, b = feeds["A"], feeds["b"]
            r = np.asarray(out["r2"], np.float64)
            x = np.asarray(out["x2"], np.float64)
            np.testing.assert_allclose(r, b - A @ x, rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# registry + plan threading
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_builtins_registered(self):
        assert {"reference", "pallas"} <= set(list_backends())
        assert get_backend("reference").name == "reference"
        assert get_backend("pallas").name == "pallas"

    def test_unknown_backend_raises(self, tmp_path):
        with pytest.raises(KeyError, match="unknown execution backend"):
            get_backend("tpu-real")
        _, plan = _lowered(tmp_path, workload="cg", n=16, iters=1)
        with pytest.raises(KeyError, match="unknown execution backend"):
            plan.run(backend="tpu-real")

    def test_lower_backend_sets_default(self, tmp_path):
        traced = Session(cache_dir=tmp_path).trace(workload="power_iteration",
                                                   n=32, iters=2)
        designed = traced.analyze().codesign()
        plan = designed.lower(backend="pallas")
        assert plan.backend == "pallas"
        assert "execution backend : pallas" in plan.explain()
        feeds = make_feeds(traced.program, seed=0)
        got = plan.run(feeds)                 # defaults to pallas
        want = evaluate(traced.program, feeds)
        np.testing.assert_allclose(np.asarray(got["x2"]),
                                   np.asarray(want["x2"]),
                                   rtol=RTOL, atol=ATOL)

    def test_custom_backend_registers_and_runs(self, tmp_path):
        class ShoutingReference(ReferenceExecutor):
            name = "shouting-reference"

        register_backend(ShoutingReference)
        try:
            _, plan = _lowered(tmp_path, workload="cg", n=16, iters=1)
            got = plan.run(seed=4, backend="shouting-reference")
            want = plan.run(seed=4, backend="reference")
            for k in want:
                np.testing.assert_array_equal(np.asarray(got[k]),
                                              np.asarray(want[k]))
        finally:
            EXECUTOR_REGISTRY.pop("shouting-reference", None)

    def test_executor_is_abstract(self, tmp_path):
        _, plan = _lowered(tmp_path, workload="cg", n=16, iters=1)
        with pytest.raises(NotImplementedError):
            Executor().run(plan)

    def test_run_missing_feed_raises(self, tmp_path):
        traced, plan = _lowered(tmp_path, workload="cg", n=16, iters=1)
        feeds = make_feeds(traced.program, seed=0)
        feeds.pop("b")
        for backend in ("reference", "pallas"):
            with pytest.raises(KeyError, match="feeds missing leaf"):
                plan.run(feeds, backend=backend)


# ---------------------------------------------------------------------------
# group -> kernel-shape selection
# ---------------------------------------------------------------------------

class TestKernelSelection:
    def _kernels(self, workload, **params):
        prog = build_workload(workload, **params)
        graph = prog.to_graph()
        groups = build_groups(graph, graph.topo_order(), 64 << 20)
        return select_group_kernels(graph, groups, 64 << 20)

    def test_kernels_partition_the_groups(self, tmp_path):
        _, plan = _lowered(tmp_path, workload="cg", n=96, iters=2)
        groups = [tuple(g) for g in plan.codesigned.best.schedule.groups]
        assert [gk.ops for gk in plan.group_kernels] == groups
        for gk in plan.group_kernels:
            if gk.kind == "stream":
                flat = [o for p in gk.passes for o in p.ops]
                assert flat == list(gk.ops)       # passes partition group
                for p in gk.passes:
                    assert p.rows % p.tile_rows == 0

    def test_cg_in_pass_rhs_splits_into_two_passes(self):
        kernels = self._kernels("cg", n=128, iters=2)
        multi = [gk for gk in kernels
                 if gk.kind == "stream" and len(gk.passes) == 2]
        # p_{k+1} = axpy(...) immediately feeds A @ p_{k+1}: the vector
        # must materialize before it can sit resident for the matvec
        assert multi, [gk.describe() for gk in kernels]
        gk = multi[0]
        assert gk.passes[1].resident    # second pass holds the new vector

    def test_in_pass_scalar_consumer_splits_and_executes(self):
        # schedule.fusable never fuses a tiled op with the in-pass scalar
        # it reads, but select_group_kernels is public API and must stay
        # safe for hand-built groups: the pass splits where the scalar
        # must materialize, and the resulting kernels execute correctly
        import jax.numpy as jnp

        from repro.exec.pallas import _StreamCall
        p = Program("scal")
        x = p.input("x", (16,))
        y = p.input("y", (16,))
        d = p.dot(x, y, name="d")
        p.output(p.axpy(d, x, y, name="z"))
        graph = p.to_graph()
        kernels = select_group_kernels(graph, [["d", "z"]], 1 << 20)
        assert kernels[0].kind == "stream"
        assert [pss.ops for pss in kernels[0].passes] == [("d",), ("z",)]
        feeds = make_feeds(p, seed=0)
        env = {k: jnp.asarray(v) for k, v in feeds.items()}
        for sp in kernels[0].passes:
            env.update(_StreamCall(p, sp, needed={"d", "z"})(env))
        want = evaluate(p, feeds)
        np.testing.assert_allclose(np.asarray(env["z"]),
                                   np.asarray(want["z"]),
                                   rtol=RTOL, atol=ATOL)

    def test_jacobi_is_block_kernel(self):
        kernels = self._kernels("jacobi2d", n=32, sweeps=3)
        assert all(gk.kind == "block" for gk in kernels)

    def test_mttkrp_falls_back_with_reason(self):
        kernels = self._kernels("mttkrp", i=16, j=16, k=16, rank=4)
        assert all(gk.kind == "jnp" for gk in kernels)
        assert any("einsum" in gk.reason for gk in kernels)

    def test_gather_falls_back_irregular(self):
        p = Program("gath")
        x = p.input("x", (32, 8))
        idx = p.input("idx", (8,), init="indices")
        p.output(p.gather(x, idx, name="g"))
        graph = p.to_graph()
        kernels = select_group_kernels(
            graph, build_groups(graph, graph.topo_order(), 1 << 20), 1 << 20)
        assert kernels[0].kind == "jnp"
        assert "irregular" in kernels[0].reason

    def test_irregular_parity_through_fallback(self, tmp_path):
        p = Program("gath2")
        x = p.input("x", (32, 8))
        idx = p.input("idx", (8,), init="indices")
        g = p.gather(x, idx, name="g")
        p.output(p.mul(g, g, name="sq"))
        traced, plan = _lowered(tmp_path, program=p)
        feeds = make_feeds(p, seed=9)
        want = evaluate(p, feeds)
        got = plan.run(feeds, backend="pallas")
        np.testing.assert_array_equal(np.asarray(got["sq"]),
                                      np.asarray(want["sq"]))
