"""Execution-backend tests: registry, kernel selection, and parity.

Parity policy (see docs/execution_backends.md):

* ``reference`` replays the co-designed schedule order through the same
  pure per-op rules as natural-order evaluation — it must match the
  natural-order oracle **bit-for-bit**.
* ``pallas`` tiles reductions (per-tile partials accumulated across the
  grid) and computes leaf-consuming contractions through XLA rather than
  NumPy's BLAS, so it matches within reduction-reassociation tolerances:
  rtol=2e-4 / atol=1e-5 for float32.  Everything elementwise and every
  per-row matvec lane uses the reference rules verbatim.
"""
import time

import numpy as np
import pytest

from repro.api import Session, get_backend, list_backends, register_backend
from repro.core import build_groups, select_group_kernels
from repro.core.lowering import (_pick_tile_rows, detect_rolled_loop,
                                 flatten_units, fuse_units)
from repro.exec import (EXECUTOR_REGISTRY, Executor, ReferenceExecutor,
                        evaluate, plan_order)
from repro.frontends import Program, build_workload, make_feeds

# float32 reduction-reassociation tolerances (documented policy)
RTOL, ATOL = 2e-4, 1e-5

#: every workload in the HPC registry, sized small enough for interpret-mode
#: CI but large enough that streaming passes run multiple row tiles
PARITY_SET = [
    ("cg", dict(n=96, iters=3)),
    ("bicgstab", dict(n=96, iters=2)),
    ("gmres", dict(n=96, restart=3)),
    ("jacobi2d", dict(n=32, sweeps=3)),
    ("power_iteration", dict(n=96, iters=3)),
    ("mttkrp", dict(i=24, j=24, k=24, rank=8)),
]


def _llm_ffn_program(m=64, d=32, f=48) -> Program:
    """One LLM FFN phase (gated MLP over a token block) on the expression
    frontend: the token dimension streams, the weight matrices are the
    resident operands — the same shape class `core.policy` fuses for the
    arch-registry plans."""
    p = Program("llm_ffn_prefill")
    x = p.input("x", (m, d))
    w_up = p.operator("w_up", (d, f))
    w_gate = p.operator("w_gate", (d, f))
    w_down = p.operator("w_down", (f, d))
    h = p.matmul(x, w_up, name="up")
    g = p.matmul(x, w_gate, name="gate")
    a = p.mul(h, g, name="act")
    p.output(p.matmul(a, w_down, name="ffn_out"))
    return p


def _lowered(tmp_path, workload=None, program=None, **params):
    if workload is not None:
        traced = Session(cache_dir=tmp_path).trace(workload=workload,
                                                   **params)
    else:
        traced = Session.from_graph(program, cache_dir=tmp_path)
    return traced, traced.analyze().codesign().lower()


# ---------------------------------------------------------------------------
# backend parity: HPC registry + one LLM phase under both backends
# ---------------------------------------------------------------------------

class TestBackendParity:
    @pytest.mark.parametrize("workload,params",
                             PARITY_SET, ids=[w for w, _ in PARITY_SET])
    def test_hpc_workload_parity(self, workload, params, tmp_path):
        traced, plan = _lowered(tmp_path, workload=workload, **params)
        feeds = make_feeds(traced.program, seed=7)
        want = evaluate(traced.program, feeds)

        ref = plan.run(feeds, backend="reference")
        assert sorted(ref) == sorted(want)
        for k in want:                    # same pure ops => bitwise
            np.testing.assert_array_equal(np.asarray(ref[k]),
                                          np.asarray(want[k]), err_msg=k)

        pal = plan.run(feeds, backend="pallas")
        assert sorted(pal) == sorted(want)
        for k in want:
            np.testing.assert_allclose(np.asarray(pal[k]),
                                       np.asarray(want[k]),
                                       rtol=RTOL, atol=ATOL, err_msg=k)

    def test_llm_ffn_phase_parity(self, tmp_path):
        prog = _llm_ffn_program()
        traced, plan = _lowered(tmp_path, program=prog)
        feeds = make_feeds(prog, seed=5)
        want = evaluate(prog, feeds)
        ref = plan.run(feeds, backend="reference")
        pal = plan.run(feeds, backend="pallas")
        np.testing.assert_array_equal(np.asarray(ref["ffn_out"]),
                                      np.asarray(want["ffn_out"]))
        np.testing.assert_allclose(np.asarray(pal["ffn_out"]),
                                   np.asarray(want["ffn_out"]),
                                   rtol=RTOL, atol=ATOL)
        # weights are resident operands of the streaming passes
        res = {t for gk in plan.group_kernels for p in gk.passes
               for t in p.resident}
        assert res & {"w_up", "w_gate", "w_down"}

    def test_awkward_row_count_still_streams(self, tmp_path):
        # rows=50: only tile divisors 2 and 1 exist — the streamer must
        # still produce correct results at the finest granularity
        p = Program("odd_rows")
        A = p.operator("A", (50, 50), init="spd")
        x = p.input("x", (50,))
        y = p.matmul(A, x, name="y")
        p.output(p.dot(y, y, name="yy"))
        traced, plan = _lowered(tmp_path, program=p)
        feeds = make_feeds(p, seed=2)
        want = evaluate(p, feeds)
        got = plan.run(feeds, backend="pallas")
        np.testing.assert_allclose(np.asarray(got["yy"]),
                                   np.asarray(want["yy"]),
                                   rtol=RTOL, atol=ATOL)

    def test_pallas_runs_codesigned_group_order(self, tmp_path):
        # the scheduled order a backend must honor differs from build
        # order whenever the search reorders; assert the contract on the
        # plan the backends actually execute
        traced, plan = _lowered(tmp_path, workload="cg", n=96, iters=3)
        order = plan_order(plan)
        natural = [n for n in traced.program._order
                   if not traced.program.nodes[n].is_leaf]
        assert sorted(order) == sorted(natural)
        groups = [list(g) for g in plan.codesigned.best.schedule.groups]
        assert order == [o for g in groups for o in g]


# ---------------------------------------------------------------------------
# fp64 validation path (make_feeds dtype satellite)
# ---------------------------------------------------------------------------

class TestFeedsDtype:
    def test_make_feeds_dtype(self):
        prog = build_workload("cg", n=16, iters=1)
        f32 = make_feeds(prog, seed=0)
        f64 = make_feeds(prog, seed=0, dtype=np.float64)
        assert all(v.dtype == np.float32 for v in f32.values())
        assert all(v.dtype == np.float64 for v in f64.values())
        # same generator stream, cast at the end: identical values
        for k in f32:
            np.testing.assert_allclose(f32[k], f64[k].astype(np.float32),
                                       rtol=0, atol=0)

    def test_index_leaves_stay_int32(self):
        p = Program("g")
        x = p.input("x", (8, 4))
        idx = p.input("idx", (3,), init="indices")
        p.output(p.gather(x, idx, name="out"))
        feeds = make_feeds(p, seed=0, dtype=np.float64)
        assert feeds["idx"].dtype == np.int32
        assert feeds["x"].dtype == np.float64

    def test_non_float_dtype_rejected(self):
        prog = build_workload("cg", n=16, iters=1)
        with pytest.raises(ValueError, match="float dtype"):
            make_feeds(prog, dtype=np.int32)

    def test_fp64_evaluation_under_x64(self, tmp_path):
        import jax
        prog = build_workload("cg", n=32, iters=2)
        feeds = make_feeds(prog, seed=1, dtype=np.float64)
        with jax.experimental.enable_x64():
            out = evaluate(prog, feeds)
            assert np.asarray(out["x2"]).dtype == np.float64
            # fp64 CG at n=32 is essentially exact: residual identity holds
            # far beyond fp32 precision
            A, b = feeds["A"], feeds["b"]
            r = np.asarray(out["r2"], np.float64)
            x = np.asarray(out["x2"], np.float64)
            np.testing.assert_allclose(r, b - A @ x, rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# registry + plan threading
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_builtins_registered(self):
        assert {"reference", "pallas"} <= set(list_backends())
        assert get_backend("reference").name == "reference"
        assert get_backend("pallas").name == "pallas"

    def test_unknown_backend_raises(self, tmp_path):
        with pytest.raises(KeyError, match="unknown execution backend"):
            get_backend("tpu-real")
        _, plan = _lowered(tmp_path, workload="cg", n=16, iters=1)
        with pytest.raises(KeyError, match="unknown execution backend"):
            plan.run(backend="tpu-real")

    def test_lower_backend_sets_default(self, tmp_path):
        traced = Session(cache_dir=tmp_path).trace(workload="power_iteration",
                                                   n=32, iters=2)
        designed = traced.analyze().codesign()
        plan = designed.lower(backend="pallas")
        assert plan.backend == "pallas"
        assert "execution backend : pallas" in plan.explain()
        feeds = make_feeds(traced.program, seed=0)
        got = plan.run(feeds)                 # defaults to pallas
        want = evaluate(traced.program, feeds)
        np.testing.assert_allclose(np.asarray(got["x2"]),
                                   np.asarray(want["x2"]),
                                   rtol=RTOL, atol=ATOL)

    def test_custom_backend_registers_and_runs(self, tmp_path):
        class ShoutingReference(ReferenceExecutor):
            name = "shouting-reference"

        register_backend(ShoutingReference)
        try:
            _, plan = _lowered(tmp_path, workload="cg", n=16, iters=1)
            got = plan.run(seed=4, backend="shouting-reference")
            want = plan.run(seed=4, backend="reference")
            for k in want:
                np.testing.assert_array_equal(np.asarray(got[k]),
                                              np.asarray(want[k]))
        finally:
            EXECUTOR_REGISTRY.pop("shouting-reference", None)

    def test_executor_is_abstract(self, tmp_path):
        _, plan = _lowered(tmp_path, workload="cg", n=16, iters=1)
        with pytest.raises(NotImplementedError):
            Executor().run(plan)

    def test_run_missing_feed_raises(self, tmp_path):
        traced, plan = _lowered(tmp_path, workload="cg", n=16, iters=1)
        feeds = make_feeds(traced.program, seed=0)
        feeds.pop("b")
        for backend in ("reference", "pallas"):
            with pytest.raises(KeyError, match="feeds missing leaf"):
                plan.run(feeds, backend=backend)


# ---------------------------------------------------------------------------
# group -> kernel-shape selection
# ---------------------------------------------------------------------------

class TestKernelSelection:
    def _kernels(self, workload, **params):
        prog = build_workload(workload, **params)
        graph = prog.to_graph()
        groups = build_groups(graph, graph.topo_order(), 64 << 20)
        return select_group_kernels(graph, groups, 64 << 20)

    def test_kernels_partition_the_groups(self, tmp_path):
        _, plan = _lowered(tmp_path, workload="cg", n=96, iters=2)
        groups = [tuple(g) for g in plan.codesigned.best.schedule.groups]
        assert [gk.ops for gk in plan.group_kernels] == groups
        for gk in plan.group_kernels:
            if gk.kind == "stream":
                flat = [o for p in gk.passes for o in p.ops]
                assert flat == list(gk.ops)       # passes partition group
                for p in gk.passes:
                    assert p.rows % p.tile_rows == 0

    def test_cg_in_pass_rhs_splits_into_two_passes(self):
        kernels = self._kernels("cg", n=128, iters=2)
        multi = [gk for gk in kernels
                 if gk.kind == "stream" and len(gk.passes) == 2]
        # p_{k+1} = axpy(...) immediately feeds A @ p_{k+1}: the vector
        # must materialize before it can sit resident for the matvec
        assert multi, [gk.describe() for gk in kernels]
        gk = multi[0]
        assert gk.passes[1].resident    # second pass holds the new vector

    def test_in_pass_scalar_consumer_splits_and_executes(self):
        # schedule.fusable never fuses a tiled op with the in-pass scalar
        # it reads, but select_group_kernels is public API and must stay
        # safe for hand-built groups: the pass splits where the scalar
        # must materialize, and the resulting kernels execute correctly
        import jax.numpy as jnp

        from repro.exec.pallas import _StreamCall
        p = Program("scal")
        x = p.input("x", (16,))
        y = p.input("y", (16,))
        d = p.dot(x, y, name="d")
        p.output(p.axpy(d, x, y, name="z"))
        graph = p.to_graph()
        kernels = select_group_kernels(graph, [["d", "z"]], 1 << 20)
        assert kernels[0].kind == "stream"
        assert [pss.ops for pss in kernels[0].passes] == [("d",), ("z",)]
        feeds = make_feeds(p, seed=0)
        env = {k: jnp.asarray(v) for k, v in feeds.items()}
        for sp in kernels[0].passes:
            env.update(_StreamCall(p, sp, needed={"d", "z"})(env))
        want = evaluate(p, feeds)
        np.testing.assert_allclose(np.asarray(env["z"]),
                                   np.asarray(want["z"]),
                                   rtol=RTOL, atol=ATOL)

    def test_jacobi_is_block_kernel(self):
        kernels = self._kernels("jacobi2d", n=32, sweeps=3)
        assert all(gk.kind == "block" for gk in kernels)

    def test_mttkrp_falls_back_with_reason(self):
        kernels = self._kernels("mttkrp", i=16, j=16, k=16, rank=4)
        assert all(gk.kind == "jnp" for gk in kernels)
        assert any("einsum" in gk.reason for gk in kernels)

    def test_gather_falls_back_irregular(self):
        p = Program("gath")
        x = p.input("x", (32, 8))
        idx = p.input("idx", (8,), init="indices")
        p.output(p.gather(x, idx, name="g"))
        graph = p.to_graph()
        kernels = select_group_kernels(
            graph, build_groups(graph, graph.topo_order(), 1 << 20), 1 << 20)
        assert kernels[0].kind == "jnp"
        assert "irregular" in kernels[0].reason

    def test_irregular_parity_through_fallback(self, tmp_path):
        p = Program("gath2")
        x = p.input("x", (32, 8))
        idx = p.input("idx", (8,), init="indices")
        g = p.gather(x, idx, name="g")
        p.output(p.mul(g, g, name="sq"))
        traced, plan = _lowered(tmp_path, program=p)
        feeds = make_feeds(p, seed=9)
        want = evaluate(p, feeds)
        got = plan.run(feeds, backend="pallas")
        np.testing.assert_array_equal(np.asarray(got["sq"]),
                                      np.asarray(want["sq"]))


# ---------------------------------------------------------------------------
# single-program executable: one dispatch, rolled loops, residency fusion
# ---------------------------------------------------------------------------

class TestSingleProgram:
    def test_exactly_one_dispatch_per_run(self, tmp_path):
        traced, plan = _lowered(tmp_path, workload="cg", n=96, iters=3)
        feeds = make_feeds(traced.program, seed=0)
        ex = get_backend("pallas").compile(plan)
        assert ex.stats == {"traces": 0, "dispatches": 0}
        for runs in (1, 2, 3):
            out = ex(feeds)
            assert ex.stats["dispatches"] == runs
        # one jit trace serves every same-dtype run: had any unit
        # dispatched on its own, re-running would re-enter Python
        assert ex.stats["traces"] == 1
        want = evaluate(traced.program, feeds)
        np.testing.assert_allclose(np.asarray(out["x3"]),
                                   np.asarray(want["x3"]),
                                   rtol=RTOL, atol=ATOL)

    def test_run_driver_uses_single_program(self, tmp_path):
        # CompiledPlan.run memoizes the compiled executable per plan: two
        # run() calls must share one executable and re-dispatch it
        traced, plan = _lowered(tmp_path, workload="power_iteration",
                                n=64, iters=3)
        feeds = make_feeds(traced.program, seed=1)
        plan.run(feeds, backend="pallas")
        plan.run(feeds, backend="pallas")
        backend = get_backend("pallas")
        entry = backend._compiled.get(id(plan))
        assert entry is not None
        ex = entry[1]
        assert ex.stats["dispatches"] == 2 and ex.stats["traces"] == 1

    @pytest.mark.parametrize("workload,params,rolls", [
        ("cg", dict(n=96, iters=4), True),
        ("bicgstab", dict(n=96, iters=4), True),   # phase-shifted x update
        ("jacobi2d", dict(n=32, sweeps=4), True),
        ("power_iteration", dict(n=96, iters=4), True),
        ("gmres", dict(n=96, restart=4), False),   # growing Arnoldi bodies
        ("mttkrp", dict(i=16, j=16, k=16, rank=4), False),  # no loop at all
    ], ids=lambda v: v if isinstance(v, str) else "")
    def test_rolled_loop_detection(self, workload, params, rolls, tmp_path):
        traced, plan = _lowered(tmp_path, workload=workload, **params)
        ep = plan.exec_plan
        assert ep is not None
        if rolls:
            assert ep.roll is not None and ep.roll.n_iters >= 2
        else:
            assert ep.roll is None
        # parity is preserved whichever path the executable takes
        feeds = make_feeds(traced.program, seed=5)
        want = evaluate(traced.program, feeds)
        got = plan.run(feeds, backend="pallas")
        for k in want:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(want[k]),
                                       rtol=RTOL, atol=ATOL, err_msg=k)

    def test_rolled_compile_time_is_iteration_free(self, tmp_path):
        # the acceptance bar: tracing cg at iters=64 must cost at most 2x
        # the iters=4 trace — the rolled body is traced once either way.
        # best-of-2 per side keeps a loaded CI runner's one-off stall from
        # flaking a ratio whose real value is ~1x
        sess = Session(cache_dir=tmp_path)

        def compile_time(iters):
            designed = sess.trace(workload="cg", n=64,
                                  iters=iters).codesign()
            plan = designed.lower(backend="pallas")
            feeds = make_feeds(designed.trace.program, seed=0)
            best = float("inf")
            for _ in range(2):
                t0 = time.perf_counter()
                ex = get_backend("pallas").compile(plan)
                ex(feeds)                          # first run = the trace
                best = min(best, time.perf_counter() - t0)
            return best, plan.exec_plan

        t4, ep4 = compile_time(4)
        t64, ep64 = compile_time(64)
        # structural guarantee first: the trace covers prologue + ONE
        # template body + epilogue, independent of the iteration count
        assert ep64.roll is not None and ep64.roll.n_iters >= 60
        traced_units = (ep64.roll.first + ep64.roll.per_iter
                        + len(ep64.units) - ep64.roll.stop)
        assert traced_units <= len(ep4.units)
        assert t64 <= 2.0 * t4, (t4, t64)

    def test_residency_fusion_shrinks_units(self, tmp_path):
        _, plan = _lowered(tmp_path, workload="cg", n=96, iters=3)
        ep = plan.exec_plan
        assert len(ep.units) < ep.n_prefuse
        # fused units absorb the scalar-glue groups: some unit carries ops
        # from more than one fusion group
        assert any(len(u.groups) > 1 for u in ep.units)
        assert "fused from" in ep.describe()

    def test_fusion_absorbs_eager_scalar_glue(self):
        # [tiled] + [scalar-only jnp] + [tiled-reading-that-scalar] must
        # fuse into ONE pass: the scalar's inputs are tile-invariant, so
        # it is recomputed per tile instead of forcing a pass break
        p = Program("glue")
        a = p.input("a", (16,))
        b = p.input("b", (16,))
        s = p.input("s", ())
        t1 = p.mul(a, b, name="t1")
        ns = p.neg(s, name="ns")
        p.output(p.axpy(ns, a, t1, name="t2"))
        graph = p.to_graph()
        kernels = select_group_kernels(graph, [["t1"], ["ns"], ["t2"]],
                                       1 << 20)
        units = fuse_units(graph, flatten_units(kernels), 1 << 20)
        assert len(units) == 1 and units[0].kind == "stream"
        assert units[0].ops == ("t1", "ns", "t2")
        # ...and a reduction-derived scalar still forces the break
        d = Program("late")
        x = d.input("x", (16,))
        y = d.input("y", (16,))
        dd = d.dot(x, y, name="dd")
        d.output(d.axpy(dd, x, y, name="z"))
        graph2 = d.to_graph()
        k2 = select_group_kernels(graph2, [["dd"], ["z"]], 1 << 20)
        u2 = fuse_units(graph2, flatten_units(k2), 1 << 20)
        assert len(u2) == 2

    def test_detect_rolled_loop_direct(self):
        # hand-built elementwise chain: per-op units, bodies recorded
        p = Program("chain")
        x = p.input("x0", (8,))
        c = p.input("c", (8,))
        for k in range(5):
            with p.iteration():
                x = p.mul(x, c, name=f"x{k + 1}")
        p.output(x)
        graph = p.to_graph()
        groups = [[f"x{k + 1}"] for k in range(5)]
        units = flatten_units(select_group_kernels(graph, groups, 1 << 20))
        roll = detect_rolled_loop(p, units)
        # iteration 0 reads the leaf x0, so it cannot match; 1..4 roll
        assert roll is not None
        assert (roll.first, roll.per_iter, roll.n_iters) == (1, 1, 4)
        [slot] = roll.slots
        assert (slot.read, slot.update, slot.final) == ("x1", "x2", "x5")
        # bodies that carry nothing / unrecorded bodies detect as None
        q = Program("noloop")
        a = q.input("a", (8,))
        q.output(q.mul(a, a, name="sq"))
        g2 = q.to_graph()
        u2 = flatten_units(select_group_kernels(g2, [["sq"]], 1 << 20))
        assert detect_rolled_loop(q, u2) is None

    def test_explain_and_report_surface_exec_plan(self, tmp_path):
        _, plan = _lowered(tmp_path, workload="cg", n=96, iters=4)
        text = plan.explain()
        assert "execution plan" in text and "rolled" in text
        rep = plan.report()
        assert rep["exec_units"] == len(plan.exec_plan.units)
        assert rep["exec_fused_from"] == plan.exec_plan.n_prefuse
        assert rep["rolled_iters"] == plan.exec_plan.roll.n_iters

    def test_donation_covers_all_leaves_and_spares_caller_buffers(
            self, tmp_path, monkeypatch):
        import repro.exec.pallas as pal
        traced, plan = _lowered(tmp_path, workload="cg", n=32, iters=2)
        ex = get_backend("pallas").compile(plan)
        # every leaf dies inside the program (outputs are op-produced)
        assert ex.donate_argnums == tuple(range(len(ex.leaf_names)))
        # donation stays off on CPU (XLA ignores it there and warns)
        monkeypatch.setattr(pal, "_BACKEND_PROBE", "cpu")
        monkeypatch.delenv("CELLO_PALLAS_DONATE", raising=False)
        assert pal.use_donation() is False
        monkeypatch.setattr(pal, "_BACKEND_PROBE", "tpu")
        assert pal.use_donation() is True
        monkeypatch.setenv("CELLO_PALLAS_DONATE", "0")
        assert pal.use_donation() is False

    def test_jnp_call_jits_lazily(self):
        from repro.exec.pallas import _JnpCall
        p = Program("scalars")
        a = p.input("a", ())
        b = p.input("b", ())
        p.output(p.mul(a, b, name="m"))
        call = _JnpCall(p, ["m"], needed={"m"})
        assert call._fn is None           # compile() must not build jits
        import jax.numpy as jnp
        env = {"a": jnp.float32(2.0), "b": jnp.float32(3.0)}
        out = call(env)                   # standalone drive jits on demand
        assert call._fn is not None
        assert float(out["m"]) == 6.0
        # apply() inlines into an outer trace without touching the jit
        call2 = _JnpCall(p, ["m"], needed={"m"})
        assert float(call2.apply(env)["m"]) == 6.0
        assert call2._fn is None

    def test_backend_probe_cached(self, monkeypatch):
        import repro.exec.pallas as pal
        monkeypatch.setattr(pal, "_BACKEND_PROBE", None)
        first = pal._default_backend()
        # once probed, the cached value is reused (no jax import per call)
        monkeypatch.setattr(pal, "_BACKEND_PROBE", "fake-backend")
        assert pal._default_backend() == "fake-backend"
        assert first in ("cpu", "gpu", "tpu")
        monkeypatch.setenv("CELLO_PALLAS_INTERPRET", "1")
        assert pal.use_interpret() is True
        monkeypatch.setenv("CELLO_PALLAS_INTERPRET", "0")
        assert pal.use_interpret() is False

    def test_perunit_backend_matches_single_program(self, tmp_path):
        traced, plan = _lowered(tmp_path, workload="bicgstab", n=64,
                                iters=2)
        feeds = make_feeds(traced.program, seed=3)
        single = plan.run(feeds, backend="pallas")
        perunit = plan.run(feeds, backend="pallas-perunit")
        for k in single:
            np.testing.assert_allclose(np.asarray(perunit[k]),
                                       np.asarray(single[k]),
                                       rtol=RTOL, atol=ATOL, err_msg=k)


# ---------------------------------------------------------------------------
# VMEM budget edge: tile selection must degrade, never corrupt
# ---------------------------------------------------------------------------

class TestTileBudget:
    def test_resident_over_budget_degrades_to_finest_tile(self):
        # resident operands already exceed the explicit budget: stream at
        # the finest granularity rather than blowing the region (or
        # producing a zero/negative tile)
        assert _pick_tile_rows(1024, per_row_bytes=8192,
                               resident_bytes=2 << 20,
                               explicit_bytes=1 << 20) == 1
        assert _pick_tile_rows(96, per_row_bytes=1 << 30,
                               resident_bytes=0,
                               explicit_bytes=1 << 20) == 1

    def test_budget_boundary_is_inclusive(self):
        # budget exactly equal to the working set of a candidate: taken
        rows, per_row = 1024, 1024
        assert _pick_tile_rows(rows, per_row, 0, 256 * per_row) == 256
        assert _pick_tile_rows(rows, per_row, 0, 256 * per_row - 1) == 128
        # resident bytes eat the budget down to the boundary
        assert _pick_tile_rows(rows, per_row, 256 * per_row,
                               512 * per_row) == 256

    def test_prime_row_count_still_positive(self):
        assert _pick_tile_rows(97, per_row_bytes=1 << 30,
                               resident_bytes=1 << 30,
                               explicit_bytes=0) == 1

    def test_tiles_always_positive_divisors(self):
        for rows in (1, 2, 50, 96, 97, 1024):
            for explicit in (0, 1 << 10, 1 << 20):
                t = _pick_tile_rows(rows, 4096, 1 << 22, explicit)
                assert t >= 1 and rows % t == 0

    def test_zero_explicit_budget_plan_still_streams_and_matches(
            self, tmp_path):
        # a plan whose split went all-implicit must still lower to valid
        # stream kernels (floor budget) and run correctly
        prog = build_workload("cg", n=32, iters=2)
        graph = prog.to_graph()
        groups = build_groups(graph, graph.topo_order(), 64 << 20)
        kernels = select_group_kernels(graph, groups, 0)
        for gk in kernels:
            for sp in gk.passes:
                assert sp.tile_rows >= 1
                assert sp.rows % sp.tile_rows == 0
