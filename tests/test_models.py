"""Per-architecture smoke tests (reduced configs) + model-level invariants."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.core.policy import default_plan
from repro.models import (decode_step, forward, init_cache, init_params,
                          param_pspecs, period_structure)
from repro.models.attention import (chunked_flash_attention, naive_attention)
from repro.models.moe import apply_moe, init_moe_params

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=32):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    kwargs = {}
    if cfg.family == "audio":
        kwargs["frames"] = jax.random.normal(KEY, (B, S, cfg.d_model),
                                             jnp.bfloat16)
    if cfg.family == "vlm":
        kwargs["img"] = jax.random.normal(KEY, (B, cfg.vision_seq,
                                                cfg.d_model), jnp.bfloat16)
    return tokens, kwargs


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_forward_and_decode(arch):
    """Reduced config: one forward (and decode step) on CPU — output shapes
    correct and finite."""
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    plan = default_plan(cfg, seq=32)
    B, S = 2, 32
    tokens, kwargs = make_batch(cfg, B, S)
    logits, caches = forward(params, cfg, plan, tokens, **kwargs)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    if not cfg.encoder_only:
        cache = init_cache(cfg, B, 64)
        lg, cache2 = decode_step(params, cache, cfg, plan, tokens[:, :1],
                                 jnp.int32(0))
        assert lg.shape == (B, 1, cfg.padded_vocab)
        assert bool(jnp.isfinite(lg).all())
        # cache structure preserved
        assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", list_archs())
def test_arch_param_specs_cover_tree(arch):
    cfg = get_config(arch).reduced()
    params = jax.eval_shape(lambda k: init_params(k, cfg), KEY)
    specs = param_pspecs(cfg)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert len(s) <= len(p.shape), (s, p.shape)


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "llama-3.2-vision-11b",
                                  "granite-3-8b"])
def test_scan_unroll_parity(arch):
    """The scan (production) and unrolled (dry-run) paths agree to bf16
    accumulation noise."""
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    plan = default_plan(cfg, seq=32)
    tokens, kwargs = make_batch(cfg)
    l1, _ = forward(params, cfg, plan, tokens, unroll=True, **kwargs)
    l2, _ = forward(params, cfg, plan, tokens, unroll=False, **kwargs)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=0.08, rtol=0.05)


def test_moe_scan_unroll_parity_loose():
    """MoE: top-k routing flips on near-tie logits under bf16 noise, so
    compare with a mismatch-budget instead of elementwise tolerance."""
    cfg = get_config("moonshot-v1-16b-a3b").reduced()
    params = init_params(KEY, cfg)
    plan = default_plan(cfg, seq=32)
    tokens, _ = make_batch(cfg)
    l1, _ = forward(params, cfg, plan, tokens, unroll=True)
    l2, _ = forward(params, cfg, plan, tokens, unroll=False)
    close = np.isclose(np.asarray(l1), np.asarray(l2), atol=0.08, rtol=0.05)
    assert close.mean() > 0.95


def test_prefill_then_decode_consistency():
    """Greedy next-token from full forward == from incremental decode."""
    cfg = get_config("granite-3-8b").reduced()
    params = init_params(KEY, cfg)
    plan = default_plan(cfg, seq=16)
    B, S = 1, 8
    tokens, _ = make_batch(cfg, B, S)
    full_logits, _ = forward(params, cfg, plan, tokens, mode="prefill")
    cache = init_cache(cfg, B, 32)
    lg = None
    for t in range(S):
        lg, cache = decode_step(params, cache, cfg, plan,
                                tokens[:, t:t + 1], jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(jnp.argmax(full_logits[:, -1], -1)),
        np.asarray(jnp.argmax(lg[:, -1], -1)))


def test_window_ring_buffer_decode():
    """Sliding-window arch decodes past the window without error and the
    attention only sees in-window entries."""
    cfg = get_config("h2o-danube-1.8b").reduced()     # window=32 reduced
    params = init_params(KEY, cfg)
    plan = default_plan(cfg, seq=16)
    B = 1
    cache = init_cache(cfg, B, cfg.window)            # ring of size window
    tok = jnp.zeros((B, 1), jnp.int32)
    for t in range(cfg.window + 8):                   # wrap the ring
        lg, cache = decode_step(params, cache, cfg, plan, tok, jnp.int32(t))
        assert bool(jnp.isfinite(lg).all()), t


def test_chunked_flash_equals_naive():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((2, 48, 8, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 48, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 48, 2, 32)), jnp.float32)
    for causal, win in [(True, None), (True, 16), (False, None)]:
        a = chunked_flash_attention(q, k, v, causal=causal, window=win,
                                    kv_block=16)
        b = naive_attention(q, k, v, causal=causal, window=win)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-5, rtol=1e-4)


def test_moe_matches_loop_reference():
    """Grouped-dispatch MoE == per-token loop reference (at high capacity,
    bf16 dispatch-buffer tolerance)."""
    D, F, E, K, T = 32, 16, 8, 2, 64
    params = init_moe_params(KEY, D, F, E, "swiglu", jnp.float32)
    x = jax.random.normal(KEY, (T, D), jnp.float32)
    got = np.asarray(apply_moe(params, x, top_k=K, activation="swiglu",
                               capacity_factor=8.0))
    logits = np.asarray(x @ params["w_router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    want = np.zeros((T, D), np.float32)
    for t in range(T):
        topk = np.argsort(probs[t])[::-1][:K]
        g = probs[t][topk]
        g = g / g.sum()
        for gi, e in zip(g, topk):
            xe = np.asarray(x[t])
            u = xe @ np.asarray(params["w_up"][e])
            ga = xe @ np.asarray(params["w_gate"][e])
            h = (ga / (1 + np.exp(-ga))) * u
            want[t] += gi * (h @ np.asarray(params["w_down"][e]))
    np.testing.assert_allclose(got, want, atol=0.02, rtol=0.05)


def test_moe_gate_mass_and_capacity():
    """Router gates are normalised; dropped tokens produce zero output."""
    D, F, E, k = 32, 16, 8, 2
    params = init_moe_params(KEY, D, F, E, "swiglu", jnp.float32)
    x = jax.random.normal(KEY, (64, D), jnp.float32)
    out = apply_moe(params, x, top_k=k, activation="swiglu")
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    # zero input -> zero output (no bias paths)
    out0 = apply_moe(params, jnp.zeros((8, D)), top_k=k, activation="swiglu")
    np.testing.assert_allclose(np.asarray(out0), 0.0, atol=1e-5)


def test_period_structure_counts():
    cases = {
        "recurrentgemma-2b": (3, 8, 2),     # 26 = 8*3 + 2
        "llama-3.2-vision-11b": (5, 8, 0),  # 40 = 8*5
        "granite-3-8b": (1, 40, 0),
        "rwkv6-7b": (1, 32, 0),
    }
    for arch, (plen, n, rest) in cases.items():
        cfg = get_config(arch)
        period, n_periods, rest_kinds = period_structure(cfg)
        assert (len(period), n_periods, len(rest_kinds)) == (plen, n, rest)


def test_supported_shapes_policy():
    assert "long_500k" in get_config("rwkv6-7b").supported_shapes()
    assert "long_500k" in get_config("recurrentgemma-2b").supported_shapes()
    assert "long_500k" in get_config("h2o-danube-1.8b").supported_shapes()
    assert "long_500k" not in get_config("gemma-7b").supported_shapes()
    assert "decode_32k" not in get_config("hubert-xlarge").supported_shapes()


def test_total_params_scale():
    """Sanity: reported sizes are in the ballpark of the names."""
    approx = {
        "gemma-7b": 8.5e9, "granite-3-8b": 8.2e9, "minitron-8b": 8.4e9,
        "rwkv6-7b": 7.6e9, "h2o-danube-1.8b": 1.8e9,
        # assigned config says 48L (hf Moonlight is 27L) → ~27B total as
        # configured; its *active* params still land at ~3B ("a3b") ✓
        "moonshot-v1-16b-a3b": 27e9, "granite-moe-1b-a400m": 1.3e9,
        "recurrentgemma-2b": 2.7e9, "llama-3.2-vision-11b": 10e9,
        "hubert-xlarge": 1e9,
    }
    for arch, expect in approx.items():
        n = get_config(arch).total_params()
        assert 0.5 * expect < n < 1.6 * expect, (arch, n, expect)
