"""Tests for the HPC workload frontend: expression-DAG builder, workload
library, numerical reference executor, and the Session integration.

The numerical "goldens" here are mathematical identities (CG residual
identity r_k = b - A x_k, Jacobi sweep formula, MTTKRP vs raw einsum, unit
norms) rather than stored float values — they hold on any platform and any
JAX version, and they check the *DAG semantics*, not one run's bits.  The
plan-vs-reference checks ARE bitwise: the scheduled order replays the same
pure ops, so outputs must be identical.
"""
import numpy as np
import pytest

from repro.api import Session
from repro.core.graph import OpGraph, TensorKind
from repro.frontends import (Program, build_workload, evaluate,
                             list_workloads, make_feeds)
from repro.frontends.reference import execute_plan


@pytest.fixture(autouse=True)
def _hermetic_cache_env(monkeypatch):
    monkeypatch.delenv("CELLO_NO_CACHE", raising=False)
    monkeypatch.delenv("CELLO_CACHE_DIR", raising=False)


# ---------------------------------------------------------------------------
# expression builder -> OpGraph lowering
# ---------------------------------------------------------------------------

class TestExprBuilder:
    def test_lowering_kinds_shapes_flops(self):
        p = Program("t")
        A = p.operator("A", (8, 4))
        x = p.input("x", (4,))
        y = p.matmul(A, x, name="y")
        z = p.norm(y, name="z")
        p.output(y, z)
        g = p.to_graph()
        g.validate()
        assert g.tensors["A"].kind == TensorKind.WEIGHT
        assert g.tensors["x"].kind == TensorKind.INPUT
        assert g.tensors["y"].kind == TensorKind.OUTPUT
        assert g.tensors["y"].shape == (8,)
        assert g.tensors["z"].shape == ()
        assert g.tensors["A"].dtype_bytes == 8          # fp64 model default
        assert g.ops["y"].flops == 2 * 8 * 4            # einsum-derived
        assert g.ops["z"].flops == 2 * 8 + 1            # explicit override

    def test_operator_sugar_and_scalar_broadcast(self):
        p = Program("t")
        x = p.input("x", (6,))
        y = p.input("y", (6,))
        s = p.dot(x, y)
        v = (x + y) * s - y / 2.0
        p.output(v)
        g = p.to_graph()
        assert g.tensors[v.name].shape == (6,)
        # python scalar operand became a rank-0 const leaf
        consts = [nd for nd in p.leaves() if nd.param("init") == "const"]
        assert len(consts) == 1 and consts[0].param("value") == 2.0

    def test_reflected_scalar_operators(self):
        p = Program("t")
        x = p.input("x", (3,))
        v = 1.0 + (2.0 - x) * 3.0 / (1.0 / -x)
        p.output(v)
        feeds = make_feeds(p, seed=0)
        out = np.asarray(evaluate(p, feeds)[v.name])
        xv = feeds["x"]
        np.testing.assert_allclose(out, 1.0 + (2.0 - xv) * 3.0 / (1.0 / -xv),
                                   rtol=1e-5)
        # const leaves materialize at their declared (broadcast) shape
        consts = [nd for nd in p.leaves() if nd.param("init") == "const"]
        assert all(feeds[nd.name].shape == nd.shape for nd in consts)

    def test_shape_mismatch_raises(self):
        p = Program("t")
        x = p.input("x", (6,))
        y = p.input("y", (5,))
        with pytest.raises(ValueError, match="broadcast"):
            p.add(x, y)
        with pytest.raises(ValueError, match="rank"):
            p.matmul(p.operator("T3", (2, 2, 2)), x)

    def test_gather_is_irregular_and_excluded_from_pins(self):
        p = Program("t")
        tbl = p.operator("tbl", (64, 8))
        idx = p.input("idx", (16,), init="indices", high=64)
        got = p.gather(tbl, idx, name="got")
        out = p.add(got, got, name="out")
        p.output(out)
        g = p.to_graph()
        assert g.ops["got"].irregular
        from repro.core.reuse import analyze
        an = analyze(g)
        assert an.tensors["tbl"].irregular
        assert "tbl" not in {c.name for c in an.ranked_pin_candidates()}

    def test_duplicate_names_and_leaf_output_raise(self):
        p = Program("t")
        x = p.input("x", (4,))
        with pytest.raises(ValueError, match="duplicate"):
            p.input("x", (4,))
        with pytest.raises(ValueError, match="leaf"):
            p.output(x)
        with pytest.raises(ValueError, match="no outputs"):
            Program("empty").to_graph()

    def test_fingerprint_tracks_content(self):
        a = build_workload("cg", n=32, iters=2)
        b = build_workload("cg", n=32, iters=2)
        c = build_workload("cg", n=32, iters=3)
        assert a.fingerprint() == b.fingerprint()
        assert a.fingerprint() != c.fingerprint()


# ---------------------------------------------------------------------------
# workload library
# ---------------------------------------------------------------------------

class TestWorkloadLibrary:
    @pytest.mark.parametrize("name", list_workloads())
    def test_builds_and_validates(self, name):
        params = ({"i": 8, "j": 8, "k": 8, "rank": 2}
                  if name == "mttkrp" else {"n": 16})
        g = build_workload(name, **params).to_graph()
        g.validate()
        assert g.ops and any(t.kind == TensorKind.OUTPUT
                             for t in g.tensors.values())

    def test_cg_cross_iteration_reuse_is_in_the_dag(self):
        g = build_workload("cg", n=32, iters=4).to_graph()
        # A feeds the initial residual matvec plus one matvec per iteration
        assert len(g.consumers("A")) == 5
        # direction vectors have multiple consumers at different distances
        assert len(g.consumers("p1")) >= 3

    def test_unknown_workload_and_params_raise(self):
        with pytest.raises(KeyError, match="unknown HPC workload"):
            build_workload("lattice-qcd")
        with pytest.raises(TypeError, match="unexpected params"):
            build_workload("cg", n=16, banana=1)

    @pytest.mark.parametrize("name", list_workloads())
    def test_non_positive_params_rejected_up_front(self, name):
        params = ({"i": 8, "j": 8, "k": 0, "rank": 2}
                  if name == "mttkrp" else
                  {"n": 16, {"gmres": "restart", "jacobi2d": "sweeps",
                             "jacobi_sparse": "sweeps"}
                   .get(name, "iters"): 0})
        with pytest.raises(ValueError, match="positive int"):
            build_workload(name, **params)


# ---------------------------------------------------------------------------
# numerical reference executor (mathematical identities as goldens)
# ---------------------------------------------------------------------------

class TestReferenceNumerics:
    def test_cg_residual_identity_and_convergence(self):
        prog = build_workload("cg", n=48, iters=5)
        feeds = make_feeds(prog, seed=1)
        vals = evaluate(prog, feeds, return_all=True)
        A, b = feeds["A"], feeds["b"]
        x5, r5 = np.asarray(vals["x5"]), np.asarray(vals["r5"])
        np.testing.assert_allclose(r5, b - A @ x5, atol=1e-4)
        norms = [float(np.linalg.norm(np.asarray(vals[f"r{k}"])))
                 for k in range(6)]
        assert norms[-1] < 0.1 * norms[0]        # SPD CG converges

    def test_bicgstab_residual_identity(self):
        prog = build_workload("bicgstab", n=40, iters=3)
        feeds = make_feeds(prog, seed=2)
        out = evaluate(prog, feeds)
        A, b = feeds["A"], feeds["b"]
        x, r = np.asarray(out["x3"]), np.asarray(out["r3"])
        np.testing.assert_allclose(r, b - A @ x, atol=1e-4)

    def test_gmres_builds_orthonormal_krylov_basis(self):
        prog = build_workload("gmres", n=32, restart=5)
        vals = evaluate(prog, make_feeds(prog, seed=0), return_all=True)
        V = np.stack([np.asarray(vals[f"v{j}"]) for j in range(6)])
        gram = V @ V.T
        np.testing.assert_allclose(gram, np.eye(6), atol=1e-3)

    def test_jacobi2d_matches_manual_sweep(self):
        prog = build_workload("jacobi2d", n=12, sweeps=3)
        feeds = make_feeds(prog, seed=4)
        out = np.asarray(evaluate(prog, feeds)["u3"])
        u, f = feeds["u0"], feeds["f"]
        for _ in range(3):
            u = 0.25 * (np.roll(u, 1, 0) + np.roll(u, -1, 0)
                        + np.roll(u, 1, 1) + np.roll(u, -1, 1) + f)
        np.testing.assert_allclose(out, u, atol=1e-5)

    def test_power_iteration_normalizes(self):
        prog = build_workload("power_iteration", n=24, iters=6)
        out = evaluate(prog, make_feeds(prog, seed=5))
        x = np.asarray(out["x6"])
        np.testing.assert_allclose(np.linalg.norm(x), 1.0, atol=1e-5)

    def test_mttkrp_matches_numpy_einsum(self):
        prog = build_workload("mttkrp", i=6, j=5, k=4, rank=3)
        feeds = make_feeds(prog, seed=6)
        out = evaluate(prog, feeds)
        X, B, C = feeds["X"], feeds["B"], feeds["C"]
        m1 = np.einsum("ijk,jr,kr->ir", X, B, C)
        np.testing.assert_allclose(np.asarray(out["M1"]), m1, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(out["M2"]),
                                   np.einsum("ijk,ir,kr->jr", X, m1, C),
                                   rtol=1e-4)

    def test_gather_reference(self):
        p = Program("t")
        tbl = p.operator("tbl", (10, 3))
        idx = p.input("idx", (4,), init="indices", high=10)
        p.output(p.gather(tbl, idx, name="g"))
        feeds = make_feeds(p, seed=7)
        out = np.asarray(evaluate(p, feeds)["g"])
        np.testing.assert_array_equal(out, feeds["tbl"][feeds["idx"]])

    def test_gather_index_leaf_inherits_row_range(self):
        # an index leaf without high= must draw from the gathered tensor's
        # rows, not its own length — else jnp.take silently clamps
        p = Program("t")
        tbl = p.operator("tbl", (8, 3))
        idx = p.input("idx", (32,), init="indices")
        p.output(p.gather(tbl, idx, name="g"))
        feeds = make_feeds(p, seed=11)
        assert feeds["idx"].max() < 8
        out = np.asarray(evaluate(p, feeds)["g"])
        np.testing.assert_array_equal(out, feeds["tbl"][feeds["idx"]])
        # a later gather over a smaller tensor can't silently clamp
        small = p.operator("small", (4, 3))
        with pytest.raises(ValueError, match="rows"):
            p.gather(small, idx)

    def test_non_topological_order_rejected(self):
        prog = build_workload("cg", n=8, iters=1)
        ops = prog.schedulable_order()
        with pytest.raises(ValueError, match="not topological"):
            execute_plan(prog, order=list(reversed(ops)))
        with pytest.raises(ValueError, match="permutation"):
            execute_plan(prog, order=ops[:-1])

    def test_schedulable_order_is_public_and_leaf_free(self):
        prog = build_workload("cg", n=8, iters=1)
        order = prog.schedulable_order()
        assert order == [n for n in prog._order
                         if not prog.nodes[n].is_leaf]
        assert not any(prog.nodes[n].is_leaf for n in order)

    def test_iteration_bodies_recorded(self):
        prog = build_workload("cg", n=8, iters=3)
        bodies = prog.iteration_bodies()
        assert len(bodies) == 3
        # each CG iteration registers exactly its 9 nodes, in build order
        assert all(len(b) == 9 for b in bodies)
        assert bodies[0][0] == "Ap0" and bodies[2][-1] == "p3"
        # returned lists are copies: mutating them cannot corrupt the
        # program's record
        bodies[0].clear()
        assert len(prog.iteration_bodies()[0]) == 9
        # bodies are metadata only — the DAG is identical to an
        # unannotated build
        assert prog.schedulable_order() == \
            build_workload("cg", n=8, iters=3).schedulable_order()

    def test_iteration_context_does_not_nest(self):
        p = Program("nest")
        with pytest.raises(ValueError, match="nest"):
            with p.iteration():
                with p.iteration():
                    pass  # pragma: no cover
        # the failed inner context must not wedge recording
        with p.iteration():
            p.input("x", (4,))
        assert [len(b) for b in p.iteration_bodies()] == [0, 1]


# ---------------------------------------------------------------------------
# Session integration: trace(workload=...) / from_graph / lower / run
# ---------------------------------------------------------------------------

class TestSessionHpc:
    def test_stage_pipeline_end_to_end_matches_reference(self, tmp_path):
        sess = Session(cache_dir=tmp_path)
        traced = sess.trace(workload="cg", n=96, iters=4)
        plan = traced.analyze().codesign().lower()
        feeds = make_feeds(traced.program, seed=3)
        got = plan.run(feeds)
        want = evaluate(traced.program, feeds)
        assert sorted(got) == sorted(want)
        for k in want:           # same pure ops, scheduled order: bitwise
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(want[k]))

    def test_paper_scale_cg_pins_operator_and_beats_implicit(self, tmp_path):
        # the acceptance shape: A is exactly the 128 MiB on-chip capacity
        res = (Session(cache_dir=tmp_path)
               .trace(workload="cg", n=4096, iters=4).analyze().codesign())
        pins = res.best.schedule.pins
        assert "A" in pins
        assert any(t.startswith("r") for t in pins)
        assert res.speedup("seq-implicit") > 2.0
        plan = res.lower()
        text = plan.explain()
        assert "A[g" in text and "execution backend : reference" in text
        assert "pallas-stream" in text      # per-group kernel selection

    def test_gmres_pins_basis_vectors(self, tmp_path):
        res = (Session(cache_dir=tmp_path)
               .trace(workload="gmres", n=4096, restart=4).codesign())
        pins = set(res.best.schedule.pins)
        assert "A" in pins and any(t.startswith("w") for t in pins)
        assert res.speedup("seq-implicit") > 2.0

    def test_trace_memoized_and_cache_hits(self, tmp_path):
        sess = Session(cache_dir=tmp_path)
        t1 = sess.trace(workload="jacobi2d", n=64, sweeps=2)
        assert sess.trace(workload="jacobi2d", n=64, sweeps=2) is t1
        fresh = t1.codesign()
        assert not fresh.from_cache
        again = Session(cache_dir=tmp_path).trace(
            workload="jacobi2d", n=64, sweeps=2).codesign()
        assert again.from_cache
        assert again.best.metrics == fresh.best.metrics
        assert again.best.schedule.pins == fresh.best.schedule.pins

    def test_workload_params_change_cache_key(self, tmp_path):
        sess = Session(cache_dir=tmp_path)
        sess.trace(workload="power_iteration", n=64, iters=2).codesign()
        other = sess.trace(workload="power_iteration", n=64,
                           iters=3).codesign()
        assert not other.from_cache

    def test_bad_trace_kwargs(self, tmp_path):
        sess = Session(cache_dir=tmp_path)
        with pytest.raises(ValueError, match="workload builder params"):
            sess.trace(workload="cg", batch=4, n=16)
        with pytest.raises(ValueError, match="not combine workload"):
            sess.trace(phase="decode", workload="cg", n=16)
        with pytest.raises(ValueError, match="not combine workload"):
            sess.trace(phase="train", workload="cg", n=16)
        with pytest.raises(TypeError, match="unexpected trace"):
            sess.trace(phase="train", n=16)
        with pytest.raises(ValueError, match="no arch config"):
            sess.trace(phase="train")
        with pytest.raises(KeyError, match="unknown HPC workload"):
            sess.trace(workload="sudoku", n=9)

    def test_from_graph_expr_program_and_opgraph(self, tmp_path):
        p = Program("chain")
        A = p.operator("A", (64, 64))
        x = p.input("x", (64,))
        y = p.matmul(A, A @ x, name="y")
        traced = Session.from_graph(y, cache_dir=tmp_path)
        assert traced.arch == "hpc:chain" and p.outputs == ["y"]
        plan = traced.codesign().lower()
        out = plan.run(seed=1)
        feeds = make_feeds(p, seed=1)
        np.testing.assert_allclose(
            np.asarray(out["y"]),
            feeds["A"] @ (feeds["A"] @ feeds["x"]), rtol=1e-4)
        # raw OpGraph: analyzable/lowerable but not runnable
        g = OpGraph("raw")
        g.tensor("a", (16, 16), kind=TensorKind.INPUT)
        g.tensor("w", (16, 16), kind=TensorKind.WEIGHT)
        g.einsum("mm", "mk,kn->mn", ["a", "w"], "out",
                 out_kind=TensorKind.OUTPUT)
        traced2 = Session.from_graph(g, cache_dir=tmp_path)
        plan2 = traced2.codesign().lower()
        with pytest.raises(ValueError, match="frontend-traced"):
            plan2.run()
        with pytest.raises(TypeError, match="from_graph"):
            Session.from_graph(42)

    def test_frontend_plan_has_no_llm_stack(self, tmp_path):
        designed = (Session(cache_dir=tmp_path)
                    .trace(workload="cg", n=32, iters=1).codesign())
        with pytest.raises(ValueError, match="no seq"):
            designed.lower(seq=8192)
        plan = designed.lower()
        with pytest.raises(ValueError, match="serving"):
            plan.serve()
        with pytest.raises(ValueError, match="training"):
            plan.train(data_iter=None, n_steps=1)
        rep = plan.report()
        assert rep["arch"] == "hpc:cg"
        assert rep["speedup_vs_implicit"] > 0
