from .pipeline import DataConfig, SyntheticLMData, markov_transition

__all__ = ["DataConfig", "SyntheticLMData", "markov_transition"]
