"""Deterministic, sharded, checkpointable synthetic data pipeline.

Every batch is a pure function of ``(seed, step, shard)`` via counter-based
RNG, so:

* restart at step k reproduces exactly the stream a continuous run saw
  (checkpoint stores only the integer ``step``),
* each data shard (host) draws a disjoint slice with no coordination,
* elastic rescale re-partitions cleanly: shard assignment depends only on
  ``(step, shard_index, n_shards)``.

Two generators:
* ``uniform``  — i.i.d. tokens (for shape/throughput benchmarks),
* ``markov``   — tokens from a fixed random first-order Markov chain; its
  conditional entropy is well below log(V), so a model trained on it shows
  a real, verifiable loss drop (used by examples/train_lm.py and the
  integration tests).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "markov"        # "markov" | "uniform"
    branching: int = 4           # markov: successors per state


def markov_transition(vocab: int, branching: int, seed: int) -> np.ndarray:
    """(vocab, branching) successor table of a sparse random Markov chain."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xC311]))
    return rng.integers(0, vocab, size=(vocab, branching), dtype=np.int32)


class SyntheticLMData:
    """Iterator over (inputs, labels) int32 arrays of shape (local_B, S)."""

    def __init__(self, config: DataConfig, shard: int = 0, n_shards: int = 1,
                 start_step: int = 0):
        assert config.global_batch % n_shards == 0, (config, n_shards)
        self.config = config
        self.shard = shard
        self.n_shards = n_shards
        self.local_batch = config.global_batch // n_shards
        self.step = start_step
        if config.kind == "markov":
            self._table = markov_transition(config.vocab, config.branching,
                                            config.seed)

    # -- checkpointable state ------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.config.seed,
                "kind": self.config.kind}

    def load_state_dict(self, state: dict) -> None:
        assert state["seed"] == self.config.seed, "seed mismatch on restore"
        self.step = int(state["step"])

    def reshard(self, shard: int, n_shards: int) -> "SyntheticLMData":
        """Elastic re-partition at the current step."""
        return SyntheticLMData(self.config, shard, n_shards, self.step)

    # -- generation -------------------------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(np.random.SeedSequence(
            [self.config.seed, step, self.shard, self.n_shards]))

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        cfg = self.config
        rng = self._rng(step)
        B, S = self.local_batch, cfg.seq_len
        if cfg.kind == "uniform":
            seq = rng.integers(0, cfg.vocab, size=(B, S + 1), dtype=np.int32)
        else:
            seq = np.empty((B, S + 1), np.int32)
            seq[:, 0] = rng.integers(0, cfg.vocab, size=B)
            choices = rng.integers(0, cfg.branching, size=(B, S))
            for t in range(1, S + 1):
                seq[:, t] = self._table[seq[:, t - 1], choices[:, t - 1]]
        return seq[:, :-1].copy(), seq[:, 1:].copy()

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        return self

    def __next__(self) -> Tuple[np.ndarray, np.ndarray]:
        batch = self.batch_at(self.step)
        self.step += 1
        return batch

    def entropy_floor(self) -> float:
        """Conditional entropy of the markov source (nats) — the loss floor."""
        if self.config.kind == "uniform":
            return float(np.log(self.config.vocab))
        # successors drawn uniformly from `branching` slots (with possible
        # duplicates): entropy <= log(branching)
        return float(np.log(self.config.branching))
