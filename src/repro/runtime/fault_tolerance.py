"""Fault tolerance & elasticity: heartbeats, stragglers, restart driver.

At 1000+ nodes the failure model is: slices die (heartbeat timeout), nodes
slow down (stragglers), and capacity changes (elastic).  The policy layer
here is hardware-agnostic and fully unit-testable; the JAX-side mechanics it
drives are (a) checkpoint restore with resharding (`repro.checkpoint`) and
(b) mesh re-creation (`launch.mesh`).

`run_with_restarts` is the generic driver: it executes a step function,
detects (injected or real) failures, restores the latest committed
checkpoint onto the surviving topology, and continues — the pattern the
integration test and examples/elastic_restart.py exercise end-to-end.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """What to do after a capacity change."""
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    restore_step: Optional[int]
    dropped_hosts: Tuple[int, ...] = ()

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.mesh_shape:
            n *= s
        return n


class HeartbeatMonitor:
    """Tracks per-host heartbeats; reports dead hosts past a timeout."""

    def __init__(self, hosts: Sequence[int], timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        now = clock()
        self._last: Dict[int, float] = {h: now for h in hosts}

    def beat(self, host: int) -> None:
        self._last[host] = self._clock()

    def dead_hosts(self) -> List[int]:
        now = self._clock()
        return sorted(h for h, t in self._last.items()
                      if now - t > self.timeout_s)

    def remove(self, host: int) -> None:
        self._last.pop(host, None)


class StragglerDetector:
    """Flags steps (or hosts) whose duration is an outlier vs the median.

    Mitigation at scale: re-balance the data shard of a persistent straggler
    or evict it (turn it into a heartbeat failure).  The detector implements
    the policy; the driver applies it.
    """

    def __init__(self, window: int = 50, threshold: float = 2.0,
                 patience: int = 3):
        self.window = window
        self.threshold = threshold
        self.patience = patience
        self._durations: List[float] = []
        self._strikes: Dict[int, int] = {}

    #: minimum prior samples before a measurement can be judged — small
    #: enough that an obvious straggler in the first handful of steps is
    #: flagged (a 5-sample warm-up used to mask it), large enough that a
    #: 1-sample "median" doesn't flag normal jitter
    MIN_HISTORY = 3

    def record(self, duration_s: float, host: Optional[int] = None) -> bool:
        """Returns True if this measurement is a straggler event."""
        hist = self._durations[-self.window:]
        self._durations.append(duration_s)
        if len(hist) < self.MIN_HISTORY:
            return False
        med = sorted(hist)[len(hist) // 2]
        is_straggler = duration_s > self.threshold * med
        if host is not None:
            if is_straggler:
                self._strikes[host] = self._strikes.get(host, 0) + 1
            else:
                self._strikes[host] = 0
        return is_straggler

    def should_evict(self, host: int) -> bool:
        return self._strikes.get(host, 0) >= self.patience

    @property
    def median_step_s(self) -> Optional[float]:
        if not self._durations:
            return None
        h = sorted(self._durations[-self.window:])
        return h[len(h) // 2]


class ElasticScaler:
    """Chooses a mesh for the devices that remain.

    Keeps the model axis fixed (TP degree is baked into layouts/kernels) and
    shrinks/grows the data axis; pods with fewer than ``model_axis`` chips
    are dropped entirely.
    """

    def __init__(self, model_axis: int = 16, pod_chips: int = 256):
        self.model_axis = model_axis
        self.pod_chips = pod_chips

    def plan(self, devices_up: int, restore_step: Optional[int],
             dropped_hosts: Sequence[int] = ()) -> ElasticPlan:
        pods = devices_up // self.pod_chips
        if pods >= 2:
            data = self.pod_chips // self.model_axis
            return ElasticPlan((pods, data, self.model_axis),
                               ("pod", "data", "model"), restore_step,
                               tuple(dropped_hosts))
        data = max(1, devices_up // self.model_axis)
        return ElasticPlan((data, self.model_axis), ("data", "model"),
                           restore_step, tuple(dropped_hosts))


def run_with_restarts(step_fn: Callable[[int], None],
                      restore_fn: Callable[[int], int],
                      n_steps: int, *, start_step: int = 0,
                      max_restarts: int = 3,
                      failure_types: Tuple[type, ...] = (RuntimeError,)
                      ) -> Dict[str, int]:
    """Run ``step_fn(step)`` for ``n_steps``; on failure, call
    ``restore_fn(failed_step) -> resume_step`` and continue.

    Returns counters {"completed": ..., "restarts": ...}.  This is the
    single-process skeleton of the fleet driver: in a real deployment,
    ``restore_fn`` re-initializes the jax.distributed client on the new
    topology and reloads the checkpoint via `repro.checkpoint`.
    """
    restarts = 0
    step = start_step
    while step < n_steps:
        try:
            step_fn(step)
            step += 1
        except failure_types:
            restarts += 1
            if restarts > max_restarts:
                raise
            step = restore_fn(step)
    return {"completed": step - start_step, "restarts": restarts}
