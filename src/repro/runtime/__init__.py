from .fault_tolerance import (ElasticPlan, ElasticScaler, HeartbeatMonitor,
                              StragglerDetector, run_with_restarts)

__all__ = ["ElasticPlan", "ElasticScaler", "HeartbeatMonitor",
           "StragglerDetector", "run_with_restarts"]
