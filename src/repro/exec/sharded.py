"""Mesh-sharded plan execution for partitioned co-designed plans.

A :class:`~repro.core.lowering.ShardedExecPlan` (``partition_plan``) proves
a co-designed plan splits into K contiguous row blocks; this module makes
the split run.  Two executables, mirroring the single-device pair:

``ShardedReference``
    The bitwise oracle.  It *simulates* the mesh on the host: every
    row-sharded tensor is a list of K local blocks, collectives are exact
    host-driven data movement (gather = concatenate in shard order, halo
    = neighbour boundary rows), and every op evaluates **eagerly**
    through the same per-op rules as
    :func:`~repro.exec.reference.eval_node` — per shard block for
    row-local ops, once on gathered-whole operands for reductions.
    Eager per-op dispatch is what makes bitwise identity *possible*: any
    whole-body traced execution (jit or eager ``shard_map`` — both trace)
    lets XLA:CPU contract mul+add chains into FMAs at codegen (below
    HLO, so even ``lax.optimization_barrier`` cannot stop it), which
    perturbs elementwise ops like ``axpy`` by 1 ulp against the eager
    unsharded oracle.  The simulated mesh keeps each op's dispatch
    identical to the single-device reference, so results are
    bitwise-equal by construction — and the oracle needs no physical
    devices, so partition semantics are testable without
    ``--xla_force_host_platform_device_count``.

``ShardedProgram``
    The real distributed pallas path.  The localized execution plan
    (rows and row tiles divided by K) drives the existing
    :class:`_StreamCall` kernels in ``defer_finalize`` mode: each
    shard's kernel emits raw reduction partials, the driver ``psum``\\ s
    them across the mesh (then applies the norm sqrt) and replays the
    pass's scalar epilogue chain — all inside ONE
    ``jax.jit(shard_map(...))`` per solve, so the single-dispatch
    guarantee survives distribution.  Cross-shard exchanges: contraction
    right-hand sides and spmv ``x`` vectors gather whole
    (``all_gather``), stencil sweeps trade one halo row with each mesh
    neighbour (``ppermute``), CSR triples localize at trace time by
    slicing each shard's indptr-aligned entry window out of the
    (zero-padded) replicated triple.

Reduction partials reassociate across shards (and the one-jit trace
contracts FMAs), so sharded pallas results carry the same documented
tolerance as single-device pallas vs reference
(``docs/execution_backends.md``).  Feed donation is disabled for sharded
programs (the replicated CSR operands outlive their first read).
"""
from __future__ import annotations

import dataclasses
from types import SimpleNamespace
from typing import Any, Dict, List, Set

from .. import obs
from ..launch.mesh import make_solver_mesh, shard_map_compat
from .base import plan_program
from .pallas import (_DISPATCHES, _TRACES, _UNITS, _StreamCall,
                     _unit_needed)
from .reference import csr_row_ids, eval_node


# --------------------------------------------------------------------------
# shared shard-local rules
# --------------------------------------------------------------------------

def _localize_csr(env: Dict[str, Any], lay, axis: str) -> None:
    """Replace a CSR triple's replicated global arrays in ``env`` with this
    shard's indptr-aligned window.

    indices/data are padded with ``pad_entries`` zeros *before* slicing,
    so the window never clamps near the tail; positions past a shard's
    true entry count resolve (via the rebased local indptr) to local row
    id ``rows_per_shard`` and are dropped by the out-of-range row mask
    every consumer already applies."""
    import jax.numpy as jnp
    from jax import lax

    rows_loc = lay.slices[0].rows
    r0 = lax.axis_index(axis) * rows_loc
    ip = env[lay.indptr]
    ip_loc = lax.dynamic_slice(ip, (r0,), (rows_loc + 1,))
    e0 = ip_loc[0]
    pad = lay.pad_entries
    ix = jnp.concatenate(
        [env[lay.indices], jnp.zeros((pad,), env[lay.indices].dtype)])
    dv = jnp.concatenate(
        [env[lay.data], jnp.zeros((pad,), env[lay.data].dtype)])
    env[lay.indptr] = ip_loc - e0
    env[lay.indices] = lax.dynamic_slice(ix, (e0,), (pad,))
    env[lay.data] = lax.dynamic_slice(dv, (e0,), (pad,))


def _stencil_shard(node, ins: List[Any], axis: str, n_shards: int):
    """The 5-point stencil rule on one row block: interior columns roll
    locally, the two boundary rows arrive from the mesh neighbours
    (circular, matching ``jnp.roll``'s wrap).  Term order matches
    :func:`eval_node` exactly, so the sharded reference stays bitwise."""
    import jax.numpy as jnp
    from jax import lax

    u = ins[0]
    fwd = [(j, (j + 1) % n_shards) for j in range(n_shards)]
    bwd = [(j, (j - 1) % n_shards) for j in range(n_shards)]
    prev_last = lax.ppermute(u[-1:, :], axis, fwd)    # shard j-1's last row
    next_first = lax.ppermute(u[:1, :], axis, bwd)    # shard j+1's first row
    down = jnp.concatenate([prev_last, u[:-1, :]], axis=0)   # roll(u, 1, 0)
    up = jnp.concatenate([u[1:, :], next_first], axis=0)     # roll(u, -1, 0)
    out = 0.25 * (down + up + jnp.roll(u, 1, 1) + jnp.roll(u, -1, 1))
    if len(ins) > 1:
        out = out + 0.25 * float(node.param("h2", 1.0)) * ins[1]
    return out


def _partition_specs(program, sharded):
    """(leaf names, leaf in_specs, out names, out specs) for the shard_map
    wrapper: row-sharded names split on the mesh axis, everything else
    (scalars, CSR triples, off-row operands) replicated."""
    from jax.sharding import PartitionSpec as P

    shard_set = set(sharded.sharded)
    leaves = [nd.name for nd in program.leaves()]
    in_specs = tuple(P(sharded.axis) if n in shard_set else P()
                     for n in leaves)
    outs = list(program.outputs)
    out_specs = tuple(P(sharded.axis) if n in shard_set else P()
                      for n in outs)
    return leaves, in_specs, outs, out_specs


# --------------------------------------------------------------------------
# the sharded reference oracle
# --------------------------------------------------------------------------

def _stencil_block(node, u_parts: List[Any], k: int, f_loc) -> Any:
    """One row block of the 5-point stencil on the simulated mesh: the
    boundary rows come from the neighbour blocks (circular, matching
    ``jnp.roll``'s wrap); term order matches :func:`eval_node` exactly."""
    import jax.numpy as jnp

    n_shards = len(u_parts)
    u = u_parts[k]
    prev_last = u_parts[(k - 1) % n_shards][-1:, :]
    next_first = u_parts[(k + 1) % n_shards][:1, :]
    down = jnp.concatenate([prev_last, u[:-1, :]], axis=0)   # roll(u, 1, 0)
    up = jnp.concatenate([u[1:, :], next_first], axis=0)     # roll(u, -1, 0)
    out = 0.25 * (down + up + jnp.roll(u, 1, 1) + jnp.roll(u, -1, 1))
    if f_loc is not None:
        out = out + 0.25 * float(node.param("h2", 1.0)) * f_loc
    return out


class ShardedReference:
    """Bitwise sharded oracle: the reference rules on a simulated mesh.

    Row-sharded tensors live as lists of K per-shard blocks; every op
    dispatches **eagerly** (exactly like the unsharded reference), with
    collectives as exact host-side data movement — see the module
    docstring for why this, and not a traced ``shard_map``, is what a
    bitwise oracle requires."""

    def __init__(self, plan):
        from .base import plan_order

        self.program = plan_program(plan)
        self.sharded = plan.sharded
        self.order = plan_order(plan)
        self.leaf_names = [nd.name for nd in self.program.leaves()]
        self.out_names = list(self.program.outputs)

    def __call__(self, feeds: Dict[str, Any]) -> Dict[str, Any]:
        import jax
        import jax.numpy as jnp

        sharded, program = self.sharded, self.program
        shard_set = set(sharded.sharded)
        halo = set(sharded.halo)
        lay_of = {lay.data: lay for lay in sharded.csr}
        K = sharded.n_shards
        rows_loc = sharded.rows_per_shard

        # env: replicated value, or list of K per-shard row blocks
        env: Dict[str, Any] = {}
        for leaf in self.leaf_names:
            if leaf not in feeds:
                raise KeyError(f"feeds missing leaf {leaf!r}")
            v = jnp.asarray(feeds[leaf])
            env[leaf] = ([v[k * rows_loc:(k + 1) * rows_loc]
                          for k in range(K)]
                         if leaf in shard_set else v)
        # CSR triples: each shard's indptr-aligned entry window out of the
        # zero-padded replicated triple (same layout the pallas path slices
        # at trace time)
        csr_loc: Dict[str, List[Any]] = {}
        for lay in sharded.csr:
            ip, ix = env[lay.indptr], env[lay.indices]
            dv, pad = env[lay.data], lay.pad_entries
            ixp = jnp.concatenate([ix, jnp.zeros((pad,), ix.dtype)])
            dvp = jnp.concatenate([dv, jnp.zeros((pad,), dv.dtype)])
            csr_loc[lay.indptr] = []
            csr_loc[lay.indices] = []
            csr_loc[lay.data] = []
            for k in range(K):
                e0 = lay.entry_starts[k]
                r0 = k * rows_loc
                csr_loc[lay.indptr].append(ip[r0:r0 + rows_loc + 1] - e0)
                csr_loc[lay.indices].append(ixp[e0:e0 + pad])
                csr_loc[lay.data].append(dvp[e0:e0 + pad])

        def full(name):
            """Gathered-whole value: concatenate blocks in shard order."""
            v = env[name]
            return jnp.concatenate(v) if isinstance(v, list) else v

        def local(name, k):
            v = env[name]
            return v[k] if isinstance(v, list) else v

        for nname in self.order:
            nd = program.nodes[nname]
            ins = nd.inputs
            if nd.op == "spmv":
                lay = lay_of[ins[2]]
                x = full(ins[3])
                parts = []
                for k in range(K):
                    ip_k = csr_loc[ins[0]][k]
                    seg = csr_row_ids(ip_k, lay.pad_entries)
                    prod = csr_loc[ins[2]][k] * jnp.take(
                        x, csr_loc[ins[1]][k], axis=0)
                    # padding rows resolve to local row id == rows_loc and
                    # are dropped by segment_sum's out-of-range mask
                    parts.append(jax.ops.segment_sum(
                        prod, seg, num_segments=rows_loc))
                env[nname] = parts
            elif nd.op in ("dot", "norm") or (
                    nd.op in ("matmul", "einsum") and nd.shape == ()):
                # reductions run once on gathered-whole operands: the
                # dispatch is identical to the single-device rule
                env[nname] = eval_node(nd, [full(t) for t in ins])
            elif nd.op in ("matmul", "einsum"):
                rhs = full(ins[1])
                env[nname] = [eval_node(nd, [local(ins[0], k), rhs])
                              for k in range(K)]
            elif nname in halo:
                u_parts = env[ins[0]]
                env[nname] = [
                    _stencil_block(nd, u_parts, k,
                                   local(ins[1], k) if len(ins) > 1
                                   else None)
                    for k in range(K)]
            elif nname in shard_set:
                env[nname] = [eval_node(nd, [local(t, k) for t in ins])
                              for k in range(K)]
            else:
                env[nname] = eval_node(nd, [env[t] for t in ins])
        return {o: full(o) for o in self.out_names}


# --------------------------------------------------------------------------
# the sharded pallas single program
# --------------------------------------------------------------------------

def _local_view(program, sharded):
    """The per-shard view of the expression program: row-sharded names
    take their local shapes, CSR members take their localized window
    shapes, and gathered operands are rewired to ``<name>@g`` alias leaves
    that keep the *global* shape (the driver materializes them with
    ``all_gather``)."""
    rows_loc = sharded.rows_per_shard
    shard_set = set(sharded.sharded)
    gathered = set(sharded.gathered)
    csr_shapes: Dict[str, tuple] = {}
    for lay in sharded.csr:
        csr_shapes[lay.indptr] = (rows_loc + 1,)
        csr_shapes[lay.indices] = (lay.pad_entries,)
        csr_shapes[lay.data] = (lay.pad_entries,)

    nodes: Dict[str, Any] = {}
    for name, nd in program.nodes.items():
        shape = tuple(nd.shape)
        if name in csr_shapes:
            shape = csr_shapes[name]
        elif name in shard_set:
            shape = (rows_loc,) + shape[1:]
        inputs = tuple(nd.inputs)
        if nd.op in ("matmul", "einsum") and nd.shape != () \
                and inputs[1] in gathered:
            inputs = (inputs[0], inputs[1] + "@g")
        elif nd.op == "spmv" and inputs[3] in gathered:
            inputs = inputs[:3] + (inputs[3] + "@g",)
        if shape != tuple(nd.shape) or inputs != tuple(nd.inputs):
            nd = dataclasses.replace(nd, shape=shape, inputs=inputs)
        nodes[name] = nd
    for g in sharded.gathered:
        nodes[g + "@g"] = dataclasses.replace(
            program.nodes[g], name=g + "@g", op="input", inputs=())
    return SimpleNamespace(nodes=nodes, outputs=tuple(program.outputs))


class _InlineUnit:
    """A block/jnp unit inlined into the shard body: reference rules per
    op, stencil sweeps through the halo exchange.  (Sharded plans skip
    ``_BlockCall``: a whole-array pallas block would need the full grid,
    which is exactly what sharding removes.)"""

    def __init__(self, view, ops, needed: Set[str], halo: Set[str],
                 axis: str, n_shards: int):
        from .pallas import _group_io

        self.nodes = [view.nodes[o] for o in ops]
        self.in_names, self.out_names = _group_io(view, self.nodes,
                                                  needed)
        self.halo = halo
        self.axis = axis
        self.n_shards = n_shards

    def apply(self, env: Dict[str, Any], dtype=None) -> Dict[str, Any]:
        vals = {n: env[n] for n in self.in_names}
        for nd in self.nodes:
            if nd.name in self.halo:
                vals[nd.name] = _stencil_shard(
                    nd, [vals[t] for t in nd.inputs], self.axis,
                    self.n_shards)
            else:
                vals[nd.name] = eval_node(nd,
                                          [vals[t] for t in nd.inputs])
        return {n: vals[n] for n in self.out_names}


class ShardedProgram:
    """One whole-plan jitted ``shard_map`` executable for a partitioned
    plan: ``feeds (global) -> {output: value (global)}``.

    Structure mirrors :class:`~repro.exec.pallas._SingleProgram` — the
    localized units trace inside a single jit (rolled loops as
    ``lax.fori_loop``), and ``stats`` counts one dispatch per solve."""

    def __init__(self, plan):
        program = plan_program(plan)
        sharded = plan.sharded
        self.sharded = sharded
        ep = sharded.local
        units, roll = ep.units, ep.roll
        # "read outside the unit" is a dataflow property of the GLOBAL
        # program (the renamed @g aliases are driver-materialized views,
        # not dataflow), so needed-sets come from the original wiring
        needed, _ = _unit_needed(program, units)
        if roll is not None:
            updates = {sl.update for sl in roll.slots}
            inits = {sl.init for sl in roll.slots if sl.init is not None}
            for ui in range(roll.first, roll.first + roll.per_iter):
                needed[ui] = needed[ui] | (updates & set(units[ui].ops))
            for ui in range(roll.first):
                needed[ui] = needed[ui] | (inits & set(units[ui].ops))
            pro = range(roll.first)
            tmpl = range(roll.first, roll.first + roll.per_iter)
            epi = range(roll.stop, len(units))
        else:
            pro, tmpl, epi = range(len(units)), (), ()

        view = _local_view(program, sharded)
        halo = set(sharded.halo)
        g_rename = {g: g + "@g" for g in sharded.gathered}

        def build(i):
            u = units[i]
            if u.kind == "stream":
                return _StreamCall(view, u.sp, needed[i],
                                   defer_finalize=True,
                                   resident_rename=g_rename)
            return _InlineUnit(view, u.ops, needed[i], halo,
                               sharded.axis, sharded.n_shards)

        self._pro = [build(i) for i in pro]
        self._tmpl = [build(i) for i in tmpl]
        self._epi = [build(i) for i in epi]
        self.roll = roll
        self.leaf_names, in_specs, self.out_names, out_specs = \
            _partition_specs(program, sharded)
        self._scope = obs.next_scope("pallas")
        for i in (*pro, *tmpl, *epi):
            _UNITS.inc(backend="pallas", kind=units[i].kind,
                       scope=self._scope)

        if roll is not None:
            tmpl_ops = {o for i in tmpl for o in units[i].ops}
            reads = {sl.read for sl in roll.slots if sl.read is not None}
            ext: List[str] = []
            for call in self._tmpl:
                for n in call.in_names:
                    # @g aliases are re-gathered inside the loop body from
                    # their base value; the base is what must be carried in
                    base = n[:-2] if n.endswith("@g") else n
                    if base not in tmpl_ops and base not in reads \
                            and base not in ext:
                        ext.append(base)
            assert all(sl.update in tmpl_ops for sl in roll.slots)
            self._tmpl_ext = ext
            self._slot_shapes = [view.nodes[sl.update].shape
                                 for sl in roll.slots]

        import jax
        mesh = make_solver_mesh(sharded.n_shards, axis=sharded.axis)
        # no donation: the replicated CSR triples and gathered operands
        # outlive their first read inside the shard body
        self._jit = jax.jit(shard_map_compat(self._traced, mesh,
                                             tuple(in_specs),
                                             tuple(out_specs)))

    @property
    def stats(self) -> Dict[str, int]:
        return {
            "traces": int(_TRACES.value(backend="pallas",
                                        scope=self._scope)),
            "dispatches": int(_DISPATCHES.value(backend="pallas",
                                                scope=self._scope)),
        }

    # -- per-unit driver (inside the shard_map trace) -------------------
    def _run_call(self, call, env: Dict[str, Any], dtype) -> None:
        import jax.numpy as jnp
        from jax import lax

        axis = self.sharded.axis
        for n in call.in_names:
            if n.endswith("@g") and n not in env:
                env[n] = lax.all_gather(env[n[:-2]], axis, tiled=True)
        out = call.apply(env, dtype)
        if isinstance(call, _StreamCall) and call.defer:
            norm = call.norm_reductions
            for n in call.red_out:
                v = lax.psum(out[n], axis)
                out[n] = jnp.sqrt(v) if n in norm else v
            env.update(out)
            # the pass's scalar chain (eager + epilogue), replayed on the
            # combined reductions — replicated, so every shard agrees
            for nd in call.finalize_nodes:
                env[nd.name] = eval_node(nd,
                                         [env[t] for t in nd.inputs])
        else:
            env.update(out)

    # -- the traced shard body ------------------------------------------
    def _traced(self, *leaf_vals):
        import jax.numpy as jnp
        _TRACES.inc(backend="pallas", scope=self._scope)
        float_dts = [v.dtype for v in leaf_vals
                     if jnp.issubdtype(v.dtype, jnp.floating)]
        dtype = jnp.result_type(*float_dts) if float_dts else jnp.float32
        env: Dict[str, Any] = {}
        for name, v in zip(self.leaf_names, leaf_vals):
            env[name] = (jnp.asarray(v, dtype)
                         if jnp.issubdtype(v.dtype, jnp.floating) else v)
        for lay in self.sharded.csr:
            _localize_csr(env, lay, self.sharded.axis)
        for call in self._pro:
            self._run_call(call, env, dtype)
        if self.roll is not None:
            from jax import lax
            slots = self.roll.slots
            base = {n: env[n] for n in self._tmpl_ext}

            def body(_, carry):
                env_l = dict(base)
                for sl, v in zip(slots, carry):
                    if sl.read is not None:
                        env_l[sl.read] = v
                for call in self._tmpl:
                    self._run_call(call, env_l, dtype)
                return tuple(env_l[sl.update] for sl in slots)

            carry = tuple(
                env[sl.init] if sl.init is not None
                else jnp.zeros(shape, dtype)
                for sl, shape in zip(slots, self._slot_shapes))
            carry = lax.fori_loop(0, self.roll.n_iters, body, carry)
            for sl, v in zip(slots, carry):
                env[sl.final] = v
        for call in self._epi:
            self._run_call(call, env, dtype)
        return tuple(env[o] for o in self.out_names)

    # -- the dispatch ---------------------------------------------------
    def __call__(self, feeds: Dict[str, Any]) -> Dict[str, Any]:
        args = []
        for leaf in self.leaf_names:
            if leaf not in feeds:
                raise KeyError(f"feeds missing leaf {leaf!r}")
            args.append(feeds[leaf])
        _DISPATCHES.inc(backend="pallas", scope=self._scope)
        outs = self._jit(*args)
        return dict(zip(self.out_names, outs))
