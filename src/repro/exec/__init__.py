"""`repro.exec` — pluggable execution backends for compiled plans.

The co-design toolchain decides *how* a workload should run (order, fusion
groups, pins, buffer split); this package is where those decisions become
computation.  ``CompiledPlan.run(backend=...)`` resolves a backend by name
from the same kind of registry as ``core.search.SearchStrategy``:

  ``reference`` — the ``jax.numpy`` interpreter (op-by-op, full tensors),
                  the bit-exact oracle every other backend validates against,
  ``pallas``    — each fusion group as tile-streaming ``pl.pallas_call``
                  kernels (``interpret=True`` off-TPU), honoring the
                  co-designed group order end-to-end.

Add a backend by subclassing :class:`Executor` and calling
:func:`register_backend` — see ``docs/execution_backends.md``.
"""
from .base import (EXECUTOR_REGISTRY, Executor, get_backend, list_backends,
                   plan_groups, plan_order, plan_program, register_backend)
from .pallas import PallasExecutor
from .reference import ReferenceExecutor, evaluate, eval_node, execute_plan

register_backend(ReferenceExecutor)
register_backend(PallasExecutor)

__all__ = [
    "EXECUTOR_REGISTRY", "Executor", "get_backend", "list_backends",
    "register_backend", "plan_groups", "plan_order", "plan_program",
    "ReferenceExecutor", "PallasExecutor",
    "evaluate", "eval_node", "execute_plan",
]
