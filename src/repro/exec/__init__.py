"""`repro.exec` — pluggable execution backends for compiled plans.

The co-design toolchain decides *how* a workload should run (order, fusion
groups, pins, buffer split); this package is where those decisions become
computation.  ``CompiledPlan.run(backend=...)`` resolves a backend by name
from the same kind of registry as ``core.search.SearchStrategy``:

  ``reference``       — the ``jax.numpy`` interpreter (op-by-op, full
                        tensors), the bit-exact oracle every other backend
                        validates against,
  ``pallas``          — the whole plan compiled into ONE jitted
                        single-program executable: tile-streaming
                        ``pl.pallas_call`` units (``interpret=True``
                        off-TPU) with cross-pass residency fusion and
                        scan-rolled solver iterations; exactly one device
                        dispatch per ``run()``,
  ``pallas-perunit``  — the 0.4-era per-unit driver (one dispatch per
                        pass), kept as the measured A/B baseline.

Add a backend by subclassing :class:`Executor` and calling
:func:`register_backend` — see ``docs/execution_backends.md``.
"""
from .base import (EXECUTOR_REGISTRY, Executor, get_backend, list_backends,
                   plan_groups, plan_order, plan_program, register_backend)
from .pallas import PallasExecutor, PerUnitPallasExecutor
from .reference import ReferenceExecutor, evaluate, eval_node, execute_plan

register_backend(ReferenceExecutor)
register_backend(PallasExecutor)
register_backend(PerUnitPallasExecutor)

__all__ = [
    "EXECUTOR_REGISTRY", "Executor", "get_backend", "list_backends",
    "register_backend", "plan_groups", "plan_order", "plan_program",
    "ReferenceExecutor", "PallasExecutor", "PerUnitPallasExecutor",
    "evaluate", "eval_node", "execute_plan",
]
