"""The ``reference`` execution backend: a ``jax.numpy`` interpreter.

This is the bit-exact oracle every other backend is validated against.  It
executes one op at a time at full-tensor granularity — the per-op rules in
:func:`eval_node` define the semantics of every expression op, and because
ops are pure, replaying a co-designed schedule order through the same rules
must match natural-order evaluation bit-for-bit.  Buffer residency is a
planning/execution concept that never reaches these rules: overbooked
prefix pins (``core.lowering.ResidentSlice``) change how the pallas
backend lays out a CSR operand, not what an spmv computes, so this
backend stays the unchanged oracle for prefix-pinned plans too.

Relocated from ``frontends/reference.py`` (which keeps the deterministic
feed generator); ``repro.frontends`` re-exports :func:`evaluate` /
:func:`execute_plan` so existing imports keep working.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

from ..testing import faults
from .base import Executor, plan_order, plan_program


def csr_row_ids(indptr, nnz: int):
    """Row id of every stored CSR entry, from ``indptr`` — the one rule
    both the reference spmv and the pallas ``spmv-stream`` kernel use, so
    their per-row summation order can never drift apart."""
    import jax.numpy as jnp
    return jnp.searchsorted(indptr, jnp.arange(nnz, dtype=indptr.dtype),
                            side="right") - 1


def eval_node(node, ins: List[Any]):
    """Reference rule for one expression op (``ins`` in operand order)."""
    import jax.numpy as jnp
    op = node.op
    if op == "matmul":
        return ins[0] @ ins[1]
    if op == "einsum":
        return jnp.einsum(node.param("spec"), *ins)
    if op == "dot":
        return jnp.dot(ins[0], ins[1])
    if op == "norm":
        return jnp.sqrt(jnp.dot(jnp.ravel(ins[0]), jnp.ravel(ins[0])))
    if op == "add":
        return ins[0] + ins[1]
    if op == "sub":
        return ins[0] - ins[1]
    if op == "mul":
        return ins[0] * ins[1]
    if op == "div":
        return ins[0] / ins[1]
    if op == "neg":
        return -ins[0]
    if op == "axpy":
        return ins[0] * ins[1] + ins[2]
    if op == "stencil2d":
        u = ins[0]
        out = 0.25 * (jnp.roll(u, 1, 0) + jnp.roll(u, -1, 0)
                      + jnp.roll(u, 1, 1) + jnp.roll(u, -1, 1))
        if len(ins) > 1:
            out = out + 0.25 * float(node.param("h2", 1.0)) * ins[1]
        return out
    if op == "gather":
        return jnp.take(ins[0], ins[1], axis=0)
    if op == "spmv":
        # CSR SpMV via explicit gather + segment sum: one multiply-add per
        # stored entry, rows resolved from indptr — the scipy-free rule
        # every sparse backend is validated against
        import jax
        indptr, indices, data, x = ins
        seg = csr_row_ids(indptr, data.shape[0])
        return jax.ops.segment_sum(data * jnp.take(x, indices, axis=0),
                                   seg, num_segments=node.shape[0])
    raise NotImplementedError(f"reference rule missing for op {op!r}")


def execute_plan(program, *, order: Optional[Sequence[str]] = None,
                 feeds: Optional[Dict[str, Any]] = None,
                 seed: int = 0, return_all: bool = False) -> Dict[str, Any]:
    """Execute the program's ops in ``order`` (default: build order).

    ``order`` is the flattened schedule from a co-designed plan; it must be
    a topological permutation of the program's ops — validated here, since
    a schedule that reads an unproduced tensor is a lowering bug, not a
    numerics question.
    """
    vals: Dict[str, Any] = {}
    op_names = program.schedulable_order()
    order = list(order) if order is not None else op_names
    if sorted(order) != sorted(op_names):
        raise ValueError(f"order is not a permutation of {program.name!r} "
                         "ops")
    if feeds is None:
        from ..frontends.reference import make_feeds
        feeds = make_feeds(program, seed)
    else:
        feeds = dict(feeds)
    for nd in program.leaves():
        if nd.name not in feeds:
            raise KeyError(f"feeds missing leaf {nd.name!r}")
        vals[nd.name] = feeds[nd.name]
    # free dead intermediates as execution passes their last consumer —
    # paper-scale grids (jacobi2d n=4096 keeps 64 MiB per sweep) would
    # otherwise all stay resident until the end of the run
    last_use: Dict[str, int] = {}
    for step, nname in enumerate(order):
        for t in program.nodes[nname].inputs:
            last_use[t] = step
    keep = set(program.outputs) if not return_all else set(vals) | set(order)
    for step, nname in enumerate(order):
        node = program.nodes[nname]
        missing = [i for i in node.inputs if i not in vals]
        if missing:
            raise ValueError(f"schedule order not topological: {nname} "
                             f"reads unproduced {missing}")
        vals[nname] = eval_node(node, [vals[i] for i in node.inputs])
        if not return_all:
            for t in set(node.inputs):
                if last_use[t] == step and t not in keep:
                    del vals[t]
    if return_all:
        return vals
    return {o: vals[o] for o in program.outputs}


def evaluate(program, feeds: Optional[Dict[str, Any]] = None, *,
             seed: int = 0, return_all: bool = False) -> Dict[str, Any]:
    """Reference evaluation in the program's natural (build) order."""
    return execute_plan(program, order=None, feeds=feeds, seed=seed,
                        return_all=return_all)


class ReferenceExecutor(Executor):
    """Replay the co-designed schedule order through the interpreter."""

    name = "reference"

    def compile(self, plan):
        # fault-injection site (docs/robustness.md): exec.compile@reference
        faults.check("exec.compile", backend=self.name)
        sharded = getattr(plan, "sharded", None)
        if sharded is not None and sharded.n_shards > 1:
            # mesh-partitioned plan: the sharded oracle replays the same
            # per-op rules under shard_map, gathering reduction operands
            # whole so results stay bitwise-identical to this backend
            from .sharded import ShardedReference
            return ShardedReference(plan)
        program = plan_program(plan)
        order = plan_order(plan)

        def fn(feeds):
            return execute_plan(program, order=order, feeds=feeds)
        return fn
