"""Executor interface + registry for plan execution backends.

A backend turns a lowered :class:`~repro.api.artifacts.CompiledPlan` for a
frontend (expression-DAG) trace into an actual computation::

    fn = get_backend("pallas").compile(plan)   # plan -> callable(feeds)
    outputs = fn(feeds)                        # {tensor name: array}

Backends register by name exactly like ``core.search.SearchStrategy``
instances, so ``CompiledPlan.run(backend=...)`` resolves through one
registry and a new backend (sharded, multi-device, TPU-real) is a registry
entry, not a rewrite.  The contract every backend must meet:

* it executes the plan's **co-designed group order** (the flattened fusion
  groups), not the program's build order,
* its outputs match the ``reference`` backend on the same feeds — bitwise
  for backends that replay the same per-op jax.numpy rules, within the
  documented reduction-reassociation tolerances for tiled backends
  (``docs/execution_backends.md``).
"""
from __future__ import annotations

import threading
import time
import weakref
from typing import Any, Callable, Dict, List, Optional, Sequence

from .. import obs
from ..testing import faults

Feeds = Dict[str, Any]
CompiledFn = Callable[[Feeds], Dict[str, Any]]

_COMPILE_S = obs.registry().histogram(
    "exec.compile_s", "plan -> callable compile wall-clock (memoized: one "
    "observation per distinct plan per executor)", unit="s")
_RUN_S = obs.registry().histogram(
    "exec.run_s", "compiled-callable dispatch wall-clock (submit-side; jax "
    "dispatch is async, so device time may extend past this)", unit="s")


class Executor:
    """Protocol: compile a frontend plan into a callable and run it."""

    name: str = "base"

    def __init__(self) -> None:
        # compiled-plan cache keyed by plan *identity* (plan equality
        # ignores the carried trace/program, so two distinct programs can
        # compare equal); weakrefs keep dead plans from pinning entries.
        # The lock serializes lookup+compile+insert, so two threads racing
        # the same plan compile it once (a serving process hits this) —
        # the weakref finalizer's dict.pop is atomic under the GIL and
        # never takes the lock, so it cannot deadlock against a compile.
        self._compiled: Dict[int, tuple] = {}
        self._compile_lock = threading.Lock()

    # -- backend contract ----------------------------------------------
    def compile(self, plan) -> CompiledFn:
        """Lower ``plan`` to a callable ``feeds -> {name: value}``."""
        raise NotImplementedError

    def compile_pure(self, plan) -> CompiledFn:
        """Like :meth:`compile`, but the returned callable must be **pure**
        and jax-traceable (``feeds -> {name: tracer}`` with no Python side
        effects per call), so it composes under ``jax.jit`` / ``jax.vmap``.
        Backends whose compiled callable is already pure (reference) just
        inherit this; backends with per-call driver state (dispatch
        counters, donation) override it to expose the traced core
        (``repro.serve.BatchedPlan`` batches through this hook)."""
        return self.compile(plan)

    # -- shared driver --------------------------------------------------
    def run(self, plan, feeds: Optional[Feeds] = None, *,
            seed: int = 0) -> Dict[str, Any]:
        """Compile (memoized, thread-safe) and execute ``plan``."""
        program = plan_program(plan)
        with self._compile_lock:
            entry = self._compiled.get(id(plan))
            fn = (entry[1] if entry is not None and entry[0]() is plan
                  else None)
            if fn is None:
                t0 = time.perf_counter()
                with obs.span("exec.compile", backend=self.name):
                    # fault-injection site (docs/robustness.md):
                    # exec.compile@<backend>
                    faults.check("exec.compile", backend=self.name)
                    fn = self.compile(plan)
                _COMPILE_S.observe(time.perf_counter() - t0,
                                   backend=self.name)
                try:
                    ref = weakref.ref(
                        plan,
                        lambda _, k=id(plan): self._compiled.pop(k, None))
                except TypeError:                    # not weakref-able
                    pass
                else:
                    self._compiled[id(plan)] = (ref, fn)
        if feeds is None:
            from ..frontends.reference import make_feeds
            feeds = make_feeds(program, seed)
        t0 = time.perf_counter()
        with obs.span("exec.dispatch", backend=self.name):
            # fault-injection site: exec.dispatch@<backend>
            faults.check("exec.dispatch", backend=self.name)
            out = fn(feeds)
        _RUN_S.observe(time.perf_counter() - t0, backend=self.name)
        return out

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.name!r})"


# --------------------------------------------------------------------------
# plan plumbing shared by every backend
# --------------------------------------------------------------------------

def plan_program(plan):
    """The expression :class:`~repro.frontends.expr.Program` behind ``plan``
    (execution backends only run frontend-traced plans)."""
    if plan.trace is None or plan.trace.program is None:
        raise ValueError("execution backends need a frontend-traced plan "
                         "(Session.trace(workload=...) or "
                         "Session.from_graph(program))")
    return plan.trace.program


def plan_groups(plan) -> List[List[str]]:
    """The co-designed fusion groups in scheduled order (each op its own
    group, in build order, when no search was run)."""
    program = plan_program(plan)
    if plan.codesigned is not None:
        return [list(g) for g in plan.codesigned.best.schedule.groups]
    return [[n] for n in program.schedulable_order()]


def plan_order(plan) -> List[str]:
    """The flattened scheduled op order."""
    return [o for g in plan_groups(plan) for o in g]


# --------------------------------------------------------------------------
# registry (mirrors core.search.SearchStrategy)
# --------------------------------------------------------------------------

EXECUTOR_REGISTRY: Dict[str, Executor] = {}


def register_backend(backend):
    """Register a backend instance (or class, instantiated with no args)."""
    inst = backend() if isinstance(backend, type) else backend
    EXECUTOR_REGISTRY[inst.name] = inst
    return backend


def get_backend(name_or_obj) -> Executor:
    if isinstance(name_or_obj, str):
        if name_or_obj not in EXECUTOR_REGISTRY:
            raise KeyError(f"unknown execution backend {name_or_obj!r}; "
                           f"have {sorted(EXECUTOR_REGISTRY)}")
        return EXECUTOR_REGISTRY[name_or_obj]
    if isinstance(name_or_obj, type):    # mirror register_backend: a bare
        return name_or_obj()             # class is instantiated with no args
    return name_or_obj


def list_backends() -> Sequence[str]:
    return sorted(EXECUTOR_REGISTRY)
