"""The ``pallas`` execution backend: co-designed groups as real kernels.

Every fusion group of a lowered plan executes as `pl.pallas_call` kernels
shaped by :func:`repro.core.lowering.select_group_kernels`:

* ``stream`` passes run a 1-D grid over row tiles of the pass's shared
  streamed length.  Contraction right-hand sides (and any other full-block
  operands) use a *constant index map*, so Pallas keeps them resident in
  VMEM across every grid step — the execution-level image of the plan's
  explicit-region pins.  Rank-0 dot/norm reductions accumulate into a
  revisited ``(1,)`` output block across the pass; scalar epilogues
  (``beta = rs'/rs``) run once on the final tile.
* ``block`` kernels hold whole arrays as single blocks (stencil sweeps need
  halo rows, which row tiles cannot provide without overlap).
* ``jnp`` groups — irregular gathers, >2-operand einsums, scalar-only
  groups — fall back to one jitted ``jax.numpy`` closure per group.

On CPU (and any non-TPU backend) kernels run with ``interpret=True``, so CI
exercises the real lowering; on TPU they compile through Mosaic with the
grid marked ``arbitrary`` (accumulation makes steps order-dependent).
Override with ``CELLO_PALLAS_INTERPRET=0/1``.

Numerics: tiled reductions re-associate the sum (per-tile partials), so
outputs match the ``reference`` backend within the tolerances documented in
``docs/execution_backends.md`` rather than bitwise.  Everything elementwise,
matvec rows, block kernels, and jnp fallbacks use the reference rules
verbatim.
"""
from __future__ import annotations

import os
from typing import Any, Callable, Dict, List, Sequence, Set, Tuple

from ..core.lowering import (GroupKernel, STREAM_EINSUMS, StreamPass,
                             select_group_kernels)
from .base import Executor, plan_groups, plan_program
from .reference import eval_node


def use_interpret() -> bool:
    """Interpret Pallas kernels unless we are actually on a TPU (CI and
    laptops exercise the same lowering through the interpreter)."""
    env = os.environ.get("CELLO_PALLAS_INTERPRET")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "no", "")
    import jax
    return jax.default_backend() != "tpu"


def _pallas_call_kwargs(interpret: bool) -> Dict[str, Any]:
    if interpret:
        return {"interpret": True}
    from ..kernels._compat import CompilerParams
    # accumulating reductions make grid steps order-dependent: the grid
    # dimension must not be parallelized across cores
    return {"compiler_params": CompilerParams(
        dimension_semantics=("arbitrary",))}


# --------------------------------------------------------------------------
# node classification inside a streaming pass
# --------------------------------------------------------------------------

def _node_class(node) -> str:
    """"tiled" | "reduce" | "epilogue" for one expr node in a stream pass."""
    if node.op in ("dot", "norm"):
        return "reduce"
    if node.shape == ():
        # a rank-0 matmul (``a,a->``) is a reduction; rank-0 elementwise
        # (alpha = rs/pAp) is a scalar epilogue
        return "reduce" if node.op in ("matmul", "einsum") else "epilogue"
    return "tiled"


# --------------------------------------------------------------------------
# kernel builders (one per GroupKernel kind)
# --------------------------------------------------------------------------

class _StreamCall:
    """One tile-streaming ``pl.pallas_call`` for a :class:`StreamPass`."""

    def __init__(self, program, sp: StreamPass, needed: Set[str]):
        self.nodes = [program.nodes[o] for o in sp.ops]
        self.sp = sp
        produced = {nd.name for nd in self.nodes}
        shapes = {n: program.nodes[n].shape
                  for nd in self.nodes for n in (*nd.inputs, nd.name)}
        self.shapes = shapes

        stream_in: List[str] = []
        scalar_in: List[str] = []
        res_in = list(sp.resident)

        def _want(name: str, bucket: List[str]):
            if name not in produced and name not in bucket:
                bucket.append(name)

        for nd in self.nodes:
            cls = _node_class(nd)
            if cls == "tiled" and nd.op in ("matmul", "einsum"):
                rhs = STREAM_EINSUMS[nd.param("spec")]
                _want(nd.inputs[1 - rhs], stream_in)
            elif cls == "tiled":
                for t in nd.inputs:
                    _want(t, scalar_in if shapes[t] == () else stream_in)
            elif cls == "reduce":
                for t in nd.inputs:
                    _want(t, stream_in)
            else:                                   # epilogue: all scalars
                for t in nd.inputs:
                    _want(t, scalar_in)

        self.stream_in, self.res_in, self.scalar_in = \
            stream_in, res_in, scalar_in
        # reductions always need an output block to accumulate into;
        # streamed / epilogue values only when read outside this pass
        self.red_out = [nd.name for nd in self.nodes
                        if _node_class(nd) == "reduce"]
        self.stream_out = [nd.name for nd in self.nodes
                           if _node_class(nd) == "tiled"
                           and nd.name in needed]
        self.epi_out = [nd.name for nd in self.nodes
                        if _node_class(nd) == "epilogue"
                        and nd.name in needed]
        self.needed = needed
        self._built: Dict[Any, Callable] = {}

    # -- pallas plumbing ------------------------------------------------
    def _specs(self, dtype):
        import jax
        from jax.experimental import pallas as pl
        tr = self.sp.tile_rows

        def stream_spec(shape):
            if len(shape) == 1:
                return pl.BlockSpec((tr,), lambda i: (i,))
            return pl.BlockSpec((tr,) + shape[1:],
                                lambda i: (i,) + (0,) * (len(shape) - 1))

        def full_spec(shape):
            shape = shape or (1,)            # rank-0 passed as (1,)
            return pl.BlockSpec(shape, lambda i: (0,) * len(shape))

        in_specs = ([stream_spec(self.shapes[n]) for n in self.stream_in]
                    + [full_spec(self.shapes[n]) for n in self.res_in]
                    + [full_spec(()) for n in self.scalar_in])
        out_specs, out_shape = [], []
        for n in self.red_out + self.epi_out:
            out_specs.append(full_spec(()))
            out_shape.append(jax.ShapeDtypeStruct((1,), dtype))
        for n in self.stream_out:
            out_specs.append(stream_spec(self.shapes[n]))
            out_shape.append(jax.ShapeDtypeStruct(self.shapes[n], dtype))
        return in_specs, out_specs, out_shape

    def _build(self, dtype):
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        n_tiles = self.sp.rows // self.sp.tile_rows
        nodes, shapes = self.nodes, self.shapes
        n_stream, n_res = len(self.stream_in), len(self.res_in)
        n_scal = len(self.scalar_in)
        scalar_outs = self.red_out + self.epi_out
        stream_out_set = set(self.stream_out)
        red_set = set(self.red_out)
        epi_nodes = [nd for nd in nodes if _node_class(nd) == "epilogue"]

        def kernel(*refs):
            i = pl.program_id(0)
            last = n_tiles - 1
            sref = dict(zip(self.stream_in, refs[:n_stream]))
            rref = dict(zip(self.res_in, refs[n_stream:n_stream + n_res]))
            cref = dict(zip(self.scalar_in,
                            refs[n_stream + n_res:
                                 n_stream + n_res + n_scal]))
            oref = dict(zip(scalar_outs + self.stream_out,
                            refs[n_stream + n_res + n_scal:]))
            tiles: Dict[str, Any] = {}

            def stv(name):                      # streamed tile value
                if name not in tiles:
                    tiles[name] = sref[name][...]
                return tiles[name]

            def opv(nd, t):                     # tiled-op operand value
                return cref[t][0] if shapes[t] == () else stv(t)

            for nd in nodes:
                cls = _node_class(nd)
                if cls == "tiled":
                    if nd.op in ("matmul", "einsum"):
                        rhs = STREAM_EINSUMS[nd.param("spec")]
                        val = jnp.dot(stv(nd.inputs[1 - rhs]),
                                      rref[nd.inputs[rhs]][...],
                                      preferred_element_type=dtype)
                    else:
                        val = eval_node(nd, [opv(nd, t) for t in nd.inputs])
                    tiles[nd.name] = val
                    if nd.name in stream_out_set:
                        oref[nd.name][...] = val
                elif cls == "reduce":
                    if nd.op == "norm":
                        x = stv(nd.inputs[0])
                        part = jnp.dot(x, x, preferred_element_type=dtype)
                    else:
                        part = jnp.dot(stv(nd.inputs[0]),
                                       stv(nd.inputs[1]),
                                       preferred_element_type=dtype)
                    _accumulate(oref[nd.name], part, i)
                    if nd.op == "norm":
                        _sqrt_at(oref[nd.name], i == last)
            if epi_nodes:
                @pl.when(i == last)
                def _():
                    vals: Dict[str, Any] = {}

                    def sval(t):
                        if t in vals:
                            return vals[t]
                        if t in red_set:
                            return oref[t][0]
                        return cref[t][0]
                    for nd in epi_nodes:
                        vals[nd.name] = eval_node(
                            nd, [sval(t) for t in nd.inputs])
                        if nd.name in oref:
                            oref[nd.name][0] = vals[nd.name]

        in_specs, out_specs, out_shape = self._specs(dtype)
        return pl.pallas_call(
            kernel, grid=(n_tiles,), in_specs=in_specs,
            out_specs=out_specs, out_shape=out_shape,
            **_pallas_call_kwargs(use_interpret()))

    # -- driver ---------------------------------------------------------
    def __call__(self, env: Dict[str, Any]) -> Dict[str, Any]:
        import jax.numpy as jnp
        dtype = jnp.result_type(
            *(env[n].dtype for n in
              self.stream_in + self.res_in + self.scalar_in))
        call = self._built.get(dtype)
        if call is None:
            call = self._built[dtype] = self._build(dtype)
        args = ([jnp.asarray(env[n], dtype) for n in self.stream_in]
                + [jnp.asarray(env[n], dtype) for n in self.res_in]
                + [jnp.reshape(jnp.asarray(env[n], dtype), (1,))
                   for n in self.scalar_in])
        outs = call(*args)
        names = self.red_out + self.epi_out + self.stream_out
        result = {}
        for n, v in zip(names, outs):
            if n in self.needed:
                result[n] = v[0] if self.shapes[n] == () else v
        return result


def _accumulate(ref, part, i):
    from jax.experimental import pallas as pl

    @pl.when(i == 0)
    def _():
        ref[0] = part

    @pl.when(i > 0)
    def _():
        ref[0] = ref[0] + part


def _sqrt_at(ref, cond):
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    @pl.when(cond)
    def _():
        ref[0] = jnp.sqrt(ref[0])


def _group_io(program, nodes, needed: Set[str]):
    """(external inputs, needed outputs) for one op group, in op order."""
    produced = {nd.name for nd in nodes}
    in_names: List[str] = []
    for nd in nodes:
        for t in nd.inputs:
            if t not in produced and t not in in_names:
                in_names.append(t)
    return in_names, [nd.name for nd in nodes if nd.name in needed]


class _BlockCall:
    """Whole-array single-block kernel for halo (stencil) groups."""

    def __init__(self, program, ops: Sequence[str], needed: Set[str]):
        self.nodes = [program.nodes[o] for o in ops]
        self.in_names, self.out_names = _group_io(program, self.nodes,
                                                  needed)
        self.shapes = {n: program.nodes[n].shape
                       for nd in self.nodes for n in (*nd.inputs, nd.name)}
        self._built: Dict[Any, Callable] = {}

    def _build(self, dtype):
        import jax
        from jax.experimental import pallas as pl
        n_in = len(self.in_names)

        def kernel(*refs):
            vals = {n: r[...] for n, r in zip(self.in_names, refs[:n_in])}
            for nd in self.nodes:
                vals[nd.name] = eval_node(nd,
                                          [vals[t] for t in nd.inputs])
            for n, r in zip(self.out_names, refs[n_in:]):
                r[...] = vals[n]

        return pl.pallas_call(
            kernel,
            out_shape=[jax.ShapeDtypeStruct(self.shapes[n], dtype)
                       for n in self.out_names],
            **_pallas_call_kwargs(use_interpret()))

    def __call__(self, env: Dict[str, Any]) -> Dict[str, Any]:
        import jax.numpy as jnp
        dtype = jnp.result_type(*(env[n].dtype for n in self.in_names))
        call = self._built.get(dtype)
        if call is None:
            call = self._built[dtype] = self._build(dtype)
        outs = call(*[jnp.asarray(env[n], dtype) for n in self.in_names])
        return dict(zip(self.out_names, outs))


class _JnpCall:
    """Jitted jax.numpy fallback for one non-streamable group."""

    def __init__(self, program, ops: Sequence[str], needed: Set[str]):
        self.nodes = [program.nodes[o] for o in ops]
        self.in_names, self.out_names = _group_io(program, self.nodes,
                                                  needed)
        import jax

        def f(*args):
            vals = dict(zip(self.in_names, args))
            for nd in self.nodes:
                vals[nd.name] = eval_node(nd,
                                          [vals[t] for t in nd.inputs])
            return tuple(vals[n] for n in self.out_names)
        self._fn = jax.jit(f)

    def __call__(self, env: Dict[str, Any]) -> Dict[str, Any]:
        outs = self._fn(*[env[n] for n in self.in_names])
        return dict(zip(self.out_names, outs))


# --------------------------------------------------------------------------
# the executor
# --------------------------------------------------------------------------

def _plan_kernels(plan, groups) -> Tuple[GroupKernel, ...]:
    kernels = getattr(plan, "group_kernels", ()) or ()
    if len(kernels) == len(groups):
        return tuple(kernels)
    sched = (plan.codesigned.best.schedule
             if plan.codesigned is not None else None)
    explicit = sched.config.explicit_bytes if sched is not None else 0
    return select_group_kernels(plan.trace.graph, groups, explicit)


class PallasExecutor(Executor):
    """Execute the co-designed group order through Pallas kernels."""

    name = "pallas"

    def compile(self, plan):
        program = plan_program(plan)
        groups = plan_groups(plan)
        kernels = _plan_kernels(plan, groups)

        # flatten groups into execution units (stream groups contribute one
        # unit per pass), then compute per-unit "needed outside" sets and
        # per-tensor last-use for freeing dead intermediates
        units: List[Tuple[List[str], Any]] = []     # (ops, kind/StreamPass)
        for gk in kernels:
            if gk.kind == "stream":
                for sp in gk.passes:
                    units.append((list(sp.ops), sp))
            else:
                units.append((list(gk.ops), gk.kind))

        unit_of_op = {o: ui for ui, (ops, _) in enumerate(units)
                      for o in ops}
        outputs = set(program.outputs)
        consumers: Dict[str, List[int]] = {}
        for ops, _ in units:
            for o in ops:
                for t in program.nodes[o].inputs:
                    consumers.setdefault(t, []).append(unit_of_op[o])

        calls = []
        for ui, (ops, how) in enumerate(units):
            needed = {o for o in ops
                      if o in outputs
                      or any(c > ui for c in consumers.get(o, ()))}
            if isinstance(how, StreamPass):
                calls.append(_StreamCall(program, how, needed))
            elif how == "block":
                calls.append(_BlockCall(program, ops, needed))
            else:
                calls.append(_JnpCall(program, ops, needed))

        last_use = {t: max(uis) for t, uis in consumers.items()}
        leaves = [nd.name for nd in program.leaves()]

        def fn(feeds):
            import jax.numpy as jnp
            env: Dict[str, Any] = {}
            for leaf in leaves:
                if leaf not in feeds:
                    raise KeyError(f"feeds missing leaf {leaf!r}")
                env[leaf] = jnp.asarray(feeds[leaf])
            for ui, call in enumerate(calls):
                env.update(call(env))
                for t in [t for t, lu in last_use.items() if lu == ui]:
                    if t not in outputs and t in env:
                        del env[t]
            return {o: env[o] for o in program.outputs}
        return fn
