"""The ``pallas`` execution backend: whole-plan single-program executables.

A compiled plan executes as **one jitted device program**: every stream /
block / jnp unit of the execution plan (``core.lowering.plan_execution``)
is traced inside a single ``jax.jit``, so a ``run()`` is exactly one device
dispatch — no per-unit Python driver, no scalar round-trips between
kernels, no per-call ``result_type``/``asarray`` conversion.  The pieces:

* ``stream`` units run ``pl.pallas_call`` with a 1-D grid over row tiles of
  the unit's shared streamed length.  Contraction right-hand sides (and any
  other full-block operands) use a *constant index map*, so Pallas keeps
  them resident in VMEM across every grid step — the execution-level image
  of the plan's explicit-region pins.  Rank-0 dot/norm reductions
  accumulate into a revisited ``(1,)`` output block across the pass;
  *eager* scalars (rank-0 glue whose in-pass inputs are tile-invariant,
  e.g. ``nalpha = -alpha``) are recomputed per tile so tiled ops can read
  them without a pass break; reduction-derived scalar epilogues
  (``beta = rs'/rs``) run once on the final tile.
* CSR SpMV ops (``spmv-stream`` group kernels) run inside stream units as
  row-tiled passes whose *entire* operand — the indptr/indices/data triple
  plus the gathered ``x`` — is VMEM-resident across every tile (constant
  index maps): rows are ragged and column access is data-dependent, so
  only the output vector streams.
* Prefix-sliced SpMV operands (overbooked pins — the pass carries a
  ``core.lowering.ResidentSlice``) instead use a padded per-tile CSR
  layout: the resident row-prefix blocks are held in VMEM across every
  grid step via constant index maps, while each spill-tail tile streams
  only its own ``(1, M)`` entry slice through the grid — per-step work is
  ``O(M)`` instead of a masked scan over all ``nnz`` entries.
* ``block`` units hold whole arrays as single blocks (stencil halos).
* ``jnp`` units — irregular gathers, >2-operand einsums — inline the
  reference rules straight into the trace.
* Adjacent units fused by the residency planner execute as one pass, so
  operands resident across former pass/group boundaries are not
  re-streamed (``core.lowering.fuse_units``).
* When the frontend recorded iteration bodies and
  ``core.lowering.detect_rolled_loop`` proved the scheduled units repeat
  them, the repeated segment runs as ``lax.fori_loop`` over one compiled
  body — ``cg(iters=64)`` traces one iteration, not 64.

Dtype is resolved once per trace from the leaf avals (jit retraces on a
dtype change); feeds are donated to the executable where the backend
supports it (never consuming caller-owned device buffers — those are
copied first); dead intermediates need no runtime ``del``: inside one
traced program, XLA's buffer liveness frees them.

The PR-3 per-unit driver is kept as the ``pallas-perunit`` backend — one
dispatch per unit, runtime freeing — as the A/B baseline TABLE 8 measures
the single-program speedup against.

On CPU (and any non-TPU backend) kernels run with ``interpret=True``, so CI
exercises the real lowering; on TPU they compile through Mosaic with the
grid marked ``arbitrary`` (accumulation makes steps order-dependent).
Override with ``CELLO_PALLAS_INTERPRET=0/1``; donation with
``CELLO_PALLAS_DONATE=0/1``.

Numerics: tiled reductions re-associate the sum (per-tile partials), so
outputs match the ``reference`` backend within the tolerances documented in
``docs/execution_backends.md`` rather than bitwise.  Everything elementwise,
matvec rows, block kernels, and jnp fallbacks use the reference rules
verbatim.
"""
from __future__ import annotations

import os
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from .. import obs
from ..testing import faults
from ..core.lowering import (STREAM_EINSUMS, ExecPlan, GroupKernel,
                             StreamPass, flatten_units, plan_execution,
                             select_group_kernels)
from .base import Executor, plan_groups, plan_program
from .reference import csr_row_ids, eval_node

_TRACES = obs.registry().counter(
    "exec.traces", "jit trace-time Python body executions, per compiled "
    "program (scope label)")
_DISPATCHES = obs.registry().counter(
    "exec.dispatches", "device dispatches, per compiled program "
    "(scope label)")
_DONATED_B = obs.registry().counter(
    "exec.donated_bytes", "leaf feed bytes donated into the executable "
    "(copies of caller-owned device buffers included)", unit="B")
_UNITS = obs.registry().counter(
    "exec.units", "execution units built at compile, by kind "
    "(stream | block | jnp)")

_BACKEND_PROBE: Optional[str] = None


def _default_backend() -> str:
    """``jax.default_backend()``, probed once per process (the probe
    imports jax and touches the platform registry — too slow per call)."""
    global _BACKEND_PROBE
    if _BACKEND_PROBE is None:
        import jax
        _BACKEND_PROBE = jax.default_backend()
    return _BACKEND_PROBE


def _env_flag(name: str) -> Optional[bool]:
    env = os.environ.get(name)
    if env is None or not env.strip():
        return None                      # unset/empty: use the default
    return env.strip().lower() not in ("0", "false", "no")


def use_interpret() -> bool:
    """Interpret Pallas kernels unless we are actually on a TPU (CI and
    laptops exercise the same lowering through the interpreter)."""
    env = _env_flag("CELLO_PALLAS_INTERPRET")
    if env is not None:
        return env
    return _default_backend() != "tpu"


def use_donation() -> bool:
    """Donate leaf feeds into the executable (dead after their last read).
    Off on CPU, where XLA ignores donation and warns."""
    env = _env_flag("CELLO_PALLAS_DONATE")
    if env is not None:
        return env
    return _default_backend() != "cpu"


def _pallas_call_kwargs(interpret: bool) -> Dict[str, Any]:
    if interpret:
        return {"interpret": True}
    from ..kernels._compat import CompilerParams
    # accumulating reductions make grid steps order-dependent: the grid
    # dimension must not be parallelized across cores
    return {"compiler_params": CompilerParams(
        dimension_semantics=("arbitrary",))}


# --------------------------------------------------------------------------
# node classification inside a streaming pass
# --------------------------------------------------------------------------

def _classify_nodes(nodes) -> Dict[str, str]:
    """"tiled" | "reduce" | "eager" | "epilogue" per node of one pass.

    ``eager`` scalars have tile-invariant in-pass inputs and are recomputed
    per tile; ``epilogue`` scalars depend on an in-pass reduction and only
    exist on the final tile.
    """
    classes: Dict[str, str] = {}
    late: Set[str] = set()
    for nd in nodes:
        if nd.op in ("dot", "norm") or (nd.op in ("matmul", "einsum")
                                        and nd.shape == ()):
            classes[nd.name] = "reduce"
            late.add(nd.name)
        elif nd.shape == ():
            if any(t in late for t in nd.inputs):
                classes[nd.name] = "epilogue"
                late.add(nd.name)
            else:
                classes[nd.name] = "eager"
        else:
            classes[nd.name] = "tiled"
    return classes


# --------------------------------------------------------------------------
# kernel builders (one per ExecUnit kind)
# --------------------------------------------------------------------------

class _StreamCall:
    """One tile-streaming ``pl.pallas_call`` for a :class:`StreamPass`.

    With ``defer_finalize=True`` (sharded execution) the kernel emits raw
    per-shard reduction partials and skips the in-kernel scalar finalize
    work: no ``sqrt`` on norm accumulators, no final-tile epilogue — the
    sharded driver combines partials with ``psum`` and replays the scalar
    chain (:attr:`finalize_nodes`) outside the kernel, inside the
    ``shard_map`` trace."""

    def __init__(self, program, sp: StreamPass, needed: Set[str], *,
                 defer_finalize: bool = False,
                 resident_rename: Optional[Dict[str, str]] = None):
        self.nodes = [program.nodes[o] for o in sp.ops]
        self.sp = sp
        self.defer = defer_finalize
        produced = {nd.name for nd in self.nodes}
        shapes = {n: program.nodes[n].shape
                  for nd in self.nodes for n in (*nd.inputs, nd.name)}
        self.shapes = shapes
        self.classes = _classify_nodes(self.nodes)

        stream_in: List[str] = []
        scalar_in: List[str] = []
        # sharded execution renames gathered operands to "<name>@g" view
        # aliases; the pass's resident set must follow those renames
        rename = resident_rename or {}
        res_in = [rename.get(n, n) for n in sp.resident]
        # derived resident inputs: per-entry CSR row ids, computed ONCE
        # per dispatch from indptr (outside the kernel) instead of a
        # searchsorted per grid step; keyed by indptr so spmv ops sharing
        # an operand share one array
        self.derived: Dict[str, Tuple[str, int]] = {}
        self._spmv_rows: Dict[str, str] = {}
        # fractional residency (overbooked pins): prefix-sliced operands
        # are re-arranged into padded per-tile CSR blocks — resident
        # prefix blocks plus streamed spill-tail blocks (``_arrange``)
        self.arranged: Dict[str, Callable] = {}
        self.tail_in: List[str] = []
        self.tail_off: Dict[str, int] = {}
        self.extra_in: List[str] = []
        self._sliced: Dict[str, Dict[str, Any]] = {}
        self._arr_cache: Dict[str, Optional[Dict[str, Any]]] = {}
        slice_of = {}
        for sl in getattr(sp, "slices", ()) or ():
            for t in sl.tensors:
                slice_of[t] = sl

        def _want(name: str, bucket: List[str]):
            if name not in produced and name not in bucket:
                bucket.append(name)

        for nd in self.nodes:
            cls = self.classes[nd.name]
            if cls == "tiled" and nd.op == "spmv":
                sl = slice_of.get(nd.inputs[0])
                am = self._arrange(program, nd, sl) if sl is not None \
                    else None
                if am is not None:
                    self._sliced[nd.name] = am
                    _want(nd.inputs[3], res_in)   # gathered x: resident
                    for t in nd.inputs[:3]:
                        # raw CSR leaves feed the arrangement but never
                        # enter the kernel themselves
                        if t not in self.extra_in:
                            self.extra_in.append(t)
                    for n in am["pre"]:
                        _want(n, res_in)
                    for n in am["tail"]:
                        if n not in self.tail_in:
                            self.tail_in.append(n)
                    continue
                for t in nd.inputs:         # CSR triple + x: all resident
                    _want(t, res_in)
                indptr, indices = nd.inputs[0], nd.inputs[1]
                rows_name = f"{indptr}@rows"
                nnz = shapes[indices][0]
                self.derived[rows_name] = (indptr, nnz)
                self._spmv_rows[nd.name] = rows_name
                shapes[rows_name] = (nnz,)
                _want(rows_name, res_in)
            elif cls == "tiled" and nd.op in ("matmul", "einsum"):
                rhs = STREAM_EINSUMS[nd.param("spec")]
                _want(nd.inputs[1 - rhs], stream_in)
            elif cls == "tiled":
                for t in nd.inputs:
                    _want(t, scalar_in if shapes[t] == () else stream_in)
            elif cls == "reduce":
                for t in nd.inputs:
                    _want(t, stream_in)
            else:                       # eager/epilogue: rank-0 operands
                for t in nd.inputs:
                    _want(t, scalar_in)

        # sliced operands' raw CSR leaves were replaced by arranged
        # blocks; only the arrangement (host side) reads them — keeping
        # the full arrays kernel-resident would defeat the split
        for t in self.extra_in:
            if t in res_in:
                res_in.remove(t)
        self.stream_in, self.res_in, self.scalar_in = \
            stream_in, res_in, scalar_in
        # reductions always need an output block to accumulate into;
        # streamed / scalar values only when read outside this pass
        self.red_out = [nd.name for nd in self.nodes
                        if self.classes[nd.name] == "reduce"]
        self.sca_out = [] if defer_finalize else \
            [nd.name for nd in self.nodes
             if self.classes[nd.name] in ("eager", "epilogue")
             and nd.name in needed]
        self.stream_out = [nd.name for nd in self.nodes
                           if self.classes[nd.name] == "tiled"
                           and nd.name in needed]
        self.needed = needed
        self._built: Dict[Any, Callable] = {}

    @property
    def in_names(self) -> List[str]:
        """External inputs only (derived row-id and arranged per-tile
        arrays are internal; ``extra_in`` raw CSR leaves feed the
        arrangement without entering the kernel)."""
        names = [n for n in self.stream_in + self.tail_in + self.res_in
                 + self.scalar_in
                 if n not in self.derived and n not in self.arranged]
        for n in self.extra_in:
            if n not in names:
                names.append(n)
        return names

    # -- fractional residency (overbooked pins) -------------------------
    def _arrange(self, program, nd, sl) -> Optional[Dict[str, Any]]:
        """Padded per-tile CSR layout for a prefix-sliced spmv operand.

        Tile boundaries are row boundaries, so tile ``t`` owns the entry
        range ``cum[t*tr] .. cum[(t+1)*tr]`` — rows never split across
        tiles and per-row summation order matches the reference rule.
        The gather/mask matrices are *static* (numpy, from the operand's
        build-time ``row_counts`` pattern meta), so arranging at dispatch
        is two fixed-shape gathers; the searchsorted row-id pass of the
        whole-resident kernel disappears entirely.  Returns ``None`` when
        the static pattern meta is unavailable or inconsistent — the op
        then falls back to the whole-resident kernel (correct, unsplit).
        """
        import numpy as np
        ipn, ixn, dvn, _x = nd.inputs
        if ipn in self._arr_cache:
            return self._arr_cache[ipn]
        self._arr_cache[ipn] = None          # default for early bail-outs
        tr, n = self.sp.tile_rows, self.sp.rows
        nnz = self.shapes[ixn][0]
        leaf = program.nodes.get(ipn)
        pattern = leaf.param("pattern") if leaf is not None else None
        if n % tr or nnz <= 0 or pattern is None:
            return None
        from ..frontends.sparse import row_counts
        try:
            counts = row_counts(pattern, n, density=leaf.param("density"),
                                bandwidth=leaf.param("bandwidth"))
        except (TypeError, ValueError):
            return None
        cum = np.concatenate(([0], np.cumsum(counts)))
        if int(cum[-1]) != nnz:
            return None
        n_tiles = n // tr
        bounds = cum[::tr]                   # row-aligned tile starts
        tcnt = bounds[1:] - bounds[:-1]
        budget = -(-max(int(tcnt.max()), 1) // 8) * 8   # lanes % 8 == 0
        pos = bounds[:-1, None] + np.arange(budget)[None, :]
        valid = np.arange(budget)[None, :] < tcnt[:, None]
        gat = np.minimum(pos, nnz - 1).astype(np.int32)
        rows = np.searchsorted(cum, np.minimum(pos, nnz - 1),
                               side="right") - 1
        trow = np.where(valid, rows - (np.arange(n_tiles) * tr)[:, None],
                        0).astype(np.int32)
        # whole tiles covered by the resident row prefix; the boundary
        # tile (partially resident) and everything after it stream
        p = min(sl.rows // tr, n_tiles - 1)

        def _vals(src, g, v, to_compute_dtype):
            def build(env, dt, src=src, g=g, v=v,
                      cast=to_compute_dtype):
                import jax.numpy as jnp
                a = jnp.asarray(env[src])
                if cast:
                    a = jnp.asarray(a, dt)
                return jnp.where(jnp.asarray(v), a[jnp.asarray(g)],
                                 jnp.zeros((), a.dtype))
            return build

        def _const(r):
            def build(env, dt, r=r):
                import jax.numpy as jnp
                return jnp.asarray(r)
            return build

        base = ipn[:-len(".indptr")] if ipn.endswith(".indptr") else ipn
        am: Dict[str, Any] = {"p": p, "budget": budget,
                              "n_tiles": n_tiles, "pre": (), "tail": ()}
        if p > 0:
            pre = (f"{base}@pd", f"{base}@pc", f"{base}@pr")
            self.arranged[pre[0]] = _vals(dvn, gat[:p], valid[:p], True)
            self.arranged[pre[1]] = _vals(ixn, gat[:p], valid[:p], False)
            self.arranged[pre[2]] = _const(trow[:p])
            for nm in pre:
                self.shapes[nm] = (p, budget)
            am["pre"] = pre
        tail = (f"{base}@td", f"{base}@tc", f"{base}@tr")
        self.arranged[tail[0]] = _vals(dvn, gat[p:], valid[p:], True)
        self.arranged[tail[1]] = _vals(ixn, gat[p:], valid[p:], False)
        self.arranged[tail[2]] = _const(trow[p:])
        for nm in tail:
            self.shapes[nm] = (n_tiles - p, budget)
            self.tail_off[nm] = p
        am["tail"] = tail
        self._arr_cache[ipn] = am
        return am

    # -- pallas plumbing ------------------------------------------------
    def _specs(self, dtype):
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl
        tr = self.sp.tile_rows

        def stream_spec(shape):
            if len(shape) == 1:
                return pl.BlockSpec((tr,), lambda i: (i,))
            return pl.BlockSpec((tr,) + shape[1:],
                                lambda i: (i,) + (0,) * (len(shape) - 1))

        def full_spec(shape):
            shape = shape or (1,)            # rank-0 passed as (1,)
            return pl.BlockSpec(shape, lambda i: (0,) * len(shape))

        def tail_spec(shape, off):
            # one padded spill-tail tile per step; prefix steps (i < off)
            # clamp to block 0 — loaded but unread (the kernel's selects
            # pick the resident prefix block instead)
            return pl.BlockSpec(
                (1,) + shape[1:],
                lambda i, off=off: (jnp.maximum(i - off, 0),)
                + (0,) * (len(shape) - 1))

        in_specs = ([stream_spec(self.shapes[n]) for n in self.stream_in]
                    + [tail_spec(self.shapes[n], self.tail_off[n])
                       for n in self.tail_in]
                    + [full_spec(self.shapes[n]) for n in self.res_in]
                    + [full_spec(()) for n in self.scalar_in])
        out_specs, out_shape = [], []
        for n in self.red_out + self.sca_out:
            out_specs.append(full_spec(()))
            out_shape.append(jax.ShapeDtypeStruct((1,), dtype))
        for n in self.stream_out:
            out_specs.append(stream_spec(self.shapes[n]))
            out_shape.append(jax.ShapeDtypeStruct(self.shapes[n], dtype))
        return in_specs, out_specs, out_shape

    def _build(self, dtype):
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        n_tiles = self.sp.rows // self.sp.tile_rows
        tile_rows = self.sp.tile_rows
        nodes, shapes, classes = self.nodes, self.shapes, self.classes
        n_stream, n_tail = len(self.stream_in), len(self.tail_in)
        n_res, n_scal = len(self.res_in), len(self.scalar_in)
        scalar_outs = self.red_out + self.sca_out
        stream_out_set = set(self.stream_out)
        sca_out_set = set(self.sca_out)
        red_set = set(self.red_out)
        epi_nodes = [] if self.defer else \
            [nd for nd in nodes if classes[nd.name] == "epilogue"]
        defer = self.defer

        def kernel(*refs):
            i = pl.program_id(0)
            last = n_tiles - 1
            sref = dict(zip(self.stream_in, refs[:n_stream]))
            tref = dict(zip(self.tail_in,
                            refs[n_stream:n_stream + n_tail]))
            rref = dict(zip(self.res_in,
                            refs[n_stream + n_tail:
                                 n_stream + n_tail + n_res]))
            cref = dict(zip(self.scalar_in,
                            refs[n_stream + n_tail + n_res:
                                 n_stream + n_tail + n_res + n_scal]))
            oref = dict(zip(scalar_outs + self.stream_out,
                            refs[n_stream + n_tail + n_res + n_scal:]))
            tiles: Dict[str, Any] = {}
            scal: Dict[str, Any] = {}

            def stv(name):                      # streamed tile value
                if name not in tiles:
                    tiles[name] = sref[name][...]
                return tiles[name]

            def scv(name):                      # tile-invariant scalar
                if name not in scal:
                    scal[name] = cref[name][0]
                return scal[name]

            def opv(nd, t):                     # tiled-op operand value
                return scv(t) if shapes[t] == () else stv(t)

            for nd in nodes:
                cls = classes[nd.name]
                if cls == "eager":
                    scal[nd.name] = eval_node(
                        nd, [scv(t) for t in nd.inputs])
                elif cls == "tiled":
                    if nd.op == "spmv" and nd.name in self._sliced:
                        val = _spmv_sliced_tile(
                            self._sliced[nd.name], tref, rref,
                            rref[nd.inputs[3]][...], i, tile_rows, dtype)
                    elif nd.op == "spmv":
                        val = _spmv_row_tile(
                            rref[self._spmv_rows[nd.name]][...],
                            rref[nd.inputs[1]][...],
                            rref[nd.inputs[2]][...],
                            rref[nd.inputs[3]][...],
                            i * tile_rows, tile_rows, dtype)
                    elif nd.op in ("matmul", "einsum"):
                        rhs = STREAM_EINSUMS[nd.param("spec")]
                        val = jnp.dot(stv(nd.inputs[1 - rhs]),
                                      rref[nd.inputs[rhs]][...],
                                      preferred_element_type=dtype)
                    else:
                        val = eval_node(nd, [opv(nd, t) for t in nd.inputs])
                    tiles[nd.name] = val
                    if nd.name in stream_out_set:
                        oref[nd.name][...] = val
                elif cls == "reduce":
                    if nd.op == "norm":
                        x = stv(nd.inputs[0])
                        part = jnp.dot(x, x, preferred_element_type=dtype)
                    else:
                        part = jnp.dot(stv(nd.inputs[0]),
                                       stv(nd.inputs[1]),
                                       preferred_element_type=dtype)
                    _accumulate(oref[nd.name], part, i)
                    if nd.op == "norm" and not defer:
                        # deferred: the sqrt applies after the cross-shard
                        # psum, not to this shard's partial
                        _sqrt_at(oref[nd.name], i == last)
            if epi_nodes or sca_out_set:
                @pl.when(i == last)
                def _():
                    vals: Dict[str, Any] = {}

                    def sval(t):
                        if t in vals:
                            return vals[t]
                        if t in red_set:
                            return oref[t][0]
                        if t in scal:
                            return scal[t]
                        return cref[t][0]
                    for nd in epi_nodes:
                        vals[nd.name] = eval_node(
                            nd, [sval(t) for t in nd.inputs])
                    for n in sca_out_set:
                        oref[n][0] = vals[n] if n in vals else scal[n]

        in_specs, out_specs, out_shape = self._specs(dtype)
        return pl.pallas_call(
            kernel, grid=(n_tiles,), in_specs=in_specs,
            out_specs=out_specs, out_shape=out_shape,
            **_pallas_call_kwargs(use_interpret()))

    # -- drivers --------------------------------------------------------
    def apply(self, env: Dict[str, Any], dtype) -> Dict[str, Any]:
        """Run (or trace) this pass over ``env`` at a resolved ``dtype``."""
        import jax.numpy as jnp
        call = self._built.get(dtype)
        if call is None:
            call = self._built[dtype] = self._build(dtype)

        def arr(n):
            b = self.arranged.get(n)
            if b is not None:       # padded per-tile CSR blocks
                return b(env, dtype)
            d = self.derived.get(n)
            if d is not None:       # per-entry CSR row ids, from indptr
                indptr, nnz = d
                return csr_row_ids(jnp.asarray(env[indptr]), nnz)
            v = jnp.asarray(env[n])
            if jnp.issubdtype(v.dtype, jnp.integer):
                return v            # CSR indptr/indices stay integer
            return jnp.asarray(v, dtype)

        args = ([arr(n) for n in self.stream_in]
                + [arr(n) for n in self.tail_in]
                + [arr(n) for n in self.res_in]
                + [jnp.reshape(jnp.asarray(env[n], dtype), (1,))
                   for n in self.scalar_in])
        outs = call(*args)
        names = self.red_out + self.sca_out + self.stream_out
        keep = (self.needed | set(self.red_out)) if self.defer \
            else self.needed
        result = {}
        for n, v in zip(names, outs):
            if n in keep:
                result[n] = v[0] if self.shapes[n] == () else v
        return result

    @property
    def finalize_nodes(self):
        """The scalar (eager + epilogue) nodes a deferring driver must
        replay after combining reduction partials, in pass order."""
        return [nd for nd in self.nodes
                if self.classes[nd.name] in ("eager", "epilogue")]

    @property
    def norm_reductions(self) -> Set[str]:
        """Reduction outputs that are *squared* partials when deferred
        (the sqrt applies after the cross-shard sum)."""
        return {nd.name for nd in self.nodes
                if nd.op == "norm" and self.classes[nd.name] == "reduce"}

    def __call__(self, env: Dict[str, Any]) -> Dict[str, Any]:
        import jax.numpy as jnp
        dtype = jnp.result_type(*(env[n].dtype for n in self.in_names))
        return self.apply(env, dtype)


def _spmv_row_tile(row_of, indices, data, x, row0, tile_rows, dtype):
    """CSR SpMV for the output rows ``[row0, row0 + tile_rows)``.

    The whole CSR operand and ``x`` are VMEM-resident (rows are ragged
    and column access is data-dependent — nothing of the operand
    streams); ``row_of`` is the per-entry row-id array, derived from
    indptr once per dispatch (``csr_row_ids``) rather than per grid
    step.  Each step keeps only its own rows' contributions via a mask
    and a per-tile segment sum, so per-row summation order matches the
    reference rule exactly.
    """
    import jax
    import jax.numpy as jnp
    contrib = (data * jnp.take(x, indices, axis=0)).astype(dtype)
    local = row_of - row0
    in_tile = (local >= 0) & (local < tile_rows)
    return jax.ops.segment_sum(
        jnp.where(in_tile, contrib, jnp.zeros((), dtype)),
        jnp.clip(local, 0, tile_rows - 1), num_segments=tile_rows)


def _spmv_sliced_tile(am, tref, rref, x, i, tile_rows, dtype):
    """CSR SpMV tile for a prefix-sliced (overbooked-pin) operand.

    Entries live in a padded per-tile layout ``(tiles, budget)``: the
    resident row-prefix blocks sit in VMEM across every grid step
    (constant index maps, dynamically indexed by the step id) while
    spill-tail blocks stream one ``(1, budget)`` slice per step.  Tile
    boundaries are row boundaries, so per-row summation order matches
    the reference rule; padding carries ``data == 0`` and contributes
    nothing.  Per-step work is ``O(budget)`` — the whole-resident
    kernel's masked scan over all ``nnz`` entries never happens here.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    td, tc, tw = (tref[n][...][0] for n in am["tail"])
    if am["pre"]:
        j = jnp.minimum(i, am["p"] - 1)
        pd, pc, pw = (pl.load(r_, (pl.dslice(j, 1), slice(None)))[0]
                      for r_ in (rref[n] for n in am["pre"]))
        use_pre = i < am["p"]
        td = jnp.where(use_pre, pd, td)
        tc = jnp.where(use_pre, pc, tc)
        tw = jnp.where(use_pre, pw, tw)
    contrib = (td * jnp.take(x, tc, axis=0)).astype(dtype)
    return jax.ops.segment_sum(contrib, tw, num_segments=tile_rows)


def _accumulate(ref, part, i):
    from jax.experimental import pallas as pl

    @pl.when(i == 0)
    def _():
        ref[0] = part

    @pl.when(i > 0)
    def _():
        ref[0] = ref[0] + part


def _sqrt_at(ref, cond):
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    @pl.when(cond)
    def _():
        ref[0] = jnp.sqrt(ref[0])


def _group_io(program, nodes, needed: Set[str]):
    """(external inputs, needed outputs) for one op group, in op order."""
    produced = {nd.name for nd in nodes}
    in_names: List[str] = []
    for nd in nodes:
        for t in nd.inputs:
            if t not in produced and t not in in_names:
                in_names.append(t)
    return in_names, [nd.name for nd in nodes if nd.name in needed]


class _BlockCall:
    """Whole-array single-block kernel for halo (stencil) groups."""

    def __init__(self, program, ops: Sequence[str], needed: Set[str]):
        self.nodes = [program.nodes[o] for o in ops]
        self.in_names, self.out_names = _group_io(program, self.nodes,
                                                  needed)
        self.shapes = {n: program.nodes[n].shape
                       for nd in self.nodes for n in (*nd.inputs, nd.name)}
        self._built: Dict[Any, Callable] = {}

    def _build(self, dtype):
        import jax
        from jax.experimental import pallas as pl
        n_in = len(self.in_names)

        def kernel(*refs):
            vals = {n: r[...] for n, r in zip(self.in_names, refs[:n_in])}
            for nd in self.nodes:
                vals[nd.name] = eval_node(nd,
                                          [vals[t] for t in nd.inputs])
            for n, r in zip(self.out_names, refs[n_in:]):
                r[...] = vals[n]

        return pl.pallas_call(
            kernel,
            out_shape=[jax.ShapeDtypeStruct(self.shapes[n], dtype)
                       for n in self.out_names],
            **_pallas_call_kwargs(use_interpret()))

    def apply(self, env: Dict[str, Any], dtype) -> Dict[str, Any]:
        import jax.numpy as jnp
        call = self._built.get(dtype)
        if call is None:
            call = self._built[dtype] = self._build(dtype)
        outs = call(*[jnp.asarray(env[n], dtype) for n in self.in_names])
        return dict(zip(self.out_names, outs))

    def __call__(self, env: Dict[str, Any]) -> Dict[str, Any]:
        import jax.numpy as jnp
        dtype = jnp.result_type(*(env[n].dtype for n in self.in_names))
        return self.apply(env, dtype)


class _JnpCall:
    """jax.numpy fallback for one non-streamable group.  Inside a
    single-program trace it inlines straight into the outer jit; driven
    standalone (``pallas-perunit``) it jits itself lazily on first call, so
    compiling a plan never eagerly builds closures for units a rolled loop
    may subsume."""

    def __init__(self, program, ops: Sequence[str], needed: Set[str]):
        self.nodes = [program.nodes[o] for o in ops]
        self.in_names, self.out_names = _group_io(program, self.nodes,
                                                  needed)
        self._fn = None                    # jitted lazily (standalone only)

    def _f(self, *args):
        vals = dict(zip(self.in_names, args))
        for nd in self.nodes:
            vals[nd.name] = eval_node(nd, [vals[t] for t in nd.inputs])
        return tuple(vals[n] for n in self.out_names)

    def apply(self, env: Dict[str, Any], dtype=None) -> Dict[str, Any]:
        outs = self._f(*[env[n] for n in self.in_names])
        return dict(zip(self.out_names, outs))

    def __call__(self, env: Dict[str, Any]) -> Dict[str, Any]:
        if self._fn is None:
            import jax
            self._fn = jax.jit(self._f)
        outs = self._fn(*[env[n] for n in self.in_names])
        return dict(zip(self.out_names, outs))


def _build_call(program, unit, needed: Set[str]):
    if unit.kind == "stream":
        return _StreamCall(program, unit.sp, needed)
    if unit.kind == "block":
        return _BlockCall(program, unit.ops, needed)
    return _JnpCall(program, unit.ops, needed)


# --------------------------------------------------------------------------
# plan plumbing shared by both pallas drivers
# --------------------------------------------------------------------------

def _plan_explicit_bytes(plan) -> int:
    sched = (plan.codesigned.best.schedule
             if plan.codesigned is not None else None)
    return sched.config.explicit_bytes if sched is not None else 0


def _plan_kernels(plan, groups) -> Tuple[GroupKernel, ...]:
    kernels = getattr(plan, "group_kernels", ()) or ()
    if len(kernels) == len(groups):
        return tuple(kernels)
    return select_group_kernels(plan.trace.graph, groups,
                                _plan_explicit_bytes(plan))


def _plan_exec(plan, program, kernels) -> ExecPlan:
    """The plan's carried :class:`ExecPlan` when it matches the kernel
    selection, else a freshly computed one."""
    ep = getattr(plan, "exec_plan", None)
    if ep is not None:
        flat = [o for u in ep.units for o in u.ops]
        if flat == [o for gk in kernels for o in gk.ops]:
            return ep
    return plan_execution(plan.trace.graph, kernels,
                          _plan_explicit_bytes(plan), program=program)


def _unit_needed(program, units
                 ) -> Tuple[List[Set[str]], Dict[str, List[int]]]:
    """Per-unit "read outside this unit" sets over the straight-line unit
    sequence (program outputs always count), plus the tensor -> consuming
    unit indices map they were derived from."""
    outputs = set(program.outputs)
    consumers: Dict[str, List[int]] = {}
    for ui, unit in enumerate(units):
        for o in unit.ops:
            for t in program.nodes[o].inputs:
                consumers.setdefault(t, []).append(ui)
    needed = [{o for o in unit.ops
               if o in outputs or any(c > ui for c in consumers.get(o, ()))}
              for ui, unit in enumerate(units)]
    return needed, consumers


# --------------------------------------------------------------------------
# the single-program executable
# --------------------------------------------------------------------------

class _SingleProgram:
    """One whole-plan jitted executable: ``feeds -> {output: value}``.

    All units trace inside a single ``jax.jit``; a detected rolled loop
    runs as ``lax.fori_loop`` over the template body's calls.  ``stats``
    counts traces (Python body executions under jit) and device dispatches
    (calls of the one jitted function) — the one-dispatch guarantee is
    ``dispatches == runs`` with ``traces`` staying at 1 per dtype.
    """

    def __init__(self, plan):
        program = plan_program(plan)
        groups = plan_groups(plan)
        kernels = _plan_kernels(plan, groups)
        ep = _plan_exec(plan, program, kernels)
        self.exec_plan = ep
        units, roll = ep.units, ep.roll
        needed, _ = _unit_needed(program, units)
        if roll is not None:
            # loop-carried values must leave their kernels even when the
            # straight-line view says nothing later reads them
            updates = {sl.update for sl in roll.slots}
            inits = {sl.init for sl in roll.slots if sl.init is not None}
            for ui in range(roll.first, roll.first + roll.per_iter):
                needed[ui] = needed[ui] | (updates & set(units[ui].ops))
            for ui in range(roll.first):
                needed[ui] = needed[ui] | (inits & set(units[ui].ops))
            pro = range(roll.first)
            tmpl = range(roll.first, roll.first + roll.per_iter)
            epi = range(roll.stop, len(units))
        else:
            pro, tmpl, epi = range(len(units)), (), ()
        self._pro = [_build_call(program, units[i], needed[i]) for i in pro]
        self._tmpl = [_build_call(program, units[i], needed[i])
                      for i in tmpl]
        self._epi = [_build_call(program, units[i], needed[i]) for i in epi]
        self.roll = roll
        self.leaf_names = [nd.name for nd in program.leaves()]
        self.out_names = list(program.outputs)
        # counters live on the global registry under this program's unique
        # scope label, so per-program exactness survives sharing one
        # registry definition across every compiled program
        self._scope = obs.next_scope("pallas")
        for i in (*pro, *tmpl, *epi):
            _UNITS.inc(backend="pallas", kind=units[i].kind,
                       scope=self._scope)

        if roll is not None:
            tmpl_ops = {o for i in tmpl for o in units[i].ops}
            reads = {sl.read for sl in roll.slots if sl.read is not None}
            ext: List[str] = []
            for call in self._tmpl:
                for n in call.in_names:
                    if n not in tmpl_ops and n not in reads \
                            and n not in ext:
                        ext.append(n)
            # detect_rolled_loop guarantees every carry update is produced
            # by the template (it bails out otherwise)
            assert all(sl.update in tmpl_ops for sl in roll.slots)
            self._tmpl_ext = ext
            self._slot_shapes = [program.nodes[sl.update].shape
                                 for sl in roll.slots]

        self._donate = use_donation()
        # every leaf dies inside the program (outputs are op-produced)
        self.donate_argnums = tuple(range(len(self.leaf_names)))
        import jax
        kwargs = ({"donate_argnums": self.donate_argnums}
                  if self._donate else {})
        self._jit = jax.jit(self._traced, **kwargs)

    @property
    def stats(self) -> Dict[str, int]:
        """This program's counters, read back from the obs registry
        (``{"traces": ..., "dispatches": ...}``, dict-comparable)."""
        return {
            "traces": int(_TRACES.value(backend="pallas",
                                        scope=self._scope)),
            "dispatches": int(_DISPATCHES.value(backend="pallas",
                                                scope=self._scope)),
        }

    # -- the traced program --------------------------------------------
    def _traced(self, *leaf_vals):
        import jax.numpy as jnp
        _TRACES.inc(backend="pallas", scope=self._scope)
        float_dts = [v.dtype for v in leaf_vals
                     if jnp.issubdtype(v.dtype, jnp.floating)]
        # dtype resolved once per trace from the leaf avals; integer
        # leaves (gather indices) keep their own dtype
        dtype = jnp.result_type(*float_dts) if float_dts else jnp.float32
        env: Dict[str, Any] = {}
        for name, v in zip(self.leaf_names, leaf_vals):
            env[name] = (jnp.asarray(v, dtype)
                         if jnp.issubdtype(v.dtype, jnp.floating) else v)
        for call in self._pro:
            env.update(call.apply(env, dtype))
        if self.roll is not None:
            from jax import lax
            slots = self.roll.slots
            base = {n: env[n] for n in self._tmpl_ext}

            def body(_, carry):
                env_l = dict(base)
                for sl, v in zip(slots, carry):
                    if sl.read is not None:
                        env_l[sl.read] = v
                for call in self._tmpl:
                    env_l.update(call.apply(env_l, dtype))
                return tuple(env_l[sl.update] for sl in slots)

            # output-only slots (init=None) seed with zeros: their carry-in
            # is never read, only their final generation leaves the loop
            carry = tuple(
                env[sl.init] if sl.init is not None
                else jnp.zeros(shape, dtype)
                for sl, shape in zip(slots, self._slot_shapes))
            carry = lax.fori_loop(0, self.roll.n_iters, body, carry)
            for sl, v in zip(slots, carry):
                env[sl.final] = v
        for call in self._epi:
            env.update(call.apply(env, dtype))
        # no runtime freeing: inside one traced program, XLA buffer
        # liveness retires dead intermediates
        return tuple(env[o] for o in self.out_names)

    # -- the pure core (serve.BatchedPlan batches through this) ---------
    def pure(self, feeds: Dict[str, Any]) -> Dict[str, Any]:
        """The traced program as a pure ``feeds -> {output: value}``
        callable: no donation, no dispatch counting, no outer jit — safe
        to compose under a caller's ``jax.jit`` / ``jax.vmap``
        (:meth:`Executor.compile_pure`).  ``stats["traces"]`` still counts
        Python body executions (a trace-time-only side effect), so batched
        wrappers can assert they retrace only per (batch size, dtype)."""
        for leaf in self.leaf_names:
            if leaf not in feeds:
                raise KeyError(f"feeds missing leaf {leaf!r}")
        outs = self._traced(*[feeds[n] for n in self.leaf_names])
        return dict(zip(self.out_names, outs))

    # -- the dispatch ---------------------------------------------------
    def __call__(self, feeds: Dict[str, Any]) -> Dict[str, Any]:
        args = []
        donated = 0
        for leaf in self.leaf_names:
            if leaf not in feeds:
                raise KeyError(f"feeds missing leaf {leaf!r}")
            v = feeds[leaf]
            if self._donate:
                import jax
                import jax.numpy as jnp
                if isinstance(v, jax.Array):
                    # donation must never consume a caller-owned buffer
                    v = jnp.array(v, copy=True)
                donated += int(getattr(v, "nbytes", 0) or 0)
            args.append(v)
        _DISPATCHES.inc(backend="pallas", scope=self._scope)
        if donated:
            _DONATED_B.inc(donated, backend="pallas", scope=self._scope)
        outs = self._jit(*args)
        return dict(zip(self.out_names, outs))


# --------------------------------------------------------------------------
# the executors
# --------------------------------------------------------------------------

class PallasExecutor(Executor):
    """Compile the whole plan into one jitted single-program executable."""

    name = "pallas"

    def compile(self, plan) -> "_SingleProgram":
        # fault-injection site (docs/robustness.md): exec.compile@pallas —
        # here as well as in the memoized run() driver, because
        # serve.BatchedPlan compiles through compile/compile_pure directly
        faults.check("exec.compile", backend=self.name)
        sharded = getattr(plan, "sharded", None)
        if sharded is not None and sharded.n_shards > 1:
            from .sharded import ShardedProgram
            return ShardedProgram(plan)
        return _SingleProgram(plan)

    def compile_pure(self, plan):
        faults.check("exec.compile", backend=self.name)
        sharded = getattr(plan, "sharded", None)
        if sharded is not None and sharded.n_shards > 1:
            raise ValueError(
                "mesh-sharded plans have no pure (vmap-composable) core; "
                "serve/batch them unsharded or run() them directly")
        # the single program's traced core, without the dispatch driver
        # (donation, counters, its own jit): composable under vmap
        return _SingleProgram(plan).pure


class PerUnitPallasExecutor(Executor):
    """The PR-3 driver: one dispatch per execution unit, runtime freeing.

    Kept as the measured A/B baseline for the single-program executable
    (TABLE 8) and as a debugging surface — each unit can be inspected in
    isolation.  Uses the *unfused* unit sequence: no cross-pass residency,
    no rolled loops.
    """

    name = "pallas-perunit"

    def compile(self, plan):
        program = plan_program(plan)
        groups = plan_groups(plan)
        kernels = _plan_kernels(plan, groups)
        units = flatten_units(kernels)
        needed, consumers = _unit_needed(program, units)
        calls = [_build_call(program, units[ui], needed[ui])
                 for ui in range(len(units))]
        scope = obs.next_scope("perunit")
        for unit in units:
            _UNITS.inc(backend=self.name, kind=unit.kind, scope=scope)

        outputs = set(program.outputs)
        last_use = {t: max(uis) for t, uis in consumers.items()}
        leaves = [nd.name for nd in program.leaves()]

        def fn(feeds):
            import jax.numpy as jnp
            env: Dict[str, Any] = {}
            for leaf in leaves:
                if leaf not in feeds:
                    raise KeyError(f"feeds missing leaf {leaf!r}")
                env[leaf] = jnp.asarray(feeds[leaf])
            for ui, call in enumerate(calls):
                env.update(call(env))
                for t in [t for t, lu in last_use.items() if lu == ui]:
                    if t not in outputs and t in env:
                        del env[t]
            return {o: env[o] for o in program.outputs}
        return fn
