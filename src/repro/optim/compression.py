"""Gradient compression (int8 with error feedback) for slow cross-pod links.

Within a pod, gradients reduce over fast ICI at full precision (implicit in
the sharded backward).  Across pods, the link is the bottleneck collective:
quantising to int8 cuts that traffic 4× (bf16→int8 plus a per-tensor f32
scale).  Error feedback accumulates the quantisation residual locally and
re-injects it next step, which preserves convergence (Karimireddy et al.
style) — `tests/test_optim.py` checks the residual-correction property.

`error_feedback_compress` is pure (pytree → pytree) so it can be applied
inside a shard_map over the "pod" axis; `launch.train` wires it in when
``compress_cross_pod`` is enabled.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass
class CompressionState:
    error: PyTree          # residual feedback buffer, same structure as grads

    @staticmethod
    def init(grads_like: PyTree) -> "CompressionState":
        return CompressionState(error=jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compress_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantisation. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def error_feedback_compress(grads: PyTree, state: CompressionState
                            ) -> Tuple[PyTree, PyTree, CompressionState]:
    """Quantise (grads + carried error); return (q_tree, scale_tree, state').

    The caller reduces (q * scale) across pods, then calls nothing else —
    decompression is `decompress_int8` leaf-wise.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = compress_int8(corrected)
        new_e = corrected - decompress_int8(q, scale)
        return (q, scale, new_e)

    triples = jax.tree.map(one, grads, state.error)
    q_tree = jax.tree.map(lambda t: t[0], triples,
                          is_leaf=lambda x: isinstance(x, tuple))
    s_tree = jax.tree.map(lambda t: t[1], triples,
                          is_leaf=lambda x: isinstance(x, tuple))
    e_tree = jax.tree.map(lambda t: t[2], triples,
                          is_leaf=lambda x: isinstance(x, tuple))
    return q_tree, s_tree, CompressionState(error=e_tree)
