from .adamw import (AdamWConfig, adamw_init, adamw_update, cosine_lr,
                    global_norm, zero1_pspecs)
from .compression import (CompressionState, compress_int8, decompress_int8,
                          error_feedback_compress)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr",
           "global_norm", "zero1_pspecs", "CompressionState",
           "compress_int8", "decompress_int8", "error_feedback_compress"]
