"""AdamW with ZeRO-1 optimizer-state sharding.

Plain-pytree implementation (no optax dependency): f32 moments, decoupled
weight decay, global-norm clipping, cosine schedule with linear warmup.

ZeRO-1: the (m, v) moments are additionally sharded along the *data* mesh
axis — `zero1_pspecs` rewrites each parameter's PartitionSpec by placing the
data axis on the first dimension that is (a) currently unsharded and (b)
divisible by the data-axis size.  Parameters and gradients keep their
original (TP) sharding; only optimizer state pays the extra partition, which
is what ZeRO stage 1 means.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params: PyTree) -> Dict[str, PyTree]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads: PyTree, state: Dict[str, PyTree],
                 params: PyTree) -> Tuple[PyTree, Dict[str, PyTree], Dict]:
    count = state["count"] + 1
    lr = cosine_lr(cfg, count.astype(jnp.float32))
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * g * g
        mh = m / (1 - cfg.beta1 ** count.astype(jnp.float32))
        vh = v / (1 - cfg.beta2 ** count.astype(jnp.float32))
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (step_ + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        pn, mn, vn = upd(g, m, v, p)
        new_p.append(pn)
        new_m.append(mn)
        new_v.append(vn)
    params = jax.tree.unflatten(treedef, new_p)
    state = {"m": jax.tree.unflatten(treedef, new_m),
             "v": jax.tree.unflatten(treedef, new_v),
             "count": count}
    return params, state, {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# ZeRO-1 sharding of optimizer state
# ---------------------------------------------------------------------------

def _zero1_spec(spec: Tuple, shape: Tuple[int, ...],
                data_size: int, data_axes) -> Tuple:
    spec = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = list(spec)
    for i, (ax, dim) in enumerate(zip(spec, shape)):
        if ax is None and dim % data_size == 0 and dim >= data_size:
            out[i] = data_axes
            break
    return tuple(out)


def zero1_pspecs(param_pspecs: PyTree, param_shapes: PyTree,
                 data_size: int, data_axes="data") -> PyTree:
    """Moment pspecs: param pspecs with the data axis added on the first
    divisible unsharded dim (falls back to the param spec when none fits)."""
    def one(spec, shaped):
        return _zero1_spec(tuple(spec), tuple(shaped.shape), data_size,
                           data_axes)
    return jax.tree.map(one, param_pspecs, param_shapes,
                        is_leaf=lambda x: isinstance(x, tuple))
