"""Span tracer: nested wall-clock spans, exportable as JSONL and Chrome
``trace_event`` JSON (loadable in Perfetto / ``chrome://tracing``).

A span is one timed region with metadata::

    tr = default_tracer()
    with tr.span("session.codesign", arch="hpc:cg"):
        ...

Spans nest per thread (a thread-local stack tracks depth), so one
instrumented ``Session`` run yields the pipeline shape directly:
``session.trace`` → ``session.analyze`` → ``session.codesign`` (with
per-search-pass children) → ``session.lower`` → ``exec.compile`` /
``exec.dispatch``.

Disabled is the default and costs one method call per span site: ``span()``
returns a shared no-op context manager, allocates nothing, and records
nothing (the <2% overhead policy in ``docs/observability.md``).  Enable via
:func:`SpanTracer.enable`, ``repro.obs.enable()``, or the ``CELLO_OBS``
environment variable.

Export schema (documented contract — ``scripts/obs_report.py --validate``
and the CI ``obs-smoke`` job check it):

* **JSONL** — one JSON object per line with exactly the keys
  ``name`` (str), ``ts_us`` (float, µs since tracer start), ``dur_us``
  (float ≥ 0), ``tid`` (int), ``pid`` (int), ``depth`` (int ≥ 0) and
  ``args`` (object).
* **Chrome** — ``{"displayTimeUnit": "ms", "traceEvents": [...]}`` where
  every event is a complete-duration event: ``ph == "X"`` with ``name``,
  ``ts``/``dur`` (µs), ``pid``, ``tid``, and the span metadata under
  ``args``.

An opt-in ``jax.profiler`` hook mirrors every span into a
``jax.profiler.TraceAnnotation``, so CELLO pipeline stages line up with XLA
events inside a device profile.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = [
    "SpanTracer", "default_tracer", "JSONL_KEYS",
    "load_jsonl", "validate_jsonl", "validate_chrome",
]

#: exactly the keys every exported JSONL span carries
JSONL_KEYS = ("name", "ts_us", "dur_us", "tid", "pid", "depth", "args")


class _NullSpan:
    """The shared disabled-path context manager: no state, no allocation."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def annotate(self, **kv) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records itself on exit."""

    __slots__ = ("tracer", "name", "args", "_t0", "_depth", "_jax_ctx")

    def __init__(self, tracer: "SpanTracer", name: str,
                 args: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.args = args
        self._t0 = 0.0
        self._depth = 0
        self._jax_ctx = None

    def __enter__(self):
        tr = self.tracer
        stack = tr._stack()
        self._depth = len(stack)
        stack.append(self)
        if tr.jax_profiler:
            self._jax_ctx = tr._jax_annotation(self.name)
            if self._jax_ctx is not None:
                self._jax_ctx.__enter__()
        self._t0 = time.perf_counter()
        return self

    def annotate(self, **kv) -> "_Span":
        """Attach metadata discovered mid-span (cache hit, batch size)."""
        self.args.update(kv)
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        if self._jax_ctx is not None:
            self._jax_ctx.__exit__(*exc)
        tr = self.tracer
        stack = tr._stack()
        if stack and stack[-1] is self:
            stack.pop()
        tr._record(self.name, self._t0 - tr._epoch, t1 - self._t0,
                   self._depth, self.args)
        return False


class SpanTracer:
    """Collects spans from every thread; exports JSONL / Chrome JSON."""

    def __init__(self, enabled: bool = False, *,
                 jax_profiler: bool = False):
        self.enabled = enabled
        self.jax_profiler = jax_profiler
        self._epoch = time.perf_counter()
        self._epoch_unix = time.time()
        self._records: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        self._local = threading.local()

    # -- span API --------------------------------------------------------
    def span(self, name: str, **args):
        """A nested timed region.  Disabled tracers return a shared no-op
        context manager (identity-stable — the zero-overhead path)."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, args)

    def record(self, name: str, start_s: float, dur_s: float, *,
               depth: Optional[int] = None, **args) -> None:
        """Record a synthetic (already-timed) span.  ``start_s`` is tracer
        time (:meth:`now`).  Used where real intervals are not observable —
        e.g. the lazily-streamed search passes report aggregate self-time."""
        if not self.enabled:
            return
        if depth is None:
            depth = len(self._stack())
        self._record(name, start_s, max(dur_s, 0.0), depth, args)

    def now(self) -> float:
        """Seconds since this tracer's epoch (span timestamps' timebase)."""
        return time.perf_counter() - self._epoch

    # -- lifecycle -------------------------------------------------------
    def enable(self, *, jax_profiler: Optional[bool] = None) -> "SpanTracer":
        self.enabled = True
        if jax_profiler is not None:
            self.jax_profiler = jax_profiler
        return self

    def disable(self) -> "SpanTracer":
        self.enabled = False
        return self

    def clear(self) -> None:
        with self._lock:
            self._records.clear()

    # -- internals -------------------------------------------------------
    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, name: str, start_s: float, dur_s: float, depth: int,
                args: Dict[str, Any]) -> None:
        rec = {
            "name": name,
            "ts_us": start_s * 1e6,
            "dur_us": dur_s * 1e6,
            "tid": threading.get_ident(),
            "pid": os.getpid(),
            "depth": depth,
            "args": _jsonable(args),
        }
        with self._lock:
            self._records.append(rec)

    @staticmethod
    def _jax_annotation(name: str):
        try:
            from jax.profiler import TraceAnnotation
        except Exception:                                # pragma: no cover
            return None
        return TraceAnnotation(name)

    # -- export ----------------------------------------------------------
    def spans(self) -> List[Dict[str, Any]]:
        """A time-ordered copy of every recorded span."""
        with self._lock:
            return sorted(self._records, key=lambda r: r["ts_us"])

    def export_jsonl(self, path: os.PathLike) -> int:
        """Write one JSON object per span (schema: :data:`JSONL_KEYS`).
        Returns the number of spans written."""
        spans = self.spans()
        with open(path, "w") as f:
            for rec in spans:
                f.write(json.dumps(rec, sort_keys=True) + "\n")
        return len(spans)

    def to_chrome(self) -> Dict[str, Any]:
        """Chrome ``trace_event`` JSON (complete ``"X"`` events; nesting is
        implied by interval containment per tid, which the per-thread span
        stack guarantees)."""
        events = []
        for rec in self.spans():
            events.append({
                "name": rec["name"],
                "ph": "X",
                "ts": rec["ts_us"],
                "dur": rec["dur_us"],
                "pid": rec["pid"],
                "tid": rec["tid"],
                "cat": rec["name"].split(".", 1)[0],
                "args": rec["args"],
            })
        return {"displayTimeUnit": "ms", "traceEvents": events,
                "otherData": {"unix_epoch_s": self._epoch_unix}}

    def export_chrome(self, path: os.PathLike) -> int:
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f, sort_keys=True)
        return len(doc["traceEvents"])


_DEFAULT = SpanTracer()


def default_tracer() -> SpanTracer:
    """The process-global tracer every instrumented layer emits to."""
    return _DEFAULT


# --------------------------------------------------------------------------
# schema validation (the documented export contract; CI's obs-smoke gate)
# --------------------------------------------------------------------------

def load_jsonl(path: os.PathLike) -> List[Dict[str, Any]]:
    spans = []
    with open(path) as f:
        for line in f:
            if line.strip():
                spans.append(json.loads(line))
    return spans


def _check_span(rec: Dict[str, Any], where: str) -> None:
    if not isinstance(rec, dict):
        raise ValueError(f"{where}: span is not an object")
    missing = [k for k in JSONL_KEYS if k not in rec]
    if missing:
        raise ValueError(f"{where}: missing keys {missing}")
    extra = sorted(set(rec) - set(JSONL_KEYS))
    if extra:
        raise ValueError(f"{where}: unexpected keys {extra}")
    if not isinstance(rec["name"], str) or not rec["name"]:
        raise ValueError(f"{where}: name must be a non-empty string")
    for k in ("ts_us", "dur_us"):
        if not isinstance(rec[k], (int, float)) or rec[k] < 0:
            raise ValueError(f"{where}: {k} must be a number >= 0")
    for k in ("tid", "pid", "depth"):
        if not isinstance(rec[k], int) or rec[k] < 0:
            raise ValueError(f"{where}: {k} must be an int >= 0")
    if not isinstance(rec["args"], dict):
        raise ValueError(f"{where}: args must be an object")


def validate_jsonl(path: os.PathLike) -> int:
    """Check a JSONL span export against the documented schema.  Returns
    the span count; raises ``ValueError`` on the first violation."""
    spans = load_jsonl(path)
    for i, rec in enumerate(spans):
        _check_span(rec, f"{path}:{i + 1}")
    return len(spans)


def validate_chrome(path: os.PathLike) -> int:
    """Check a Chrome ``trace_event`` export: a ``traceEvents`` list of
    complete (``ph == "X"``) events with µs timestamps.  Returns the event
    count; raises ``ValueError`` on the first violation."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError(f"{path}: not a trace_event document "
                         "(no traceEvents key)")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError(f"{path}: traceEvents must be a list")
    for i, ev in enumerate(events):
        where = f"{path}: event {i}"
        if not isinstance(ev, dict):
            raise ValueError(f"{where}: not an object")
        if ev.get("ph") != "X":
            raise ValueError(f"{where}: ph must be 'X' (complete event), "
                             f"got {ev.get('ph')!r}")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            raise ValueError(f"{where}: name must be a non-empty string")
        for k in ("ts", "dur"):
            if not isinstance(ev.get(k), (int, float)) or ev[k] < 0:
                raise ValueError(f"{where}: {k} must be a number >= 0")
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                raise ValueError(f"{where}: {k} must be an int")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"{where}: args must be an object")
    return len(events)


def _jsonable(args: Dict[str, Any]) -> Dict[str, Any]:
    """Span metadata must serialize: keep JSON scalars, repr the rest."""
    out = {}
    for k, v in args.items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[k] = v
        else:
            out[k] = repr(v)
    return out
