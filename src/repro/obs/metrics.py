"""Thread-safe metrics registry: labeled counters, gauges, and streaming
histograms with quantile export.

The registry is the single home for every runtime measurement the toolchain
emits — pipeline stage counters, executor dispatch/trace counts, serving
latency histograms.  One process-global default registry
(:func:`default_registry`) backs the instrumented layers; each instrument is
**defined exactly once** per (registry, name) — re-requesting the same name
returns the same instrument object, and requesting it with a different kind
raises.

Per-component exactness (a test asserting "this compiled plan dispatched
exactly twice") comes from **scope labels**: each instrumented object takes
a unique scope id (:func:`next_scope`) and reads back only its own label
cells, so two servers (or two compiled plans) in one process never alias
each other's counts while still sharing one registry definition.

Concurrency: one lock per registry guards every write *and*
:meth:`MetricsRegistry.snapshot`, so a snapshot is a consistent point-in-time
copy — no counter in it can be mid-update, and two counters bumped under an
outer caller lock (the serving layer does this) can never be observed torn.

Histograms are streaming: observations land in logarithmic buckets
(growth factor ``HIST_GROWTH``), so quantiles (p50/p90/p99) are estimated
within a documented relative error of ±5% (``HIST_REL_ERROR``) at O(1)
memory per distinct magnitude; exact ``count``/``sum``/``min``/``max`` ride
along, and quantile estimates are clamped into ``[min, max]``.
"""
from __future__ import annotations

import itertools
import math
import threading
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_registry", "next_scope", "HIST_GROWTH", "HIST_REL_ERROR",
]

#: log-bucket growth factor for streaming histograms
HIST_GROWTH = 1.1
#: documented relative quantile error bound: sqrt(growth) - 1 (~4.9%)
HIST_REL_ERROR = math.sqrt(HIST_GROWTH) - 1.0

_LOG_G = math.log(HIST_GROWTH)

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


# --------------------------------------------------------------------------
# instruments
# --------------------------------------------------------------------------

class _Instrument:
    """Base: a named metric with labeled cells, bound to one registry."""

    kind = "base"

    def __init__(self, registry: "MetricsRegistry", name: str,
                 help: str = "", unit: str = ""):
        self.registry = registry
        self.name = name
        self.help = help
        self.unit = unit
        self._lock = registry._lock
        self._cells: Dict[LabelKey, Any] = {}

    def _new_cell(self):
        raise NotImplementedError

    def _cell(self, labels: Dict[str, Any]):
        key = _label_key(labels)
        with self._lock:
            cell = self._cells.get(key)
            if cell is None:
                cell = self._cells[key] = self._new_cell()
            return cell

    def labels(self, **labels):
        """The bound cell for one label set (created on first use)."""
        return self._cell(labels)

    def __repr__(self) -> str:
        return (f"{type(self).__name__}({self.name!r}, "
                f"{len(self._cells)} cell(s))")


class _CounterCell:
    """Monotonic float cell; ``inc`` is atomic under the registry lock."""

    __slots__ = ("_lock", "_value")

    def __init__(self, lock):
        self._lock = lock
        self._value = 0.0

    def inc(self, value: float = 1.0) -> None:
        if value < 0:
            raise ValueError(f"counters only go up; inc({value})")
        with self._lock:
            self._value += value

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Counter(_Instrument):
    """A monotonically increasing labeled count (requests, dispatches,
    cache hits, bytes)."""

    kind = "counter"

    def _new_cell(self):
        return _CounterCell(self._lock)

    def inc(self, value: float = 1.0, **labels) -> None:
        self._cell(labels).inc(value)

    def value(self, **labels) -> float:
        return self._cell(labels).value


class _GaugeCell:
    __slots__ = ("_lock", "_value")

    def __init__(self, lock):
        self._lock = lock
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def add(self, delta: float) -> None:
        with self._lock:
            self._value += delta

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge(_Instrument):
    """A labeled point-in-time level (queue depth, resident plans)."""

    kind = "gauge"

    def _new_cell(self):
        return _GaugeCell(self._lock)

    def set(self, value: float, **labels) -> None:
        self._cell(labels).set(value)

    def add(self, delta: float, **labels) -> None:
        self._cell(labels).add(delta)

    def value(self, **labels) -> float:
        return self._cell(labels).value


class _HistogramCell:
    """Streaming log-bucket histogram cell.

    Positive observations land in bucket ``floor(log(x) / log(growth))``;
    zero/negative observations land in a dedicated underflow bucket (they
    represent "no elapsed time" for the duration histograms this backs).
    Quantiles interpolate at the bucket's geometric midpoint and are
    clamped into the exact observed ``[min, max]``.
    """

    __slots__ = ("_lock", "count", "sum", "min", "max", "_buckets",
                 "_underflow")

    def __init__(self, lock):
        self._lock = lock
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._buckets: Dict[int, int] = {}
        self._underflow = 0

    def observe(self, x: float) -> None:
        x = float(x)
        with self._lock:
            self.count += 1
            self.sum += x
            if x < self.min:
                self.min = x
            if x > self.max:
                self.max = x
            if x > 0.0:
                idx = math.floor(math.log(x) / _LOG_G)
                self._buckets[idx] = self._buckets.get(idx, 0) + 1
            else:
                self._underflow += 1

    # -- quantiles (call with the lock held or on a snapshot copy) -------
    def _quantile_locked(self, q: float) -> Optional[float]:
        if self.count == 0:
            return None
        # nearest-rank over the cumulative bucket counts
        rank = max(1, math.ceil(q * self.count))
        seen = self._underflow
        if rank <= seen:
            return max(self.min, 0.0) if self.min != math.inf else 0.0
        for idx in sorted(self._buckets):
            seen += self._buckets[idx]
            if rank <= seen:
                mid = math.exp((idx + 0.5) * _LOG_G)
                return min(max(mid, self.min), self.max)
        return self.max

    def quantile(self, q: float) -> Optional[float]:
        """Estimated q-quantile (``0 <= q <= 1``), within
        ±\\ :data:`HIST_REL_ERROR` relative error of the sample quantile."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile q must be in [0, 1], got {q}")
        with self._lock:
            return self._quantile_locked(q)

    def summary(self) -> Dict[str, Any]:
        with self._lock:
            if self.count == 0:
                return {"count": 0, "sum": 0.0, "mean": None, "min": None,
                        "max": None, "p50": None, "p90": None, "p99": None}
            return {
                "count": self.count,
                "sum": self.sum,
                "mean": self.sum / self.count,
                "min": self.min,
                "max": self.max,
                "p50": self._quantile_locked(0.50),
                "p90": self._quantile_locked(0.90),
                "p99": self._quantile_locked(0.99),
            }


class Histogram(_Instrument):
    """A labeled streaming distribution (latencies, sizes, bytes) with
    p50/p90/p99 export — see :class:`_HistogramCell` for the bucket math."""

    kind = "histogram"

    def _new_cell(self):
        return _HistogramCell(self._lock)

    def observe(self, x: float, **labels) -> None:
        self._cell(labels).observe(x)

    def quantile(self, q: float, **labels) -> Optional[float]:
        return self._cell(labels).quantile(q)

    def summary(self, **labels) -> Dict[str, Any]:
        return self._cell(labels).summary()


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


# --------------------------------------------------------------------------
# the registry
# --------------------------------------------------------------------------

class MetricsRegistry:
    """Name → instrument, with one lock guarding every write and snapshot.

    ``counter`` / ``gauge`` / ``histogram`` are get-or-define: the first
    call for a name defines the instrument, later calls return the same
    object (the "defined exactly once" contract); asking for an existing
    name with a different kind raises ``TypeError``.
    """

    def __init__(self):
        # RLock: instrument writes happen under callbacks that may already
        # hold the lock through snapshot() helpers
        self._lock = threading.RLock()
        self._instruments: Dict[str, _Instrument] = {}

    def _get_or_define(self, kind: str, name: str, help: str, unit: str):
        cls = _KINDS[kind]
        with self._lock:
            inst = self._instruments.get(name)
            if inst is not None:
                if inst.kind != kind:
                    raise TypeError(
                        f"metric {name!r} already defined as {inst.kind}, "
                        f"cannot redefine as {kind}")
                return inst
            inst = cls(self, name, help=help, unit=unit)
            self._instruments[name] = inst
            return inst

    def counter(self, name: str, help: str = "", unit: str = "") -> Counter:
        return self._get_or_define("counter", name, help, unit)

    def gauge(self, name: str, help: str = "", unit: str = "") -> Gauge:
        return self._get_or_define("gauge", name, help, unit)

    def histogram(self, name: str, help: str = "",
                  unit: str = "") -> Histogram:
        return self._get_or_define("histogram", name, help, unit)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._instruments)

    # -- consistent export ----------------------------------------------
    def snapshot(self, scope: Optional[str] = None) -> Dict[str, Any]:
        """One consistent point-in-time copy of every instrument.

        The whole copy happens under the registry lock, so no cell is
        mid-update and counters bumped together under a caller's outer
        lock appear together.  ``scope`` filters to cells whose ``scope``
        label matches (instruments with no matching cell are dropped).
        Returns plain JSON-serializable data::

            {name: {"kind": ..., "help": ..., "unit": ...,
                    "cells": [{"labels": {...}, "value": ...}      # counter
                              {"labels": {...}, "value": {...}}]}} # histogram
        """
        with self._lock:
            out: Dict[str, Any] = {}
            for name in sorted(self._instruments):
                inst = self._instruments[name]
                cells = []
                for key, cell in sorted(inst._cells.items()):
                    labels = dict(key)
                    if scope is not None and labels.get("scope") != scope:
                        continue
                    if inst.kind == "histogram":
                        value: Any = cell.summary()
                    else:
                        value = cell.value
                    cells.append({"labels": labels, "value": value})
                if cells or scope is None:
                    out[name] = {"kind": inst.kind, "help": inst.help,
                                 "unit": inst.unit, "cells": cells}
            return out

    def reset(self) -> None:
        """Drop every instrument (tests only — instrumented modules keep
        handles to old instruments, so production code never calls this)."""
        with self._lock:
            self._instruments.clear()

    def __repr__(self) -> str:
        with self._lock:
            return f"MetricsRegistry({len(self._instruments)} instrument(s))"


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-global registry every instrumented layer writes to."""
    return _DEFAULT


_SCOPE_COUNTER = itertools.count(1)


def next_scope(prefix: str) -> str:
    """A unique scope-label value (``"serve-3"``): one per instrumented
    object, so per-object reads never alias across instances."""
    return f"{prefix}-{next(_SCOPE_COUNTER)}"


def merge_summaries(summaries: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Merge histogram summaries (count/sum/min/max only — quantiles do
    not merge; callers wanting merged quantiles should share one cell)."""
    count, total = 0, 0.0
    lo, hi = math.inf, -math.inf
    for s in summaries:
        if not s or not s.get("count"):
            continue
        count += s["count"]
        total += s["sum"]
        lo = min(lo, s["min"])
        hi = max(hi, s["max"])
    if count == 0:
        return {"count": 0, "sum": 0.0, "mean": None, "min": None,
                "max": None}
    return {"count": count, "sum": total, "mean": total / count,
            "min": lo, "max": hi}
