"""`repro.obs` — the unified observability layer: metrics + span tracing.

Two process-global primitives back every instrumented layer of the
toolchain (``docs/observability.md`` for the full surface):

* :func:`registry` — a thread-safe :class:`~repro.obs.metrics.MetricsRegistry`
  of labeled counters, gauges, and streaming histograms (p50/p90/p99
  export).  ``Session`` stages, the codesign disk cache, execution
  backends, and the serving layer all define their instruments here
  exactly once; ``registry().snapshot()`` is one consistent point-in-time
  copy.
* :func:`tracer` — a :class:`~repro.obs.tracing.SpanTracer` of nested
  wall-clock spans, exportable as JSONL or Chrome ``trace_event`` JSON
  (Perfetto-loadable).  Disabled by default at near-zero cost; enable in
  code (:func:`enable`) or via the environment::

      CELLO_OBS=jsonl:/tmp/cello.jsonl python examples/observe_cg.py
      CELLO_OBS=chrome:/tmp/cello.trace.json python -m benchmarks.run ...

  ``CELLO_OBS`` accepts a comma-separated list of ``jsonl:PATH`` /
  ``chrome:PATH`` sinks (flushed at interpreter exit and on
  :func:`flush`), or just ``1`` to enable tracing with no sink.
  Add ``jaxprof`` to mirror spans into ``jax.profiler`` annotations.

Render either artifact with ``python scripts/obs_report.py FILE``.
"""
from __future__ import annotations

import atexit
import os
import warnings
from typing import Any, Dict, List, Optional, Tuple

from .metrics import (Counter, Gauge, HIST_GROWTH, HIST_REL_ERROR,
                      Histogram, MetricsRegistry, default_registry,
                      merge_summaries, next_scope)
from .tracing import (JSONL_KEYS, SpanTracer, default_tracer, load_jsonl,
                      validate_chrome, validate_jsonl)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "SpanTracer",
    "HIST_GROWTH", "HIST_REL_ERROR", "JSONL_KEYS",
    "registry", "tracer", "span", "enable", "disable", "flush",
    "default_registry", "default_tracer", "next_scope", "merge_summaries",
    "load_jsonl", "validate_chrome", "validate_jsonl",
    "configure_from_env",
]


def registry() -> MetricsRegistry:
    """The process-global metrics registry."""
    return default_registry()


def tracer() -> SpanTracer:
    """The process-global span tracer."""
    return default_tracer()


def span(name: str, **args):
    """Convenience: a span on the global tracer (no-op when disabled)."""
    return default_tracer().span(name, **args)


# -- sinks ------------------------------------------------------------------

#: (format, path) sinks flushed by :func:`flush` and at interpreter exit
_SINKS: List[Tuple[str, str]] = []
_ATEXIT_REGISTERED = False


def _register_atexit() -> None:
    global _ATEXIT_REGISTERED
    if not _ATEXIT_REGISTERED:
        _ATEXIT_REGISTERED = True
        atexit.register(flush)


def flush() -> Dict[str, int]:
    """Write every configured sink now.  Returns ``{path: span count}``.
    Failures warn (observability must never take the workload down)."""
    out: Dict[str, int] = {}
    tr = default_tracer()
    for fmt, path in list(_SINKS):
        try:
            if fmt == "jsonl":
                out[path] = tr.export_jsonl(path)
            else:
                out[path] = tr.export_chrome(path)
        except OSError as e:                             # pragma: no cover
            warnings.warn(f"obs sink {fmt}:{path} failed: {e}",
                          stacklevel=2)
    return out


def enable(*, jsonl: Optional[str] = None, chrome: Optional[str] = None,
           jax_profiler: bool = False) -> SpanTracer:
    """Turn span tracing on, optionally attaching export sinks."""
    tr = default_tracer().enable(jax_profiler=jax_profiler)
    for fmt, path in (("jsonl", jsonl), ("chrome", chrome)):
        if path:
            _SINKS.append((fmt, str(path)))
            _register_atexit()
    return tr


def disable() -> SpanTracer:
    """Turn span tracing off (sinks stay configured; flush still works)."""
    return default_tracer().disable()


def configure_from_env(env: Optional[str] = None) -> bool:
    """Apply the ``CELLO_OBS`` spec (see module docstring).  Called once at
    import; returns True when tracing was enabled.  A malformed spec warns
    and is ignored — observability must never break the import."""
    spec = os.environ.get("CELLO_OBS", "") if env is None else env
    spec = spec.strip()
    if not spec or spec.lower() in ("0", "false", "off", "no"):
        return False
    jax_profiler = False
    sinks: List[Tuple[str, str]] = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if part.lower() in ("1", "true", "on", "yes"):
            continue                     # enable, no sink
        if part.lower() in ("jaxprof", "jax_profiler"):
            jax_profiler = True
            continue
        fmt, sep, path = part.partition(":")
        if sep and fmt.lower() in ("jsonl", "chrome", "trace") and path:
            sinks.append(("jsonl" if fmt.lower() == "jsonl" else "chrome",
                          path))
        else:
            warnings.warn(
                f"CELLO_OBS: unrecognized part {part!r} (want 1, jaxprof, "
                "jsonl:PATH or chrome:PATH) — ignored", stacklevel=2)
    enable(jax_profiler=jax_profiler)
    for fmt, path in sinks:
        _SINKS.append((fmt, path))
    if sinks:
        _register_atexit()
    return True


def snapshot(scope: Optional[str] = None) -> Dict[str, Any]:
    """Convenience: one consistent metrics snapshot off the global
    registry (what ``CompiledPlan.report()`` and ``benchmarks/run.py
    --json`` embed)."""
    return default_registry().snapshot(scope)


configure_from_env()
