"""Lowering CELLO co-design decisions onto the JAX/TPU execution stack.

The co-design result (fusion groups + pins + buffer split) becomes:

* **kernel selection** — a fusion group covering {scores, softmax, pv} turns
  on the flash-attention Pallas kernel; one covering {up, act, down} turns on
  the fused-MLP kernel; RG-LRU / WKV scan ops select their dedicated kernels.
  Block shapes are derived from the explicit-region budget (this is the
  BlockSpec the schedule "pins").

* **remat (implicit-buffer) policy** — tensors the co-designer kept on-chip
  map to `jax.checkpoint` *saved* names; everything else is recomputed in the
  backward pass.  `checkpoint_policy()` builds the actual policy object used
  by `launch.train`.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax

from ..configs.base import ArchConfig
from .costmodel import HardwareModel, V5E
from .schedule import CoDesignResult

# canonical checkpoint-name tags used by repro.models
KNOWN_SAVE_NAMES = ("attn_out", "mlp_out", "q_out", "kv_out", "probs",
                    "mlp_hidden", "router_logits", "rnn_state", "x_mid")


@dataclasses.dataclass(frozen=True)
class CelloPlan:
    arch: str
    use_flash_attention: bool = True
    q_block: int = 512
    kv_block: int = 512
    use_fused_mlp: bool = True
    mlp_block_m: int = 256
    mlp_block_f: int = 512
    use_fused_rmsnorm: bool = True
    remat_save_names: Tuple[str, ...] = ("attn_out", "mlp_out")
    explicit_frac: float = 0.5
    # decode-cache write strategy: shard-local broadcast-select (True) vs
    # dynamic_update_slice (False — forces SPMD full-remat on a cache whose
    # sequence dim is sharded; kept as the §Perf baseline knob)
    cache_select_update: bool = True
    # MoE expert-capacity factor (buffer collectives scale linearly with it)
    moe_capacity_factor: float = 1.25
    notes: str = ""

    def checkpoint_policy(self):
        if not self.remat_save_names:
            return jax.checkpoint_policies.nothing_saveable
        return jax.checkpoint_policies.save_only_these_names(
            *self.remat_save_names)


def _pick_attention_blocks(head_dim: int, explicit_bytes: int,
                           seq: int) -> Tuple[int, int]:
    """Largest MXU-aligned (q_block, kv_block) whose flash working set fits.

    Working set per (q_blk, kv_blk) tile, bf16 with f32 accumulators:
      q: q·e·2, k/v: 2·kv·e·2, scores: q·kv·4, out acc: q·e·4, stats: 2·q·4
    """
    best = (128, 128)
    for q in (128, 256, 512, 1024):
        for kv in (128, 256, 512, 1024):
            if q > seq or kv > seq:
                continue
            ws = (q * head_dim * 2 + 2 * kv * head_dim * 2
                  + q * kv * 4 + q * head_dim * 4 + 2 * q * 4)
            if ws <= explicit_bytes and (q, kv) >= best:
                best = (q, kv)
    return best


def _pick_mlp_blocks(d_model: int, d_ff: int, explicit_bytes: int
                     ) -> Tuple[int, int]:
    """(m_block, f_block): token tile × hidden tile for the fused MLP."""
    best = (128, 128)
    for m in (128, 256, 512):
        for f in (128, 256, 512, 1024):
            if f > d_ff:
                continue
            # x tile + w_up col tile + h tile + w_down row tile + out acc
            ws = (m * d_model * 2 + d_model * f * 2 * 2
                  + m * f * 4 + f * d_model * 2 + m * d_model * 4)
            if ws <= explicit_bytes and m * f >= best[0] * best[1]:
                best = (m, f)
    return best


def lower_codesign(cfg: ArchConfig, result: CoDesignResult,
                   seq: int = 4096, hw: HardwareModel = V5E) -> CelloPlan:
    """Translate a CoDesignResult on the layer graph into an execution plan.

    This is the lowering behind ``repro.api.Session.lower()``."""
    sched = result.best.schedule
    explicit = sched.config.explicit_bytes or hw.vmem_bytes // 2

    def fused_together(*frags: str) -> bool:
        for group in sched.groups:
            names = ",".join(group)
            if all(f in names for f in frags):
                return True
        return False

    flash = fused_together(".scores", ".pv")
    fused_mlp = fused_together("mlp.up", "mlp.down")
    qb, kb = _pick_attention_blocks(cfg.resolved_head_dim, explicit, seq)
    mb, fb = _pick_mlp_blocks(cfg.d_model, cfg.d_ff, explicit)

    # pinned tensors -> checkpoint save-names (suffix match on known tags)
    saves = set()
    for tname in sched.pins:
        for tag in KNOWN_SAVE_NAMES:
            if tname.endswith(tag):
                saves.add(tag)
    # block outputs are always cheap to keep relative to recompute
    saves.update({"attn_out", "mlp_out"})
    if cfg.attention_free or cfg.hybrid_period:
        saves.add("rnn_state")

    return CelloPlan(
        arch=cfg.name,
        use_flash_attention=flash,
        q_block=qb, kv_block=kb,
        use_fused_mlp=fused_mlp,
        mlp_block_m=mb, mlp_block_f=fb,
        remat_save_names=tuple(sorted(saves)),
        explicit_frac=sched.config.explicit_frac,
        notes=(f"groups={len(sched.groups)} pins={len(sched.pins)} "
               f"speedup={result.speedup():.2f}x"),
    )


# ``plan_from_codesign`` (the 0.2-era deprecation shim for
# :func:`lower_codesign`) was removed in 0.4 after its promised one-release
# window — see docs/api_migration.md.


def default_plan(cfg: ArchConfig, seq: int = 4096,
                 hw: HardwareModel = V5E) -> CelloPlan:
    """Paper-faithful default without running the search (used by smoke
    tests and the dry-run, where search cost would dominate)."""
    explicit = hw.vmem_bytes // 2
    qb, kb = _pick_attention_blocks(cfg.resolved_head_dim, explicit, seq)
    mb, fb = _pick_mlp_blocks(cfg.d_model, cfg.d_ff, explicit)
    saves = {"attn_out", "mlp_out"}
    if cfg.attention_free or cfg.hybrid_period:
        saves.add("rnn_state")
    return CelloPlan(arch=cfg.name, q_block=qb, kv_block=kb,
                     mlp_block_m=mb, mlp_block_f=fb,
                     remat_save_names=tuple(sorted(saves)),
                     notes="default (no search)")
