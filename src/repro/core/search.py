"""Composable pass pipeline for the CELLO co-design search.

The joint schedule × buffer search is factored into a registry of passes run
over a stream of candidate :class:`SearchPoint`\\ s:

  ``OrderPass``      — expand one seed point into candidate topological
                       orders, delegating to a pluggable
                       :class:`SearchStrategy` (exhaustive / greedy / ALAP…),
  ``SplitSweepPass`` — expand each order across explicit/implicit splits,
  ``FusionPass``     — greedy maximal fusion chains per (order, split),
  ``PinPass``        — reuse analysis + greedy pin selection,
  ``EvaluatePass``   — hybrid-buffer simulation + speedup/energy model.

:func:`run_codesign` streams points through the default pipeline and reduces
them to a :class:`~repro.core.schedule.CoDesignResult`.  The enumeration
order, tie-breaking, and per-point arithmetic are exactly those of the
original monolithic ``schedule.co_design`` loop, so results are bit-identical
— new strategies or passes plug in without perturbing the default search.

New orderings register with :func:`register_strategy`; new passes with
:func:`register_pass`.  ``repro.api`` re-exports this module's surface.
"""
from __future__ import annotations

import dataclasses
import time
from typing import (Dict, Iterable, Iterator, List, Optional, Sequence,
                    Tuple, Type)

from .. import obs
from .buffer import BufferConfig, TrafficReport, sequential_groups, simulate
from .costmodel import HardwareModel, Metrics, V5E, evaluate
from .graph import OpGraph, TensorKind
from .reuse import ReuseAnalysis, analyze

DEFAULT_SPLITS = (0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0)

_SEARCH_S = obs.registry().histogram(
    "codesign.search_s", "joint schedule x buffer search wall-clock",
    unit="s")
_POINTS = obs.registry().counter(
    "codesign.points", "design points streamed through the search pipeline")
_PINS = obs.registry().counter(
    "codesign.pins", "sparse-operand pin decisions of winning schedules, "
    "by outcome label: full | prefix | streamed")
_OVERBOOK_FRAC = obs.registry().histogram(
    "codesign.overbook_frac", "resident row fraction of prefix-pinned "
    "sparse operands in winning schedules")


# --------------------------------------------------------------------------
# search state
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SearchPoint:
    """One candidate design flowing through the pass pipeline."""
    order: Optional[List[str]] = None
    split: Optional[float] = None
    config: Optional[BufferConfig] = None
    groups: Optional[List[List[str]]] = None
    analysis: Optional[ReuseAnalysis] = None
    pins: Optional[Dict[str, Tuple[int, int]]] = None
    report: Optional[TrafficReport] = None
    metrics: Optional[Metrics] = None
    # baseline knobs (the paper's ablations flow through the same pipeline)
    fuse: bool = True
    pin: bool = True
    last_use_invalidate: bool = True


@dataclasses.dataclass
class SearchContext:
    """Shared, read-only inputs plus per-run caches for the passes."""
    graph: OpGraph
    hw: HardwareModel = V5E
    capacity_bytes: int = 0
    max_orders: int = 16
    splits: Sequence[float] = DEFAULT_SPLITS
    # allow sparse pins to exceed the explicit region by this fraction of
    # its capacity, pinning an indptr-aligned row prefix and streaming the
    # spill tail (0.0 = all-or-nothing pins, the pre-overbook behaviour)
    overbook: float = 0.0
    # analyze(graph, order) is pure in (graph, order): cache it per order so
    # the split sweep doesn't recompute the same reuse analysis nine times.
    _analysis_cache: Dict[Tuple[str, ...], ReuseAnalysis] = \
        dataclasses.field(default_factory=dict)

    def analysis_for(self, order: Sequence[str]) -> ReuseAnalysis:
        key = tuple(order)
        hit = self._analysis_cache.get(key)
        if hit is None:
            hit = self._analysis_cache[key] = analyze(self.graph, list(order))
        return hit


# --------------------------------------------------------------------------
# ordering strategies (pluggable)
# --------------------------------------------------------------------------

class SearchStrategy:
    """Protocol: produce candidate topological orders for the search."""
    name: str = "base"

    def orders(self, graph: OpGraph, max_orders: int) -> List[List[str]]:
        raise NotImplementedError


STRATEGY_REGISTRY: Dict[str, SearchStrategy] = {}


def register_strategy(strategy) -> SearchStrategy:
    """Register a strategy instance (or class, instantiated with no args)."""
    inst = strategy() if isinstance(strategy, type) else strategy
    STRATEGY_REGISTRY[inst.name] = inst
    return strategy


def get_strategy(name_or_obj) -> SearchStrategy:
    if isinstance(name_or_obj, str):
        if name_or_obj not in STRATEGY_REGISTRY:
            raise KeyError(f"unknown search strategy {name_or_obj!r}; "
                           f"have {sorted(STRATEGY_REGISTRY)}")
        return STRATEGY_REGISTRY[name_or_obj]
    if isinstance(name_or_obj, type):    # mirror register_strategy: a bare
        return name_or_obj()             # class is instantiated with no args
    return name_or_obj


def _lazy_order(graph: OpGraph, natural: Sequence[str]) -> List[str]:
    """ALAP-flavoured topological order: among ready ops, prefer the one
    whose output is consumed soonest (shrinks late-use reuse distances)."""
    remaining = set(natural)
    placed: List[str] = []
    produced = {t.name for t in graph.tensors.values()
                if t.kind in (TensorKind.INPUT, TensorKind.WEIGHT)}
    natural = list(natural)
    while remaining:
        ready = [o for o in natural
                 if o in remaining
                 and all(t in produced for t in graph.ops[o].inputs)]

        def urgency(o: str) -> int:
            t = graph.ops[o].output
            for j, other in enumerate(natural):
                if other in remaining and other != o and t in graph.ops[other].inputs:
                    return j
            return len(natural)
        ready.sort(key=urgency)
        pick = ready[0]
        placed.append(pick)
        remaining.discard(pick)
        produced.add(graph.ops[pick].output)
    return placed


@register_strategy
class DefaultStrategy(SearchStrategy):
    """The paper's search: exhaustive for small DAGs (≤10 ops), natural +
    ALAP heuristic otherwise."""
    name = "default"

    def orders(self, graph: OpGraph, max_orders: int) -> List[List[str]]:
        orders = [graph.topo_order()]
        if len(graph.ops) <= 10:
            for o in graph.all_topo_orders(limit=max_orders):
                if o not in orders:
                    orders.append(o)
        else:
            lazy = _lazy_order(graph, graph.topo_order())
            if lazy not in orders:
                orders.append(lazy)
        return orders[:max_orders]


@register_strategy
class ExhaustiveStrategy(SearchStrategy):
    """Enumerate topological orders up to ``max_orders`` regardless of size."""
    name = "exhaustive"

    def orders(self, graph: OpGraph, max_orders: int) -> List[List[str]]:
        orders = [graph.topo_order()]
        for o in graph.all_topo_orders(limit=max_orders):
            if o not in orders:
                orders.append(o)
        return orders[:max_orders]


@register_strategy
class GreedyStrategy(SearchStrategy):
    """Construction (natural) order only — the cheapest search."""
    name = "greedy"

    def orders(self, graph: OpGraph, max_orders: int) -> List[List[str]]:
        return [graph.topo_order()]


@register_strategy
class AlapStrategy(SearchStrategy):
    """Natural + ALAP orders only (skip exhaustive enumeration)."""
    name = "alap"

    def orders(self, graph: OpGraph, max_orders: int) -> List[List[str]]:
        orders = [graph.topo_order()]
        lazy = _lazy_order(graph, graph.topo_order())
        if lazy not in orders:
            orders.append(lazy)
        return orders[:max_orders]


# --------------------------------------------------------------------------
# passes (composable; registered by name)
# --------------------------------------------------------------------------

class Pass:
    """Protocol: transform/expand a stream of search points."""
    name: str = "base"

    def run(self, ctx: SearchContext,
            points: Iterable[SearchPoint]) -> Iterator[SearchPoint]:
        raise NotImplementedError


PASS_REGISTRY: Dict[str, Type[Pass]] = {}


def register_pass(cls: Type[Pass]) -> Type[Pass]:
    PASS_REGISTRY[cls.name] = cls
    return cls


@register_pass
class OrderPass(Pass):
    """Expand each seed point into one point per candidate order."""
    name = "order"

    def __init__(self, strategy="default"):
        self.strategy = get_strategy(strategy)

    def run(self, ctx, points):
        for pt in points:
            for order in self.strategy.orders(ctx.graph, ctx.max_orders):
                yield dataclasses.replace(pt, order=order)


@register_pass
class SplitSweepPass(Pass):
    """Expand each point across the explicit/implicit split grid."""
    name = "split-sweep"

    def __init__(self, splits: Optional[Sequence[float]] = None):
        self.splits = splits

    def run(self, ctx, points):
        splits = self.splits if self.splits is not None else ctx.splits
        for pt in points:
            for split in splits:
                cfg = BufferConfig(
                    capacity_bytes=ctx.capacity_bytes, explicit_frac=split,
                    last_use_invalidate=pt.last_use_invalidate)
                yield dataclasses.replace(pt, split=split, config=cfg)


@register_pass
class FusionPass(Pass):
    """Greedy maximal fusion chains along the order (or op-by-op when the
    point is a no-fusion baseline)."""
    name = "fusion"

    def run(self, ctx, points):
        from .schedule import build_groups     # late: avoid import cycle
        for pt in points:
            groups = (build_groups(ctx.graph, pt.order,
                                   pt.config.explicit_bytes)
                      if pt.fuse else sequential_groups(ctx.graph, pt.order))
            yield dataclasses.replace(pt, groups=groups)


@register_pass
class PinPass(Pass):
    """Reuse analysis + greedy explicit-region pin selection."""
    name = "pin"

    def run(self, ctx, points):
        from .schedule import choose_pins      # late: avoid import cycle
        for pt in points:
            if pt.pin and pt.config.explicit_bytes > 0:
                analysis = ctx.analysis_for(pt.order)
                pins = choose_pins(ctx.graph, pt.groups, analysis,
                                   pt.config.explicit_bytes,
                                   overbook=ctx.overbook)
                if getattr(pins, "partial", None):
                    # Overbooking is speculative: yield the conservative
                    # all-or-nothing pin set FIRST so the strict-< best
                    # comparison keeps it on ties — EvaluatePass rejects
                    # the overbooked point whenever its per-pass streamed
                    # tail traffic dominates the prefix's captured reuse.
                    conservative = choose_pins(ctx.graph, pt.groups,
                                               analysis,
                                               pt.config.explicit_bytes)
                    yield dataclasses.replace(pt, analysis=analysis,
                                              pins=conservative)
            else:
                analysis, pins = None, {}
            yield dataclasses.replace(pt, analysis=analysis, pins=pins)


@register_pass
class EvaluatePass(Pass):
    """Hybrid-buffer simulation + roofline/energy scoring."""
    name = "evaluate"

    def run(self, ctx, points):
        for pt in points:
            rep = simulate(ctx.graph, pt.groups, pt.config, pt.pins)
            met = evaluate(ctx.graph, pt.groups, rep, ctx.hw)
            yield dataclasses.replace(pt, report=rep, metrics=met)


def default_pipeline(strategy="default",
                     splits: Optional[Sequence[float]] = None) -> List[Pass]:
    return [OrderPass(strategy), SplitSweepPass(splits), FusionPass(),
            PinPass(), EvaluatePass()]


def run_pipeline(ctx: SearchContext, passes: Sequence[Pass],
                 seed: Optional[SearchPoint] = None) -> Iterator[SearchPoint]:
    points: Iterable[SearchPoint] = iter([seed or SearchPoint()])
    for p in passes:
        points = p.run(ctx, points)
    return iter(points)


class _TimedIter:
    """Wraps one pass's generator, accumulating wall-clock spent inside
    ``next()``.  The passes are lazy, so a pull on stage N runs every
    upstream stage too: ``elapsed`` is *inclusive* time, and a stage's
    exclusive self-time is ``elapsed[N] - elapsed[N-1]``."""

    __slots__ = ("_it", "elapsed", "count")

    def __init__(self, it: Iterable[SearchPoint]):
        self._it = iter(it)
        self.elapsed = 0.0
        self.count = 0

    def __iter__(self) -> "_TimedIter":
        return self

    def __next__(self) -> SearchPoint:
        t0 = time.perf_counter()
        try:
            item = next(self._it)
        except StopIteration:
            self.elapsed += time.perf_counter() - t0
            raise
        self.elapsed += time.perf_counter() - t0
        self.count += 1
        return item


def _timed_pipeline(ctx: SearchContext, passes: Sequence[Pass]):
    """Like :func:`run_pipeline` with a :class:`_TimedIter` between stages,
    so per-pass self-time is recoverable from the lazy stream."""
    points: Iterable[SearchPoint] = iter([SearchPoint()])
    timers: List[Tuple[str, _TimedIter]] = []
    for p in passes:
        timer = _TimedIter(p.run(ctx, points))
        timers.append((p.name, timer))
        points = timer
    return points, timers


# --------------------------------------------------------------------------
# the co-design driver
# --------------------------------------------------------------------------

def _pin_outcomes(graph: OpGraph, pins) -> List[Tuple[str, str, float]]:
    """Classify each sparse CSR triple under a pin set.

    Returns ``(operand, outcome, resident_frac)`` rows where outcome is
    ``full`` (whole triple pinned), ``prefix`` (overbooked: row prefix
    resident, tail streamed) or ``streamed`` (nothing pinned).
    """
    from .schedule import sparse_operand_groups    # late: import cycle
    partial = dict(getattr(pins, "partial", None) or {})
    spans = dict(pins or {})
    out: List[Tuple[str, str, float]] = []
    for grp in sparse_operand_groups(graph):
        base = grp[0].rsplit(".", 1)[0]
        pp = next((partial[m] for m in grp if m in partial), None)
        if pp is not None:
            out.append((base, "prefix", pp.frac))
        elif all(m in spans for m in grp):
            out.append((base, "full", 1.0))
        else:
            out.append((base, "streamed", 0.0))
    return out


def _to_evaluated(pt: SearchPoint):
    from .schedule import EvaluatedSchedule, Schedule
    return EvaluatedSchedule(
        Schedule(pt.order, pt.groups, pt.pins, pt.config), pt.report,
        pt.metrics)


def evaluate_point(ctx: SearchContext, order: List[str], split: float, *,
                   last_use_invalidate: bool = True, fuse: bool = True,
                   pin: bool = True):
    """Score a single (order, split, knobs) design point."""
    seed = SearchPoint(order=order, fuse=fuse, pin=pin,
                       last_use_invalidate=last_use_invalidate)
    passes = [SplitSweepPass([split]), FusionPass(), PinPass(),
              EvaluatePass()]
    return _to_evaluated(next(run_pipeline(ctx, passes, seed)))


def run_codesign(graph: OpGraph, *, capacity_bytes: Optional[int] = None,
                 hw: HardwareModel = V5E, max_orders: int = 16,
                 strategy="default",
                 splits: Sequence[float] = DEFAULT_SPLITS,
                 overbook: float = 0.0,
                 natural_analysis: Optional[ReuseAnalysis] = None):
    """Joint schedule × buffer-split search. Returns best + baselines.

    The engine behind the staged ``repro.api.Session.codesign`` stage (and
    the removed 0.2-era ``co_design``).  ``natural_analysis`` (from a
    prior analyze() stage) pre-seeds the per-order analysis cache — analyze
    is pure in (graph, order), so seeding cannot change results.

    ``overbook`` lets sparse pins exceed the explicit region by that
    fraction of its capacity: the operand's indptr-aligned row prefix is
    pinned and the spill tail streamed per pass.  Both the conservative
    and the overbooked pin sets compete in the search, so overbooking is
    only kept when the cost model says the prefix's reuse beats the tail's
    streamed traffic.  ``overbook=0`` is bit-identical to the historical
    all-or-nothing search.
    """
    from .schedule import CoDesignResult
    graph.validate()
    if overbook < 0:
        raise ValueError(f"overbook must be >= 0, got {overbook}")
    splits = list(splits)    # normalize once: a one-shot iterable must not
    if not splits:           # be consumed by the guard before the sweep
        raise ValueError("splits must be a non-empty sequence of fractions")
    ctx = SearchContext(graph=graph, hw=hw,
                        capacity_bytes=capacity_bytes or hw.vmem_bytes,
                        max_orders=max_orders, splits=splits,
                        overbook=overbook)
    if natural_analysis is not None:
        ctx._analysis_cache[tuple(natural_analysis.order)] = natural_analysis

    strat_name = get_strategy(strategy).name
    tracer = obs.tracer()
    passes = default_pipeline(strategy, splits)
    best: Optional[SearchPoint] = None
    split_sweep: Dict[float, Metrics] = {}
    t_search = time.perf_counter()
    with obs.span("codesign.search", strategy=strat_name,
                  max_orders=max_orders, splits=len(splits)) as sp:
        start = tracer.now()
        timers: List[Tuple[str, _TimedIter]] = []
        if tracer.enabled:
            points, timers = _timed_pipeline(ctx, passes)
        else:
            points = run_pipeline(ctx, passes)
        n_points = 0
        for pt in points:
            n_points += 1
            cur = split_sweep.get(pt.split)
            if cur is None or pt.metrics.time_s < cur.time_s:
                split_sweep[pt.split] = pt.metrics
            if (best is None
                    or (pt.metrics.time_s, pt.metrics.energy_j)
                    < (best.metrics.time_s, best.metrics.energy_j)):
                best = pt
        sp.annotate(points=n_points)
        outcomes = (_pin_outcomes(graph, best.pins)
                    if best is not None else [])
        # per-pass self-time as synthetic consecutive child spans: the
        # stages stream lazily, so real intervals interleave per point —
        # aggregate self-time is the honest per-pass number.
        cursor, prev = start, 0.0
        for pass_name, timer in timers:
            self_s = max(timer.elapsed - prev, 0.0)
            meta = {}
            if pass_name == "pin" and outcomes:
                # annotate the pin span with the winning pin set:
                # "A=prefix(0.77)+x=full" style, one term per operand
                meta["pins"] = "+".join(
                    f"{name}={kind}" if kind != "prefix"
                    else f"{name}=prefix({frac:.2f})"
                    for name, kind, frac in outcomes)
            tracer.record(f"codesign.pass.{pass_name}", cursor, self_s,
                          points=timer.count, **meta)
            cursor += self_s
            prev = timer.elapsed
    _SEARCH_S.observe(time.perf_counter() - t_search, strategy=strat_name)
    _POINTS.inc(n_points, strategy=strat_name)
    if best is None:    # a custom strategy returned no candidate orders
        raise ValueError(f"search produced no candidates: strategy "
                         f"{strat_name!r} yielded no "
                         "orders for this graph")
    for _name, kind, frac in outcomes:
        _PINS.inc(outcome=kind)
        if kind == "prefix":
            _OVERBOOK_FRAC.observe(frac)

    nat = graph.topo_order()
    with obs.span("codesign.baselines"):
        baselines = {
            # plain cache, op-by-op, no hints — the "implicit-only"
            # accelerator
            "seq-implicit": evaluate_point(ctx, nat, 0.0,
                                           last_use_invalidate=False,
                                           fuse=False, pin=False),
            # scratchpad-only: pinning but no cache for the rest
            "seq-explicit": evaluate_point(ctx, nat, 1.0, fuse=False,
                                           pin=True),
            # fusion, all capacity explicit, no implicit region
            "fused-only": evaluate_point(ctx, nat, 1.0, fuse=True, pin=True),
        }
    return CoDesignResult(best=_to_evaluated(best), baselines=baselines,
                          split_sweep=split_sweep, overbook=overbook)
