"""Per-architecture layer DAG builders for the CELLO co-designer.

These graphs are the *analysis-level* view of one transformer block (plus
optional embedding/logits stages): enough fidelity in shapes/FLOPs/bytes for
the schedule × buffer co-design and the speedup/energy tables, at tensor
granularity.  The *execution-level* view is `repro.models` + `repro.kernels`;
`core.policy` connects the two (fusion groups found here select kernels and
remat save-sets there).

Graphs are assembled through :meth:`OpGraph.build`'s value-flow builder:
every op returns the name of the tensor it produced, and downstream ops take
those returned values, so the DAG wiring is carried by data flow rather than
by re-derived string keys.

Conventions:
  * batch and sequence are flattened where attention doesn't need them apart,
  * GQA is modelled with K/V at their true (smaller) kv-head sizes while the
    score/PV contractions carry full-head FLOPs (broadcast is free),
  * data-dependent ops (MoE top-k routing/dispatch) are marked ``irregular``
    — the co-designer must leave their reuse to the implicit region,
  * recurrences (RG-LRU, WKV6) are ``scan`` ops — unfusable with neighbours
    except via their dedicated kernels; their *state* is a pin candidate.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from ..configs.base import ArchConfig
from .graph import GraphBuilder, OpGraph, TensorKind

BF16 = 2
F32 = 4


def attention_block(b: GraphBuilder, cfg: ArchConfig, prefix: str, x: str,
                    batch: int, q_len: int, kv_len: int,
                    cross_kv: Optional[str] = None,
                    out_kind: TensorKind = TensorKind.INTERMEDIATE) -> str:
    """Standard (GQA / sliding-window / cross) attention sub-DAG. Returns the
    name of the block output tensor (pre-residual)."""
    d, h, kvh, e = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    bb, s = batch, q_len
    z = kv_len if cfg.window is None else min(kv_len, cfg.window)

    wq = b.weight(f"{prefix}.wq", (d, h * e))
    wo = b.weight(f"{prefix}.wo", (h * e, d))
    q = b.contract(f"{prefix}.q", [x, wq], f"{prefix}.q_out",
                   (bb, s, h, e), 2 * bb * s * d * h * e)

    if cross_kv is None:
        wk = b.weight(f"{prefix}.wk", (d, kvh * e))
        wv = b.weight(f"{prefix}.wv", (d, kvh * e))
        k_t = b.contract(f"{prefix}.k", [x, wk], f"{prefix}.k_out",
                         (bb, z, kvh, e), 2 * bb * z * d * kvh * e)
        v_t = b.contract(f"{prefix}.v", [x, wv], f"{prefix}.v_out",
                         (bb, z, kvh, e), 2 * bb * z * d * kvh * e)
    else:
        # cross-attention: K/V come from the (pinned-candidate) image tensor
        k_t = v_t = cross_kv

    # scores + softmax + PV: FLOPs carry full h heads (GQA broadcast free)
    scores = b.contract(f"{prefix}.scores", [q, k_t], f"{prefix}.scores_out",
                        (bb, h, s, z), 2 * bb * h * s * z * e)
    probs = b.elementwise(f"{prefix}.softmax", [scores], f"{prefix}.probs",
                          flops_per_elem=5)
    pv = b.contract(f"{prefix}.pv", [probs, v_t], f"{prefix}.pv_out",
                    (bb, s, h, e), 2 * bb * h * s * z * e)
    return b.contract(f"{prefix}.o", [pv, wo], f"{prefix}.attn_out",
                      (bb, s, d), 2 * bb * s * h * e * d, out_kind=out_kind)


def mlp_block(b: GraphBuilder, cfg: ArchConfig, prefix: str, x: str,
              tokens: int, out_kind: TensorKind = TensorKind.INTERMEDIATE) -> str:
    d, f = cfg.d_model, cfg.d_ff
    gated = cfg.activation in ("swiglu", "geglu")
    if cfg.is_moe:
        return moe_block(b, cfg, prefix, x, tokens, out_kind)
    w_up = b.weight(f"{prefix}.w_up", (d, (2 if gated else 1) * f))
    w_down = b.weight(f"{prefix}.w_down", (f, d))
    h = b.contract(f"{prefix}.up", [x, w_up], f"{prefix}.h",
                   (tokens, (2 if gated else 1) * f),
                   2 * tokens * d * (2 if gated else 1) * f)
    a = b.elementwise(f"{prefix}.act", [h], f"{prefix}.a",
                      flops_per_elem=4, out_shape=(tokens, f))
    return b.contract(f"{prefix}.down", [a, w_down], f"{prefix}.mlp_out",
                      (tokens, d), 2 * tokens * f * d, out_kind=out_kind)


def moe_block(b: GraphBuilder, cfg: ArchConfig, prefix: str, x: str,
              tokens: int, out_kind: TensorKind = TensorKind.INTERMEDIATE) -> str:
    """Top-k MoE FFN.  Routing/dispatch are data-dependent ⇒ irregular:
    their reuse must live in the implicit region (the CELLO showcase)."""
    d, f, E, k = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.top_k
    gated = cfg.activation in ("swiglu", "geglu")
    w_router = b.weight(f"{prefix}.w_router", (d, E))
    w_up_e = b.weight(f"{prefix}.w_up_e", (E, d, (2 if gated else 1) * f))
    w_down_e = b.weight(f"{prefix}.w_down_e", (E, f, d))
    logits = b.contract(f"{prefix}.router", [x, w_router],
                        f"{prefix}.logits", (tokens, E), 2 * tokens * d * E,
                        dtype_bytes=F32)
    gates = b.elementwise(f"{prefix}.topk", [logits], f"{prefix}.gates",
                          flops_per_elem=2, out_shape=(tokens, k),
                          dtype_bytes=F32, irregular=True)
    # dispatch: gather tokens to experts (data-dependent addressing)
    xe = b.elementwise(f"{prefix}.dispatch", [x, gates], f"{prefix}.xe",
                       flops_per_elem=0, out_shape=(tokens * k, d),
                       irregular=True, spec="gather")
    h = b.contract(f"{prefix}.up", [xe, w_up_e], f"{prefix}.h",
                   (tokens * k, (2 if gated else 1) * f),
                   2 * tokens * k * d * (2 if gated else 1) * f)
    a = b.elementwise(f"{prefix}.act", [h], f"{prefix}.a",
                      flops_per_elem=4, out_shape=(tokens * k, f))
    ye = b.contract(f"{prefix}.down", [a, w_down_e], f"{prefix}.ye",
                    (tokens * k, d), 2 * tokens * k * f * d)
    # combine: weighted scatter-add back to token order (data-dependent)
    return b.elementwise(f"{prefix}.combine", [ye, gates],
                         f"{prefix}.mlp_out", flops_per_elem=2 * k,
                         out_shape=(tokens, d), irregular=True, spec="gather",
                         out_kind=out_kind)


def rglru_block(b: GraphBuilder, cfg: ArchConfig, prefix: str, x: str,
                batch: int, seq: int) -> str:
    """RG-LRU recurrent block (recurrentgemma): gated linear recurrence."""
    d = cfg.d_model
    bb, s = batch, seq
    wx, wgate, wa, wout = b.weights(prefix, ("wx", "wgate", "wa", "wout"),
                                    (d, d))
    xb = b.contract(f"{prefix}.proj", [x, wx], f"{prefix}.xb",
                    (bb, s, d), 2 * bb * s * d * d)
    g = b.contract(f"{prefix}.gates", [x, wgate, wa], f"{prefix}.g",
                   (bb, s, 2 * d), 2 * bb * s * d * 2 * d)
    # the recurrence itself: sequential along s => 'scan' op
    h = b.scan(f"{prefix}.scan", [xb, g], f"{prefix}.h",
               (bb, s, d), flops_per_elem=8)
    return b.contract(f"{prefix}.out", [h, wout], f"{prefix}.rglru_out",
                      (bb, s, d), 2 * bb * s * d * d)


def rwkv_block(b: GraphBuilder, cfg: ArchConfig, prefix: str, x: str,
               batch: int, seq: int) -> str:
    """RWKV6 time-mix: r/k/v/g projections + WKV6 recurrence + output."""
    d = cfg.d_model
    bb, s = batch, seq
    H, e = cfg.n_heads, cfg.resolved_head_dim
    wr, wk, wv, wg, wo, ww = b.weights(
        prefix, ("wr", "wk", "wv", "wg", "wo", "ww"), (d, d))
    rkvg = b.contract(f"{prefix}.rkvg", [x, wr, wk, wv, wg, ww],
                      f"{prefix}.rkvg_out", (bb, s, 5 * d),
                      2 * bb * s * d * 5 * d)
    # WKV6 recurrence: per head, state (e x e) updated per step
    wkv = b.scan(f"{prefix}.wkv", [rkvg], f"{prefix}.wkv_out",
                 (bb, s, d), flops=2 * bb * s * H * e * e * 4)
    return b.contract(f"{prefix}.out", [wkv, wo], f"{prefix}.rwkv_out",
                      (bb, s, d), 2 * bb * s * d * d)


def layer_graph(cfg: ArchConfig, batch: int, seq: int, *,
                layer_kind: Optional[str] = None,
                include_residuals: bool = True) -> OpGraph:
    """One transformer block as an OpGraph (the CELLO unit of analysis).

    The residual stream exhibits the paper's "complex reuse": ``x`` feeds the
    norm AND the residual add (two consumers, different distances); the block
    output feeds the next norm and the next residual add likewise.
    """
    kind = layer_kind or cfg.layer_kinds()[0]
    d = cfg.d_model
    tokens = batch * seq
    with OpGraph.build(f"{cfg.name}:{kind}:b{batch}s{seq}") as b:
        x = b.input("x", (batch, seq, d))
        ln1_w = b.weight("ln1.w", (d,))
        ln2_w = b.weight("ln2.w", (d,))
        x_n1 = b.elementwise("ln1", [x, ln1_w], "x_n1", flops_per_elem=6)

        if kind == "attn":
            y = attention_block(b, cfg, "attn", x_n1, batch, seq, seq)
        elif kind == "xattn":
            img_kv = b.input("img_kv", (batch, cfg.vision_seq,
                                        2 * cfg.n_kv_heads *
                                        cfg.resolved_head_dim))
            y = attention_block(b, cfg, "xattn", x_n1, batch, seq,
                                cfg.vision_seq, cross_kv=img_kv)
        elif kind == "rglru":
            y = rglru_block(b, cfg, "rglru", x_n1, batch, seq)
        elif kind == "rwkv":
            y = rwkv_block(b, cfg, "rwkv", x_n1, batch, seq)
        else:
            raise ValueError(kind)

        if include_residuals:
            src = b.elementwise("res1", [x, y], "x_mid", flops_per_elem=1)
        else:
            src = y
        x_n2 = b.elementwise("ln2", [src, ln2_w], "x_n2", flops_per_elem=6)
        m = mlp_block(b, cfg, "mlp", x_n2, tokens)
        if include_residuals:
            b.elementwise("res2", [src, m], "x_out", flops_per_elem=1,
                          out_kind=TensorKind.OUTPUT,
                          out_shape=(batch, seq, d))
    return b.graph


def decode_graph(cfg: ArchConfig, batch: int, kv_len: int) -> OpGraph:
    """Single-token decode step for one layer: KV-cache reuse pattern.

    The cache is an INPUT consumed by scores/PV and extended (OUTPUT) — the
    canonical multi-distance reuse tensor for serving.
    """
    kind = next((k for k in cfg.layer_kinds() if k in ("attn", "rwkv")),
                cfg.layer_kinds()[0])
    d, h, kvh, e = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim)
    bb = batch
    z = kv_len if cfg.window is None else min(kv_len, cfg.window)
    with OpGraph.build(f"{cfg.name}:decode:b{batch}kv{kv_len}") as b:
        x = b.input("x", (bb, 1, d))
        ln1_w = b.weight("ln1.w", (d,))
        x_n1 = b.elementwise("ln1", [x, ln1_w], "x_n1", flops_per_elem=6)
        if kind == "rwkv":
            state = b.input("state", (bb, cfg.n_heads, e, e), dtype_bytes=F32)
            wr, wk, wv, wo = b.weights("t", ("wr", "wk", "wv", "wo"), (d, d))
            rkv = b.contract("t.rkv", [x_n1, wr, wk, wv], "t.rkv_out",
                             (bb, 1, 3 * d), 2 * bb * d * 3 * d)
            ty = b.scan("t.wkv", [rkv, state], "t.y", (bb, 1, d),
                        flops=2 * bb * cfg.n_heads * e * e * 4)
            b.elementwise("t.state_new", [rkv, state], "state_out",
                          flops_per_elem=2, out_shape=(bb, cfg.n_heads, e, e),
                          dtype_bytes=F32, out_kind=TensorKind.OUTPUT)
            y = b.contract("t.o", [ty, wo], "attn_out", (bb, 1, d),
                           2 * bb * d * d)
        else:
            k_cache = b.input("k_cache", (bb, z, kvh, e))
            v_cache = b.input("v_cache", (bb, z, kvh, e))
            wq = b.weight("attn.wq", (d, h * e))
            wk = b.weight("attn.wk", (d, kvh * e))
            wv = b.weight("attn.wv", (d, kvh * e))
            wo = b.weight("attn.wo", (h * e, d))
            q = b.contract("attn.q", [x_n1, wq], "q", (bb, 1, h, e),
                           2 * bb * d * h * e)
            b.contract("attn.kv_new", [x_n1, wk, wv], "kv_new",
                       (bb, 1, 2 * kvh, e), 4 * bb * d * kvh * e,
                       out_kind=TensorKind.OUTPUT)
            scores = b.contract("attn.scores", [q, k_cache], "scores",
                                (bb, h, 1, z), 2 * bb * h * z * e)
            probs = b.elementwise("attn.softmax", [scores], "probs",
                                  flops_per_elem=5)
            ctx = b.contract("attn.pv", [probs, v_cache], "ctx",
                             (bb, 1, h, e), 2 * bb * h * z * e)
            y = b.contract("attn.o", [ctx, wo], "attn_out", (bb, 1, d),
                           2 * bb * h * e * d)
        x_mid = b.elementwise("res1", [x, y], "x_mid", flops_per_elem=1)
        ln2_w = b.weight("ln2.w", (d,))
        x_n2 = b.elementwise("ln2", [x_mid, ln2_w], "x_n2", flops_per_elem=6)
        m = mlp_block(b, cfg, "mlp", x_n2, bb)
        b.elementwise("res2", [x_mid, m], "x_out", flops_per_elem=1,
                      out_kind=TensorKind.OUTPUT, out_shape=(bb, 1, d))
    return b.graph


# ---------------------------------------------------------------------------
# group -> kernel-shape selection (execution backends)
# ---------------------------------------------------------------------------
#
# A co-designed plan's fusion groups are *claims*: "these ops run as one
# tile-streaming pass through the explicit region".  The execution backends
# (`repro.exec`) make the claim real; this selection decides, per group,
# which kernel shape the claim lowers to:
#
#   ``stream`` — `pl.pallas_call` passes with a 1-D grid over row tiles of
#                the pass's shared streamed length; contraction right-hand
#                sides stay resident in VMEM across every tile (constant
#                index map), rank-0 dot/norm reductions accumulate across
#                grid steps, and scalar epilogues run once on the final
#                tile.  A group usually lowers to ONE pass; it splits into
#                sequential passes exactly where a contraction reads a
#                vector produced earlier in the same group (the value must
#                fully materialize before it can be a resident operand).
#   ``spmv-stream`` — a stream group whose passes include CSR SpMV ops:
#                the same 1-D row-tile grid, but the sparse operand's
#                indptr/indices/data triple AND the gathered x stay
#                resident in VMEM across every tile (rows are ragged and
#                column access is data-dependent); the output vector
#                streams row tiles.  With an overbooked (partial) pin the
#                residency is *fractional*: a :class:`ResidentSlice`
#                records the indptr-aligned row prefix held resident
#                while tail tiles stream their CSR slices per grid step.
#   ``block``  — one `pl.pallas_call` with whole arrays as single blocks:
#                stencil sweeps need halo rows, so they cannot row-stream
#                without overlap; the explicit region holds the full grid.
#   ``jnp``    — jitted jax.numpy fallback for shapes the streamer cannot
#                express (irregular gathers, scans, >2-operand einsums,
#                mixed streamed lengths); ``reason`` records why.

#: einsum specs the tile-streamer lowers: LHS streams row tiles, RHS stays
#: resident (spec -> index of the resident operand)
STREAM_EINSUMS = {"ab,b->a": 1, "ab,bc->ac": 1}
#: rank-0 contraction of two streamed vectors (rank-1 @ rank-1)
REDUCE_EINSUMS = ("a,a->",)

_TILE_ROW_CANDIDATES = (1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1)


@dataclasses.dataclass(frozen=True)
class ResidentSlice:
    """A contiguous, indptr-aligned row window of one operand (or of the
    whole pass) held by a single residency domain.

    Two producers, one record:

    * **Overbooked pins** (``row0 == 0``): rows ``[0, rows)`` of the CSR
      operand (the ``entries`` first indices/data entries) are held in
      VMEM across every tile; the remaining ``total_rows - rows`` rows
      stream their CSR slices through the grid per step.  Produced from
      an overbooked pin's :class:`~repro.core.schedule.PartialPin`
      records.
    * **Mesh shards** (``row0 = k * rows``): shard ``k`` of a partitioned
      plan owns rows ``[row0, row0 + rows)`` of the global problem — the
      ``entries`` CSR entries starting at ``entry0``.  Produced by
      :func:`partition_plan`."""
    tensors: Tuple[str, ...]        # the triple members covered (in order)
    rows: int                       # rows in this window (indptr-aligned)
    total_rows: int
    entries: int                    # nnz entries inside the window
    total_entries: int
    row0: int = 0                   # first global row of the window
    entry0: int = 0                 # first global CSR entry of the window

    @property
    def frac(self) -> float:
        return self.rows / max(1, self.total_rows)

    def describe(self) -> str:
        if self.row0:
            return f"rows[{self.row0}:{self.row0 + self.rows}]"
        return f"prefix({self.rows}/{self.total_rows}r)"


@dataclasses.dataclass(frozen=True)
class StreamPass:
    """One tile-streaming pallas pass over a slice of a fusion group."""
    ops: Tuple[str, ...]
    rows: int                       # streamed leading-dim length
    tile_rows: int                  # rows per grid step (divides ``rows``)
    resident: Tuple[str, ...]       # operands held in VMEM across all tiles
    reductions: Tuple[str, ...]     # rank-0 accumulators in this pass
    # fractional residency of spmv operands (overbooked pins): members of
    # ``resident`` named here hold only their row prefix in VMEM
    slices: Tuple[ResidentSlice, ...] = ()


@dataclasses.dataclass(frozen=True)
class GroupKernel:
    """The kernel shape selected for one fusion group."""
    ops: Tuple[str, ...]
    kind: str                       # "stream" | "block" | "jnp"
    passes: Tuple[StreamPass, ...] = ()   # populated for kind == "stream"
    reason: str = ""                # why a jnp fallback was selected

    def describe(self) -> str:
        if self.kind in ("stream", "spmv-stream"):
            bits = []
            for p in self.passes:
                res = f" res={'+'.join(p.resident)}" if p.resident else ""
                red = f" acc={'+'.join(p.reductions)}" if p.reductions \
                    else ""
                part = "".join(f" {sl.describe()}" for sl in p.slices)
                bits.append(f"{p.rows}r/{p.tile_rows}t{res}{red}{part}")
            tag = " | ".join(bits)
            n = len(self.passes)
            label = ("pallas-spmv" if self.kind == "spmv-stream"
                     else "pallas-stream")
            return (f"{label}[{tag}]" if n == 1
                    else f"{label}[{n} passes: {tag}]")
        if self.kind == "block":
            return "pallas-block[halo ops, full-array block]"
        return f"jnp-fallback({self.reason})"


def _pick_tile_rows(rows: int, per_row_bytes: int, resident_bytes: int,
                    explicit_bytes: int) -> int:
    """Largest row tile (a divisor of ``rows``) whose streaming working set
    fits the explicit region.  The co-design's own fusion-legality check
    (`schedule.fusable`) guaranteed *some* tile fits; when the resident
    operands already cover (or exceed) the budget, we still stream — at
    the finest granularity, never a zero/negative tile."""
    budget = max(explicit_bytes - resident_bytes, 0)
    for t in _TILE_ROW_CANDIDATES:
        if t <= rows and rows % t == 0 and t * per_row_bytes <= budget:
            return t
    # over-budget fallback: the smallest divisor among the candidates
    # (1 divides everything, so this always exists and is positive)
    return next(t for t in reversed(_TILE_ROW_CANDIDATES)
                if t <= rows and rows % t == 0)


def select_group_kernels(graph: OpGraph, groups, explicit_bytes: int,
                         partial=None) -> Tuple[GroupKernel, ...]:
    """Pick a kernel shape for every fusion group of a frontend plan.

    Pure graph-level classification (shapes + op specs); the expression
    semantics needed to *execute* each shape live in ``repro.exec``.

    ``partial`` maps tensor names to
    :class:`~repro.core.schedule.PartialPin` records (an overbooked pin
    set's ``.partial``): spmv operands named there carry a
    :class:`ResidentSlice` on their pass instead of the whole-operand
    residency assumption.
    """
    return tuple(_select_one(graph, list(g), explicit_bytes, partial)
                 for g in groups)


def _finalizes_late(graph: OpGraph, op, late: set) -> bool:
    """True when ``op``'s value only exists on the pass's *final* grid step:
    rank-0 reductions (dot/norm/`a,a->` accumulate across tiles), and any
    scalar computed from one (the ``beta = rs'/rs`` epilogues)."""
    if graph.tensors[op.output].shape != ():
        return False
    if op.spec == "reduce" or op.is_einsum:
        return True
    return any(t in late for t in op.inputs)


def _segment_group(graph: OpGraph, group) -> list:
    """Split a group into streaming passes.  A new pass starts where an op
    needs a value that only exists once the current pass *completes*:

    * a contraction whose resident operand was produced earlier in the
      group (the vector must fully materialize before it can sit in VMEM),
    * a tiled op reading an in-pass rank-0 value that *finalizes on the
      last tile* — a reduction, or a scalar chained off one.  A scalar
      whose in-pass inputs are all tile-invariant (``nalpha = -alpha`` with
      ``alpha`` external) is recomputed per tile instead ("eager" scalar),
      so it does NOT force a pass break; this is what lets the residency
      planner fuse ``x``/``r`` updates with the neg/axpy glue between them.

    ``fusable()`` never emits groups that need the late-scalar break, but
    ``select_group_kernels`` is public API and must be safe for any group
    handed to it.
    """
    segments, cur, produced, late = [], [], set(), set()
    for oname in group:
        op = graph.ops[oname]
        needs_break = False
        if op.is_einsum and op.spec in STREAM_EINSUMS:
            needs_break = op.inputs[STREAM_EINSUMS[op.spec]] in produced
        if op.spec == "spmv":
            # every spmv operand (CSR triple + gathered x) sits resident,
            # so any of them produced in-pass must materialize first
            needs_break = any(t in produced for t in op.inputs)
        if not needs_break and graph.tensors[op.output].shape != ():
            needs_break = any(t in late for t in op.inputs)
        if needs_break and cur:
            segments.append(cur)
            cur, produced, late = [], set(), set()
        cur.append(oname)
        produced.add(op.output)
        if _finalizes_late(graph, op, late):
            late.add(op.output)
    if cur:
        segments.append(cur)
    return segments


def _select_one(graph: OpGraph, group, explicit_bytes: int,
                partial=None) -> GroupKernel:
    ops = [graph.ops[o] for o in group]
    gops = tuple(group)

    for op in ops:
        if op.irregular or op.spec in ("gather", "scan"):
            return GroupKernel(gops, "jnp",
                               reason=f"{op.name}: irregular/scan reuse")

    # stencil sweeps need halo rows -> whole-array block kernel; they may
    # chain with same-shape elementwise ops inside the group
    if any(op.spec == "stencil2d" for op in ops):
        shapes = {graph.tensors[op.output].shape for op in ops}
        if len(shapes) != 1 or not all(op.spec in ("stencil2d", "ew")
                                       for op in ops):
            return GroupKernel(gops, "jnp",
                               reason="stencil mixed with non-halo ops")
        return GroupKernel(gops, "block")

    passes = []
    for seg in _segment_group(graph, group):
        sp = _classify_pass(graph, seg, explicit_bytes, partial)
        if isinstance(sp, str):                    # rejection reason
            return GroupKernel(gops, "jnp", reason=sp)
        passes.append(sp)
    kind = ("spmv-stream" if any(op.spec == "spmv" for op in ops)
            else "stream")
    return GroupKernel(gops, kind, passes=tuple(passes))


def _classify_pass(graph: OpGraph, seg, explicit_bytes: int, partial=None):
    """One segment -> :class:`StreamPass`, or a rejection-reason string."""
    partial = partial or {}
    ops = [graph.ops[o] for o in seg]
    produced = {op.output for op in ops}
    rows = None
    per_row = 0
    resident = []
    reductions = []
    slices = []
    streamed_seen = set()

    def _stream(tname) -> bool:
        """Account ``tname`` as streamed; False on row-count clash."""
        nonlocal rows, per_row
        spec = graph.tensors[tname]
        n = spec.shape[0]
        if rows is None:
            rows = n
        elif rows != n:
            return False
        if tname not in streamed_seen:
            streamed_seen.add(tname)
            per_row += spec.bytes // max(1, n)
        return True

    for op in ops:
        oshape = graph.tensors[op.output].shape
        if op.is_einsum and op.spec in REDUCE_EINSUMS:
            if not all(_stream(t) for t in op.inputs):
                return f"{op.name}: mixed row counts"
            reductions.append(op.output)
        elif op.is_einsum:
            rhs = STREAM_EINSUMS.get(op.spec)
            if rhs is None:
                return f"{op.name}: einsum {op.spec!r} beyond the streamer"
            if op.inputs[rhs] in produced:
                return f"{op.name}: contraction RHS produced in-pass"
            if not _stream(op.inputs[1 - rhs]) or not _stream(op.output):
                return f"{op.name}: mixed row counts"
            if op.inputs[rhs] not in resident:
                resident.append(op.inputs[rhs])
        elif op.spec == "spmv":
            # CSR SpMV: the output vector streams row tiles; the operand
            # triple and the gathered x are held resident — rows are
            # ragged and column access is data-dependent.  An overbooked
            # pin relaxes this to a resident row *prefix* (ResidentSlice)
            # with tail tiles streaming their CSR slices per grid step.
            if any(t in produced for t in op.inputs):
                return f"{op.name}: spmv operand produced in-pass"
            if not _stream(op.output):
                return f"{op.name}: mixed row counts"
            for t in op.inputs:
                if t not in resident:
                    resident.append(t)
            part = tuple(t for t in op.inputs if t in partial)
            if part:
                pp = partial[part[0]]
                sl = ResidentSlice(tensors=part, rows=pp.rows,
                                   total_rows=pp.total_rows,
                                   entries=pp.entries,
                                   total_entries=pp.total_entries)
                if sl not in slices:
                    slices.append(sl)
        elif op.spec == "reduce":
            if any(len(graph.tensors[t].shape) != 1 for t in op.inputs):
                return f"{op.name}: non-vector reduction"
            if not all(_stream(t) for t in op.inputs):
                return f"{op.name}: mixed row counts"
            reductions.append(op.output)
        elif op.spec == "ew":
            if oshape == ():        # scalar epilogue (beta = rs'/rs, ...)
                continue
            for t in list(op.inputs) + [op.output]:
                if graph.tensors[t].shape == ():
                    continue        # broadcast scalar operand
                if graph.tensors[t].shape != oshape:
                    return f"{op.name}: operand shape mismatch"
                if not _stream(t):
                    return f"{op.name}: mixed row counts"
        else:
            return f"{op.name}: op spec {op.spec!r}"

    if rows is None:                # nothing streams: scalar-only group
        return "scalar-only group"

    part_names = {t for sl in slices for t in sl.tensors}
    res_bytes = sum(partial[t].resident_bytes if t in part_names
                    else graph.tensors[t].bytes for t in resident)
    tile = _pick_tile_rows(rows, per_row, res_bytes,
                           max(explicit_bytes, 1 << 20))
    return StreamPass(ops=tuple(seg), rows=rows, tile_rows=tile,
                      resident=tuple(resident), reductions=tuple(reductions),
                      slices=tuple(slices))


# ---------------------------------------------------------------------------
# execution planning: fused dispatch units, cross-pass residency, rolled loops
# ---------------------------------------------------------------------------
#
# ``select_group_kernels`` answers "what kernel shape does each fusion group
# lower to"; this layer answers "how does the whole plan execute as ONE
# program".  Three decisions live here:
#
#   * **units** — the flat dispatch sequence (stream groups contribute one
#     unit per pass);
#   * **residency planning** — adjacent units sharing the same streamed
#     length fuse into a single pass when no value must materialize between
#     them, so streamed operands are read once and resident operands are
#     carried across what used to be pass *and group* boundaries (the
#     execution image of the explicit region persisting across the group
#     order) instead of being re-streamed per unit;
#   * **rolled loops** — when the frontend recorded per-iteration bodies
#     (``Program.iteration``) and the scheduled unit sequence repeats them
#     verbatim, the repeated segment is described once plus a trip count,
#     so an executor can run it as ``lax.fori_loop`` over one compiled body
#     instead of dispatching every unrolled copy.

@dataclasses.dataclass(frozen=True)
class ExecUnit:
    """One execution dispatch unit: a streaming pass, a whole-array block
    kernel, or a jnp-fallback group slice."""
    ops: Tuple[str, ...]
    kind: str                           # "stream" | "block" | "jnp"
    sp: Optional[StreamPass] = None     # populated for kind == "stream"
    groups: Tuple[int, ...] = ()        # originating fusion-group indices
    fused: int = 1                      # pre-fusion units merged into this

    def describe(self) -> str:
        extra = ""
        if self.sp is not None:
            extra = f" {self.sp.rows}r/{self.sp.tile_rows}t"
            if self.sp.resident:
                extra += f" res={'+'.join(self.sp.resident)}"
            for sl in self.sp.slices:
                extra += f" {sl.describe()}"
        if self.fused > 1:
            extra += f" (fused x{self.fused})"
        return f"{self.kind}[{'+'.join(self.ops)}]{extra}"


@dataclasses.dataclass(frozen=True)
class ResidentSpan:
    """A tensor held resident (constant index map) over a unit range."""
    tensor: str
    first: int                          # first unit index (inclusive)
    last: int                           # last unit index (inclusive)


@dataclasses.dataclass(frozen=True)
class CarrySlot:
    """One loop-carried value of a rolled iteration segment."""
    update: str            # template node whose value advances the slot
    final: str             # unrolled name the slot holds after the loop
    init: Optional[str] = None   # pre-loop env name seeding the slot
    #                              (None: seed with zeros — the slot is
    #                              only read after its first update)
    read: Optional[str] = None   # name the template reads it as (None:
    #                              output-only slot, threaded for the final)


@dataclasses.dataclass(frozen=True)
class RolledLoop:
    """A detected repeated iteration segment of the unit sequence: units
    ``[first, first + per_iter)`` are the template body; executing it
    ``n_iters`` times with the carry rebinding below reproduces units
    ``[first, first + per_iter * n_iters)`` exactly."""
    first: int
    per_iter: int
    n_iters: int
    slots: Tuple[CarrySlot, ...]

    @property
    def stop(self) -> int:
        """Index one past the last unit the rolled segment replaces."""
        return self.first + self.per_iter * self.n_iters


def flatten_units(kernels) -> Tuple[ExecUnit, ...]:
    """The flat dispatch sequence of a kernel selection (stream groups
    contribute one unit per pass, in order)."""
    units: List[ExecUnit] = []
    for gi, gk in enumerate(kernels):
        if gk.kind in ("stream", "spmv-stream"):
            # spmv-stream passes dispatch exactly like plain stream passes
            # (the pass's ops carry the spmv-ness); the distinct group
            # kind only records which kernel family was selected
            for sp in gk.passes:
                units.append(ExecUnit(sp.ops, "stream", sp, (gi,)))
        else:
            units.append(ExecUnit(tuple(gk.ops), gk.kind, None, (gi,)))
    return tuple(units)


def _merge_candidate(graph: OpGraph, unit: ExecUnit) -> bool:
    """Streaming passes merge; so do scalar-only jnp groups (their rank-0
    chains become eager/epilogue scalars of the absorbing pass)."""
    if unit.kind == "stream":
        return True
    if unit.kind != "jnp":
        return False
    return all(graph.ops[o].spec == "ew" and not graph.ops[o].irregular
               and graph.tensors[graph.ops[o].output].shape == ()
               for o in unit.ops)


def fuse_units(graph: OpGraph, units, explicit_bytes: int,
               partial=None) -> Tuple[ExecUnit, ...]:
    """The cross-pass residency planner: greedily merge adjacent units into
    one streaming pass wherever re-segmentation proves no value has to
    materialize at the old boundary.  Merged units stream each operand once
    for all their ops and keep resident operands in place across the former
    pass/group boundaries instead of re-streaming them."""
    fused: List[ExecUnit] = []
    for unit in units:
        prev = fused[-1] if fused else None
        if (prev is not None and _merge_candidate(graph, prev)
                and _merge_candidate(graph, unit)):
            ops = list(prev.ops) + list(unit.ops)
            segs = _segment_group(graph, ops)
            if len(segs) == 1:
                sp = _classify_pass(graph, segs[0], explicit_bytes, partial)
                if isinstance(sp, StreamPass):
                    fused[-1] = ExecUnit(tuple(ops), "stream", sp,
                                         prev.groups + unit.groups,
                                         prev.fused + unit.fused)
                    continue
        fused.append(unit)
    return tuple(fused)


def resident_spans(units) -> Tuple[ResidentSpan, ...]:
    """Unit-index span each resident operand is held over."""
    first: Dict[str, int] = {}
    last: Dict[str, int] = {}
    for ui, unit in enumerate(units):
        if unit.sp is None:
            continue
        for t in unit.sp.resident:
            first.setdefault(t, ui)
            last[t] = ui
    return tuple(ResidentSpan(t, first[t], last[t]) for t in sorted(first))


def _build_sigma(program) -> Optional[Dict[str, str]]:
    """The iteration-successor renaming: node at position ``j`` of body
    ``i`` ↦ node at position ``j`` of body ``i+1``.  Only equal-length
    consecutive bodies contribute (GMRES's growing Arnoldi bodies simply
    produce a partial map the matcher then rejects)."""
    bodies = [list(b) for b in program.iteration_bodies()]
    if len(bodies) < 2:
        return None
    sigma: Dict[str, str] = {}
    for a, b in zip(bodies, bodies[1:]):
        if len(a) == len(b):
            sigma.update(zip(a, b))
    return sigma or None


def _unit_matches(program, sigma: Dict[str, str], ua: ExecUnit,
                  ub: ExecUnit) -> bool:
    """Is ``ub`` exactly the σ-image of ``ua``?  Ops map positionally
    through σ, node structure is identical, and every operand is either
    σ-renamed or the same loop-invariant name."""
    if ua.kind != ub.kind or len(ua.ops) != len(ub.ops):
        return False
    if (ua.sp is None) != (ub.sp is None):
        return False
    if ua.sp is not None and (ua.sp.rows != ub.sp.rows
                              or ua.sp.tile_rows != ub.sp.tile_rows):
        return False
    for o, o2 in zip(ua.ops, ub.ops):
        if sigma.get(o) != o2:
            return False
        na, nb = program.nodes[o], program.nodes[o2]
        if (na.op != nb.op or na.shape != nb.shape
                or na.dtype_bytes != nb.dtype_bytes
                or na.params != nb.params
                or len(na.inputs) != len(nb.inputs)):
            return False
        for ta, tb in zip(na.inputs, nb.inputs):
            if tb != sigma.get(ta, ta):
                return False
    return True


def detect_rolled_loop(program, units) -> Optional[RolledLoop]:
    """Find the repeated per-iteration segment of a scheduled unit sequence.

    ``program`` is an expression ``Program`` (duck-typed: needs
    ``iteration_bodies()``, ``nodes`` and ``outputs``) whose builders
    recorded the unrolled solver-iteration bodies.  Those bodies define the
    successor renaming σ (:func:`_build_sigma`); detection then *proves*
    unit-level periodicity — a period ``P`` and region where every unit is
    exactly the σ-image of the unit ``P`` places earlier — so it tolerates
    schedules that phase-shift work across iteration boundaries (BiCGStab's
    deferred ``x`` update).  Iteration 0 typically stays unrolled: CG's
    ``p0`` aliases ``r0``, so its wiring differs from every later
    iteration's.  Returns the roll with the largest unit savings, or
    ``None`` when no period survives the proof.
    """
    if program is None:
        return None
    sigma = _build_sigma(program)
    if sigma is None:
        return None
    total = len(units)

    best: Optional[Tuple[int, int, int, int]] = None   # (saved, first, P, n)
    for P in range(1, total // 2 + 1):
        # every maximal run of σ-matches units[t] -> units[t+P]: a run over
        # t ∈ [a, c] makes units[a, c+P+1) periodic with period P.  All
        # runs matter — the final unrolled iteration often schedules
        # differently (CG fuses the last x-update into it), leaving a
        # trivial run at the tail next to the real one
        t = total - P - 1
        while t >= 0:
            if not _unit_matches(program, sigma, units[t], units[t + P]):
                t -= 1
                continue
            c = t
            while t > 0 and _unit_matches(program, sigma,
                                          units[t - 1], units[t - 1 + P]):
                t -= 1
            a = t
            n = (c + P + 1 - a) // P     # whole periods in the region
            a = (c + P + 1) - P * n      # truncate the partial leading one
            saved = (n - 1) * P
            if n >= 2 and (best is None or saved > best[0]):
                best = (saved, a, P, n)
            t -= 1
    if best is None:
        return None
    _, first, P, n = best

    # carry slots: template reads whose σ-image the template itself
    # produces thread through the loop; σ-mapped reads produced elsewhere
    # defeat the roll; σ-less reads are loop-invariant
    template = units[first:first + P]
    products = [o for u in template for o in u.ops]
    prod_set = set(products)
    reads: List[str] = []
    for u in template:
        for o in u.ops:
            for t in program.nodes[o].inputs:
                if t not in prod_set and t not in reads:
                    reads.append(t)

    def sig_pow(name: str, k: int) -> Optional[str]:
        for _ in range(k):
            name = sigma.get(name)
            if name is None:
                return None
        return name

    final_of: Dict[str, str] = {}
    for o in products:
        f = sig_pow(o, n - 1)
        if f is None:
            return None
        final_of[o] = f

    slots: List[CarrySlot] = []
    updates: set = set()
    for t in reads:
        st = sigma.get(t)
        if st is None:
            continue                     # loop-invariant operand
        if st not in prod_set:
            return None                  # next-generation value produced
        #                                  outside the template
        slots.append(CarrySlot(update=st, final=final_of[st],
                               init=t, read=t))
        updates.add(st)

    # products the epilogue (or the program outputs) read must come from
    # the final rolled generation; thread them as output-only slots
    region_products = {o for u in units[first:first + P * n] for o in u.ops}
    needed_after = set(program.outputs)
    for u in units[first + P * n:]:
        for o in u.ops:
            needed_after.update(program.nodes[o].inputs)
    final_to_template = {f: o for o, f in final_of.items()}
    for f in sorted(needed_after & region_products):
        o = final_to_template.get(f)
        if o is None:
            return None                  # a mid-generation value escapes
        if o not in updates:
            updates.add(o)
            slots.append(CarrySlot(update=o, final=f, init=None,
                                   read=None))
    if not slots:
        return None                      # iterations that carry nothing
    return RolledLoop(first=first, per_iter=P, n_iters=n,
                      slots=tuple(slots))


@dataclasses.dataclass(frozen=True)
class ExecPlan:
    """Execution-level plan for one compiled frontend plan: the fused
    dispatch units, the residency spans they imply, and the rolled
    iteration segment (when one was proven)."""
    units: Tuple[ExecUnit, ...]
    roll: Optional[RolledLoop]
    spans: Tuple[ResidentSpan, ...]
    n_prefuse: int                      # unit count before residency fusion

    def describe(self) -> str:
        bits = [f"{len(self.units)} units"]
        if len(self.units) != self.n_prefuse:
            bits.append(f"fused from {self.n_prefuse} passes")
        if self.roll is not None:
            r = self.roll
            bits.append(f"units[u{r.first}..u{r.first + r.per_iter - 1}] "
                        f"rolled x{r.n_iters}")
        carried = [sp for sp in self.spans if sp.last > sp.first]
        if carried:
            bits.append("resident across units: " + ", ".join(
                f"{sp.tensor}[u{sp.first}..u{sp.last}]" for sp in carried))
        return "; ".join(bits)


def plan_execution(graph: OpGraph, kernels, explicit_bytes: int,
                   program=None, partial=None) -> ExecPlan:
    """Units → residency fusion → rolled-loop detection, in that order.
    ``program`` (the frontend expression DAG) is optional; without it the
    plan is straight-line.  ``partial`` carries the overbooked pin set's
    per-tensor :class:`~repro.core.schedule.PartialPin` records so merged
    passes keep their :class:`ResidentSlice` annotations."""
    units = flatten_units(kernels)
    n_pre = len(units)
    fused = fuse_units(graph, units, explicit_bytes, partial)
    roll = detect_rolled_loop(program, fused)
    return ExecPlan(units=fused, roll=roll, spans=resident_spans(fused),
                    n_prefuse=n_pre)


# ---------------------------------------------------------------------------
# mesh partitioning: contiguous row-block shards of an ExecPlan
# ---------------------------------------------------------------------------
#
# A co-designed :class:`ExecPlan` runs its streamed passes over one global
# leading dimension.  :func:`partition_plan` splits that dimension into K
# contiguous row blocks — one per device of a 1-D ``jax.sharding.Mesh`` —
# and proves the split is sound for every unit of the plan:
#
#   * dense streamed operands split into equal row blocks (a shard is a
#     :class:`ResidentSlice` with a nonzero ``row0``, reusing the
#     overbooked-pin machinery rather than re-inventing it);
#   * CSR operands split at *indptr-aligned* row boundaries: the exact
#     per-shard entry windows come from the deterministic pattern
#     generators (``frontends.sparse.row_counts``), padded to one static
#     per-shard width so every shard traces the same program;
#   * contraction right-hand sides and spmv ``x`` vectors are exchanged
#     whole (``all_gather``) before each pass — the gathered-x exchange;
#   * ``stencil2d`` sweeps exchange one halo row with each mesh neighbour
#     (``ppermute``) instead of gathering the grid;
#   * rank-0 dot/norm reductions combine per-shard partials with ``psum``
#     (the reference oracle instead gathers operands whole so its sharded
#     results stay bitwise-identical to the single-device rules).
#
# Shapes the row-block story cannot express raise
# :class:`PlanPartitionError` — loudly, at lower time, never at dispatch.

class PlanPartitionError(ValueError):
    """A co-designed plan cannot be split into contiguous row blocks."""


@dataclasses.dataclass(frozen=True)
class CsrShardLayout:
    """Static row-block split of one CSR operand triple.

    ``entry_starts[k]`` is the global CSR entry index of shard ``k``'s
    first row (``entry_starts[K] == nnz``) — by construction the value of
    ``indptr[k * rows_per_shard]``, so every boundary is indptr-aligned.
    At dispatch each shard slices ``pad_entries`` entries starting at its
    boundary out of the (zero-padded) global indices/data, so all shards
    share one static shape; positions past a shard's true window resolve
    to local row id ``rows_per_shard`` and are dropped by the same
    out-of-range mask the tile kernels already apply."""
    indptr: str
    indices: str
    data: str
    rows: int                        # global row count
    nnz: int                         # global stored entries
    entry_starts: Tuple[int, ...]    # len n_shards + 1, indptr-aligned
    pad_entries: int                 # static per-shard entry window
    slices: Tuple[ResidentSlice, ...]   # shard k's row/entry window

    def describe(self) -> str:
        blocks = "/".join(str(b - a) for a, b in
                          zip(self.entry_starts, self.entry_starts[1:]))
        return (f"csr[{self.data}: {self.rows}r {self.nnz}nnz -> "
                f"{blocks} entries, pad {self.pad_entries}]")


@dataclasses.dataclass(frozen=True)
class ShardedExecPlan:
    """A partitioned execution plan: the single-device plan, its localized
    (per-shard) twin, and everything an executor needs to wire the
    exchanges — which names are row-sharded, which get gathered whole,
    which ops halo-exchange, and which rank-0 values psum."""
    base: ExecPlan                   # global plan (unchanged)
    local: ExecPlan                  # per-shard plan: rows / tiles ÷ K
    n_shards: int
    axis: str                        # mesh axis name
    rows: int                        # global streamed leading dim
    shards: Tuple[ResidentSlice, ...]      # shard k's row block
    csr: Tuple[CsrShardLayout, ...]        # per CSR operand triple
    sharded: Tuple[str, ...]         # names split along their leading dim
    gathered: Tuple[str, ...]        # row-sharded names exchanged whole
    halo: Tuple[str, ...]            # ops needing halo exchange
    reduced: Tuple[str, ...]         # rank-0 values combined across shards

    @property
    def rows_per_shard(self) -> int:
        return self.rows // self.n_shards

    def is_sharded(self, name: str) -> bool:
        return name in self._sharded_set

    @property
    def _sharded_set(self):
        return set(self.sharded)

    def describe(self) -> str:
        bits = [f"{self.n_shards} shards x {self.rows_per_shard} rows "
                f"over '{self.axis}'"]
        if self.gathered:
            bits.append("gather=" + "+".join(self.gathered))
        if self.reduced:
            bits.append("psum=" + "+".join(self.reduced))
        if self.halo:
            bits.append("halo=" + "+".join(self.halo))
        for lay in self.csr:
            bits.append(lay.describe())
        return "; ".join(bits)


def _localize_tile(tile_rows: int, rows_loc: int) -> int:
    """The per-shard row tile: the global tile when it still divides the
    local row count, otherwise the largest divisor not exceeding it."""
    t = min(tile_rows, rows_loc)
    if rows_loc % t:
        t = math.gcd(t, rows_loc)
    return max(t, 1)


def _localize_pass(sp: StreamPass, n_shards: int) -> StreamPass:
    rows_loc = sp.rows // n_shards
    return dataclasses.replace(
        sp, rows=rows_loc, tile_rows=_localize_tile(sp.tile_rows, rows_loc))


def _csr_layout(program, node, n_shards: int) -> CsrShardLayout:
    """Indptr-aligned entry windows for one spmv's CSR triple, derived
    from the deterministic pattern meta on the triple's leaves."""
    from ..frontends.sparse import row_counts
    indptr, indices, data = node.inputs[:3]
    rows = int(node.shape[0])
    nnz = int(program.nodes[indices].shape[0])
    leaf = program.nodes[indptr]
    pattern = leaf.param("pattern")
    if pattern is None:
        raise PlanPartitionError(
            f"spmv '{node.name}': CSR operand '{data}' carries no pattern "
            f"meta; cannot compute indptr-aligned shard boundaries")
    try:
        counts = row_counts(pattern, rows,
                            density=leaf.param("density"),
                            bandwidth=leaf.param("bandwidth"))
    except Exception as e:                       # unknown pattern/params
        raise PlanPartitionError(
            f"spmv '{node.name}': unusable CSR pattern meta "
            f"({pattern!r}): {e}") from e
    cum = [0]
    for c in counts:
        cum.append(cum[-1] + int(c))
    if cum[-1] != nnz:
        raise PlanPartitionError(
            f"spmv '{node.name}': pattern meta predicts {cum[-1]} entries "
            f"but '{indices}' holds {nnz}")
    rows_loc = rows // n_shards
    starts = tuple(cum[k * rows_loc] for k in range(n_shards + 1))
    widest = max(b - a for a, b in zip(starts, starts[1:]))
    pad = max(8, -(-widest // 8) * 8)
    slices = tuple(
        ResidentSlice(tensors=(indptr, indices, data), rows=rows_loc,
                      total_rows=rows, entries=starts[k + 1] - starts[k],
                      total_entries=nnz, row0=k * rows_loc,
                      entry0=starts[k])
        for k in range(n_shards))
    return CsrShardLayout(indptr=indptr, indices=indices, data=data,
                          rows=rows, nnz=nnz, entry_starts=starts,
                          pad_entries=pad, slices=slices)


def partition_plan(exec_plan: ExecPlan, mesh_axes, *,
                   program) -> ShardedExecPlan:
    """Split a co-designed :class:`ExecPlan` into contiguous row blocks.

    ``mesh_axes`` is either the shard count ``K`` or an ``(axis, K)``
    pair naming the 1-D mesh axis.  ``program`` is the frontend
    expression :class:`~repro.frontends.expr.Program` the plan was
    lowered from — partitioning needs its op/shape/CSR-meta view.

    Raises :class:`PlanPartitionError` for anything the row-block story
    cannot express: ragged row counts, einsums other than ``ab,b->a`` /
    ``a,a->``, irregular gathers/scans, overbooked partial pins
    (fractional residency and sharding both claim the row dimension),
    non-scalar jnp fallbacks, or CSR operands without consistent
    deterministic pattern meta."""
    axis, n_shards = (("shards", mesh_axes) if isinstance(mesh_axes, int)
                      else (mesh_axes[0], int(mesh_axes[1])))
    if n_shards < 1:
        raise PlanPartitionError(f"shard count must be >= 1, got {n_shards}")
    if program is None:
        raise PlanPartitionError(
            "partitioning needs the frontend expression program "
            "(plan was lowered without one)")

    rows: Optional[int] = None

    def claim_rows(n: int, what: str) -> None:
        nonlocal rows
        if rows is None:
            rows = n
        elif rows != n:
            raise PlanPartitionError(
                f"{what}: leading dim {n} != plan row dim {rows}; "
                f"mixed streamed lengths cannot share one row split")

    csr: Dict[str, CsrShardLayout] = {}
    gathered: List[str] = []
    halo: List[str] = []
    reduced: List[str] = []

    for unit in exec_plan.units:
        if unit.kind == "stream":
            sp = unit.sp
            if sp.slices:
                raise PlanPartitionError(
                    f"pass {'+'.join(sp.ops)} carries overbooked partial "
                    f"pins; fractional residency and mesh sharding both "
                    f"claim the row dimension — re-codesign with "
                    f"overbook=0 to shard")
            claim_rows(sp.rows, f"pass {'+'.join(sp.ops)}")
            for o in sp.ops:
                nd = program.nodes[o]
                if nd.op == "spmv":
                    data = nd.inputs[2]
                    if data not in csr:
                        csr[data] = _csr_layout(program, nd, n_shards)
                    x = nd.inputs[3]
                    if (program.nodes[x].shape
                            and program.nodes[x].shape[0] == sp.rows
                            and x not in gathered):
                        gathered.append(x)
                elif nd.op in ("matmul", "einsum") and nd.shape != ():
                    spec = nd.param("spec")
                    if spec != "ab,b->a":
                        raise PlanPartitionError(
                            f"op '{o}': einsum {spec!r} has no row-block "
                            f"split (only 'ab,b->a' contractions and "
                            f"'a,a->' reductions shard)")
                    rhs = nd.inputs[1]
                    if (program.nodes[rhs].shape
                            and program.nodes[rhs].shape[0] == sp.rows
                            and rhs not in gathered):
                        gathered.append(rhs)
                elif (nd.op in ("dot", "norm")
                      or (nd.op in ("matmul", "einsum")
                          and nd.shape == ())):
                    # rank-0 reductions over streamed vectors: per-shard
                    # partials combine with psum (scalar ew epilogues
                    # recompute replicated from those, no exchange)
                    if o not in reduced:
                        reduced.append(o)
        elif unit.kind == "block":
            for o in unit.ops:
                nd = program.nodes[o]
                claim_rows(nd.shape[0], f"block op '{o}'")
                if nd.op == "stencil2d":
                    halo.append(o)
        else:                                    # jnp fallback
            for o in unit.ops:
                nd = program.nodes[o]
                if nd.irregular or nd.op in ("gather", "scan"):
                    raise PlanPartitionError(
                        f"op '{o}' ({nd.op}) is data-dependent; "
                        f"irregular addressing has no contiguous row split")
                if nd.shape != ():
                    raise PlanPartitionError(
                        f"jnp-fallback op '{o}' produces shape "
                        f"{nd.shape}; only scalar fallbacks replicate")

    if rows is None:
        raise PlanPartitionError("plan has no streamed rows to shard")
    if rows % n_shards:
        raise PlanPartitionError(
            f"{rows} rows do not split evenly over {n_shards} shards")

    rows_loc = rows // n_shards
    csr_members = {m for lay in csr.values()
                   for m in (lay.indptr, lay.indices, lay.data)}
    sharded = tuple(
        n for n, nd in program.nodes.items()
        if nd.shape and nd.shape[0] == rows and n not in csr_members)

    local_units = tuple(
        dataclasses.replace(u, sp=_localize_pass(u.sp, n_shards))
        if u.kind == "stream" else u
        for u in exec_plan.units)
    local = dataclasses.replace(exec_plan, units=local_units)

    shards = tuple(
        ResidentSlice(tensors=(), rows=rows_loc, total_rows=rows,
                      entries=0, total_entries=0, row0=k * rows_loc)
        for k in range(n_shards))
    return ShardedExecPlan(
        base=exec_plan, local=local, n_shards=n_shards, axis=axis,
        rows=rows, shards=shards, csr=tuple(csr.values()),
        sharded=sharded, gathered=tuple(gathered), halo=tuple(halo),
        reduced=tuple(reduced))
