"""Per-architecture layer DAG builders for the CELLO co-designer.

These graphs are the *analysis-level* view of one transformer block (plus
optional embedding/logits stages): enough fidelity in shapes/FLOPs/bytes for
the schedule × buffer co-design and the speedup/energy tables, at tensor
granularity.  The *execution-level* view is `repro.models` + `repro.kernels`;
`core.policy` connects the two (fusion groups found here select kernels and
remat save-sets there).

Conventions:
  * batch and sequence are flattened where attention doesn't need them apart,
  * GQA is modelled with K/V at their true (smaller) kv-head sizes while the
    score/PV contractions carry full-head FLOPs (broadcast is free),
  * data-dependent ops (MoE top-k routing/dispatch) are marked ``irregular``
    — the co-designer must leave their reuse to the implicit region,
  * recurrences (RG-LRU, WKV6) are ``scan`` ops — unfusable with neighbours
    except via their dedicated kernels; their *state* is a pin candidate.
"""
from __future__ import annotations

from typing import Optional

from ..configs.base import ArchConfig
from .graph import OpGraph, TensorKind

BF16 = 2
F32 = 4


def _set_flops(g, name, inputs, out, out_shape, flops, dtype_bytes,
               out_kind, irregular):
    """Contraction node with explicit output shape/FLOPs (covers broadcasty
    einsums the strict parser can't express, e.g. GQA score contractions)."""
    op = g.elementwise(name, inputs, out, out_shape=out_shape,
                       flops_per_elem=0, dtype_bytes=dtype_bytes,
                       out_kind=out_kind, spec="contract",
                       irregular=irregular)
    op.flops = int(flops)
    return op


def attention_block(g: OpGraph, cfg: ArchConfig, prefix: str, x: str,
                    batch: int, q_len: int, kv_len: int,
                    cross_kv: Optional[str] = None,
                    out_kind: TensorKind = TensorKind.INTERMEDIATE) -> str:
    """Standard (GQA / sliding-window / cross) attention sub-DAG. Returns the
    name of the block output tensor (pre-residual)."""
    d, h, kvh, e = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b, s = batch, q_len
    z = kv_len if cfg.window is None else min(kv_len, cfg.window)

    g.tensor(f"{prefix}.wq", (d, h * e), kind=TensorKind.WEIGHT)
    g.tensor(f"{prefix}.wo", (h * e, d), kind=TensorKind.WEIGHT)
    _set_flops(g, f"{prefix}.q", [x, f"{prefix}.wq"], f"{prefix}.q_out",
               (b, s, h, e), 2 * b * s * d * h * e, BF16,
               TensorKind.INTERMEDIATE, False)

    if cross_kv is None:
        g.tensor(f"{prefix}.wk", (d, kvh * e), kind=TensorKind.WEIGHT)
        g.tensor(f"{prefix}.wv", (d, kvh * e), kind=TensorKind.WEIGHT)
        _set_flops(g, f"{prefix}.k", [x, f"{prefix}.wk"], f"{prefix}.k_out",
                   (b, z, kvh, e), 2 * b * z * d * kvh * e, BF16,
                   TensorKind.INTERMEDIATE, False)
        _set_flops(g, f"{prefix}.v", [x, f"{prefix}.wv"], f"{prefix}.v_out",
                   (b, z, kvh, e), 2 * b * z * d * kvh * e, BF16,
                   TensorKind.INTERMEDIATE, False)
        k_t, v_t = f"{prefix}.k_out", f"{prefix}.v_out"
    else:
        # cross-attention: K/V come from the (pinned-candidate) image tensor
        k_t = v_t = cross_kv

    # scores + softmax + PV: FLOPs carry full h heads (GQA broadcast free)
    _set_flops(g, f"{prefix}.scores", [f"{prefix}.q_out", k_t],
               f"{prefix}.scores_out", (b, h, s, z),
               2 * b * h * s * z * e, BF16, TensorKind.INTERMEDIATE, False)
    g.elementwise(f"{prefix}.softmax", [f"{prefix}.scores_out"],
                  f"{prefix}.probs", flops_per_elem=5)
    _set_flops(g, f"{prefix}.pv", [f"{prefix}.probs", v_t],
               f"{prefix}.pv_out", (b, s, h, e),
               2 * b * h * s * z * e, BF16, TensorKind.INTERMEDIATE, False)
    _set_flops(g, f"{prefix}.o", [f"{prefix}.pv_out", f"{prefix}.wo"],
               f"{prefix}.attn_out", (b, s, d), 2 * b * s * h * e * d,
               BF16, out_kind, False)
    return f"{prefix}.attn_out"


def mlp_block(g: OpGraph, cfg: ArchConfig, prefix: str, x: str,
              tokens: int, out_kind: TensorKind = TensorKind.INTERMEDIATE) -> str:
    d, f = cfg.d_model, cfg.d_ff
    gated = cfg.activation in ("swiglu", "geglu")
    if cfg.is_moe:
        return moe_block(g, cfg, prefix, x, tokens, out_kind)
    g.tensor(f"{prefix}.w_up", (d, (2 if gated else 1) * f),
             kind=TensorKind.WEIGHT)
    g.tensor(f"{prefix}.w_down", (f, d), kind=TensorKind.WEIGHT)
    _set_flops(g, f"{prefix}.up", [x, f"{prefix}.w_up"], f"{prefix}.h",
               (tokens, (2 if gated else 1) * f),
               2 * tokens * d * (2 if gated else 1) * f, BF16,
               TensorKind.INTERMEDIATE, False)
    g.elementwise(f"{prefix}.act", [f"{prefix}.h"], f"{prefix}.a",
                  flops_per_elem=4, out_shape=(tokens, f))
    _set_flops(g, f"{prefix}.down", [f"{prefix}.a", f"{prefix}.w_down"],
               f"{prefix}.mlp_out", (tokens, d), 2 * tokens * f * d,
               BF16, out_kind, False)
    return f"{prefix}.mlp_out"


def moe_block(g: OpGraph, cfg: ArchConfig, prefix: str, x: str,
              tokens: int, out_kind: TensorKind = TensorKind.INTERMEDIATE) -> str:
    """Top-k MoE FFN.  Routing/dispatch are data-dependent ⇒ irregular:
    their reuse must live in the implicit region (the CELLO showcase)."""
    d, f, E, k = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.top_k
    gated = cfg.activation in ("swiglu", "geglu")
    g.tensor(f"{prefix}.w_router", (d, E), kind=TensorKind.WEIGHT)
    g.tensor(f"{prefix}.w_up_e", (E, d, (2 if gated else 1) * f),
             kind=TensorKind.WEIGHT)
    g.tensor(f"{prefix}.w_down_e", (E, f, d), kind=TensorKind.WEIGHT)
    _set_flops(g, f"{prefix}.router", [x, f"{prefix}.w_router"],
               f"{prefix}.logits", (tokens, E), 2 * tokens * d * E, F32,
               TensorKind.INTERMEDIATE, False)
    g.elementwise(f"{prefix}.topk", [f"{prefix}.logits"], f"{prefix}.gates",
                  flops_per_elem=2, out_shape=(tokens, k), dtype_bytes=F32,
                  irregular=True)
    # dispatch: gather tokens to experts (data-dependent addressing)
    g.elementwise(f"{prefix}.dispatch", [x, f"{prefix}.gates"],
                  f"{prefix}.xe", flops_per_elem=0,
                  out_shape=(tokens * k, d), irregular=True, spec="gather")
    _set_flops(g, f"{prefix}.up", [f"{prefix}.xe", f"{prefix}.w_up_e"],
               f"{prefix}.h", (tokens * k, (2 if gated else 1) * f),
               2 * tokens * k * d * (2 if gated else 1) * f, BF16,
               TensorKind.INTERMEDIATE, False)
    g.elementwise(f"{prefix}.act", [f"{prefix}.h"], f"{prefix}.a",
                  flops_per_elem=4, out_shape=(tokens * k, f))
    _set_flops(g, f"{prefix}.down", [f"{prefix}.a", f"{prefix}.w_down_e"],
               f"{prefix}.ye", (tokens * k, d), 2 * tokens * k * f * d,
               BF16, TensorKind.INTERMEDIATE, False)
    # combine: weighted scatter-add back to token order (data-dependent)
    g.elementwise(f"{prefix}.combine", [f"{prefix}.ye", f"{prefix}.gates"],
                  f"{prefix}.mlp_out", flops_per_elem=2 * k,
                  out_shape=(tokens, d), irregular=True, spec="gather",
                  out_kind=out_kind)
    return f"{prefix}.mlp_out"


def rglru_block(g: OpGraph, cfg: ArchConfig, prefix: str, x: str,
                batch: int, seq: int) -> str:
    """RG-LRU recurrent block (recurrentgemma): gated linear recurrence."""
    d = cfg.d_model
    b, s = batch, seq
    for w in ("wx", "wgate", "wa", "wout"):
        g.tensor(f"{prefix}.{w}", (d, d), kind=TensorKind.WEIGHT)
    _set_flops(g, f"{prefix}.proj", [x, f"{prefix}.wx"], f"{prefix}.xb",
               (b, s, d), 2 * b * s * d * d, BF16,
               TensorKind.INTERMEDIATE, False)
    _set_flops(g, f"{prefix}.gates", [x, f"{prefix}.wgate", f"{prefix}.wa"],
               f"{prefix}.g", (b, s, 2 * d), 2 * b * s * d * 2 * d, BF16,
               TensorKind.INTERMEDIATE, False)
    # the recurrence itself: sequential along s => 'scan' op
    op = g.elementwise(f"{prefix}.scan", [f"{prefix}.xb", f"{prefix}.g"],
                       f"{prefix}.h", flops_per_elem=8, out_shape=(b, s, d),
                       spec="scan")
    _set_flops(g, f"{prefix}.out", [f"{prefix}.h", f"{prefix}.wout"],
               f"{prefix}.rglru_out", (b, s, d), 2 * b * s * d * d, BF16,
               TensorKind.INTERMEDIATE, False)
    return f"{prefix}.rglru_out"


def rwkv_block(g: OpGraph, cfg: ArchConfig, prefix: str, x: str,
               batch: int, seq: int) -> str:
    """RWKV6 time-mix: r/k/v/g projections + WKV6 recurrence + output."""
    d = cfg.d_model
    b, s = batch, seq
    H, e = cfg.n_heads, cfg.resolved_head_dim
    for w in ("wr", "wk", "wv", "wg", "wo", "ww"):
        g.tensor(f"{prefix}.{w}", (d, d), kind=TensorKind.WEIGHT)
    _set_flops(g, f"{prefix}.rkvg", [x, f"{prefix}.wr", f"{prefix}.wk",
                                     f"{prefix}.wv", f"{prefix}.wg",
                                     f"{prefix}.ww"],
               f"{prefix}.rkvg_out", (b, s, 5 * d), 2 * b * s * d * 5 * d,
               BF16, TensorKind.INTERMEDIATE, False)
    # WKV6 recurrence: per head, state (e x e) updated per step
    op = g.elementwise(f"{prefix}.wkv", [f"{prefix}.rkvg_out"],
                       f"{prefix}.wkv_out", flops_per_elem=0,
                       out_shape=(b, s, d), spec="scan")
    op.flops = 2 * b * s * H * e * e * 4       # state update + readout
    _set_flops(g, f"{prefix}.out", [f"{prefix}.wkv_out", f"{prefix}.wo"],
               f"{prefix}.rwkv_out", (b, s, d), 2 * b * s * d * d, BF16,
               TensorKind.INTERMEDIATE, False)
    return f"{prefix}.rwkv_out"


def layer_graph(cfg: ArchConfig, batch: int, seq: int, *,
                layer_kind: Optional[str] = None,
                include_residuals: bool = True) -> OpGraph:
    """One transformer block as an OpGraph (the CELLO unit of analysis).

    The residual stream exhibits the paper's "complex reuse": ``x`` feeds the
    norm AND the residual add (two consumers, different distances); the block
    output feeds the next norm and the next residual add likewise.
    """
    kind = layer_kind or cfg.layer_kinds()[0]
    g = OpGraph(f"{cfg.name}:{kind}:b{batch}s{seq}")
    d = cfg.d_model
    tokens = batch * seq
    g.tensor("x", (batch, seq, d), kind=TensorKind.INPUT)
    g.tensor("ln1.w", (d,), kind=TensorKind.WEIGHT)
    g.tensor("ln2.w", (d,), kind=TensorKind.WEIGHT)
    g.elementwise("ln1", ["x", "ln1.w"], "x_n1", flops_per_elem=6)

    if kind == "attn":
        y = attention_block(g, cfg, "attn", "x_n1", batch, seq, seq)
    elif kind == "xattn":
        g.tensor("img_kv", (batch, cfg.vision_seq, 2 * cfg.n_kv_heads *
                            cfg.resolved_head_dim), kind=TensorKind.INPUT)
        y = attention_block(g, cfg, "xattn", "x_n1", batch, seq,
                            cfg.vision_seq, cross_kv="img_kv")
    elif kind == "rglru":
        y = rglru_block(g, cfg, "rglru", "x_n1", batch, seq)
    elif kind == "rwkv":
        y = rwkv_block(g, cfg, "rwkv", "x_n1", batch, seq)
    else:
        raise ValueError(kind)

    if include_residuals:
        g.elementwise("res1", ["x", y], "x_mid", flops_per_elem=1)
        src = "x_mid"
    else:
        src = y
    g.elementwise("ln2", [src, "ln2.w"], "x_n2", flops_per_elem=6)
    m = mlp_block(g, cfg, "mlp", "x_n2", tokens)
    if include_residuals:
        g.elementwise("res2", [src, m], "x_out", flops_per_elem=1,
                      out_kind=TensorKind.OUTPUT, out_shape=(batch, seq, d))
    g.validate()
    return g


def decode_graph(cfg: ArchConfig, batch: int, kv_len: int) -> OpGraph:
    """Single-token decode step for one layer: KV-cache reuse pattern.

    The cache is an INPUT consumed by scores/PV and extended (OUTPUT) — the
    canonical multi-distance reuse tensor for serving.
    """
    kind = next((k for k in cfg.layer_kinds() if k in ("attn", "rwkv")),
                cfg.layer_kinds()[0])
    g = OpGraph(f"{cfg.name}:decode:b{batch}kv{kv_len}")
    d, h, kvh, e = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim)
    b = batch
    z = kv_len if cfg.window is None else min(kv_len, cfg.window)
    g.tensor("x", (b, 1, d), kind=TensorKind.INPUT)
    g.tensor("ln1.w", (d,), kind=TensorKind.WEIGHT)
    g.elementwise("ln1", ["x", "ln1.w"], "x_n1", flops_per_elem=6)
    if kind == "rwkv":
        g.tensor("state", (b, cfg.n_heads, e, e), dtype_bytes=F32,
                 kind=TensorKind.INPUT)
        for w in ("wr", "wk", "wv", "wo"):
            g.tensor(f"t.{w}", (d, d), kind=TensorKind.WEIGHT)
        _set_flops(g, "t.rkv", ["x_n1", "t.wr", "t.wk", "t.wv"], "t.rkv_out",
                   (b, 1, 3 * d), 2 * b * d * 3 * d, BF16,
                   TensorKind.INTERMEDIATE, False)
        op = g.elementwise("t.wkv", ["t.rkv_out", "state"], "t.y",
                           flops_per_elem=0, out_shape=(b, 1, d), spec="scan")
        op.flops = 2 * b * cfg.n_heads * e * e * 4
        g.elementwise("t.state_new", ["t.rkv_out", "state"], "state_out",
                      flops_per_elem=2, out_shape=(b, cfg.n_heads, e, e),
                      dtype_bytes=F32, out_kind=TensorKind.OUTPUT)
        _set_flops(g, "t.o", ["t.y", "t.wo"], "attn_out", (b, 1, d),
                   2 * b * d * d, BF16, TensorKind.INTERMEDIATE, False)
        y = "attn_out"
    else:
        g.tensor("k_cache", (b, z, kvh, e), kind=TensorKind.INPUT)
        g.tensor("v_cache", (b, z, kvh, e), kind=TensorKind.INPUT)
        g.tensor("attn.wq", (d, h * e), kind=TensorKind.WEIGHT)
        g.tensor("attn.wk", (d, kvh * e), kind=TensorKind.WEIGHT)
        g.tensor("attn.wv", (d, kvh * e), kind=TensorKind.WEIGHT)
        g.tensor("attn.wo", (h * e, d), kind=TensorKind.WEIGHT)
        _set_flops(g, "attn.q", ["x_n1", "attn.wq"], "q", (b, 1, h, e),
                   2 * b * d * h * e, BF16, TensorKind.INTERMEDIATE, False)
        _set_flops(g, "attn.kv_new", ["x_n1", "attn.wk", "attn.wv"], "kv_new",
                   (b, 1, 2 * kvh, e), 4 * b * d * kvh * e, BF16,
                   TensorKind.OUTPUT, False)
        _set_flops(g, "attn.scores", ["q", "k_cache"], "scores", (b, h, 1, z),
                   2 * b * h * z * e, BF16, TensorKind.INTERMEDIATE, False)
        g.elementwise("attn.softmax", ["scores"], "probs", flops_per_elem=5)
        _set_flops(g, "attn.pv", ["probs", "v_cache"], "ctx", (b, 1, h, e),
                   2 * b * h * z * e, BF16, TensorKind.INTERMEDIATE, False)
        _set_flops(g, "attn.o", ["ctx", "attn.wo"], "attn_out", (b, 1, d),
                   2 * b * h * e * d, BF16, TensorKind.INTERMEDIATE, False)
        y = "attn_out"
    g.elementwise("res1", ["x", y], "x_mid", flops_per_elem=1)
    g.tensor("ln2.w", (d,), kind=TensorKind.WEIGHT)
    g.elementwise("ln2", ["x_mid", "ln2.w"], "x_n2", flops_per_elem=6)
    m = mlp_block(g, cfg, "mlp", "x_n2", b)
    g.elementwise("res2", ["x_mid", m], "x_out", flops_per_elem=1,
                  out_kind=TensorKind.OUTPUT, out_shape=(b, 1, d))
    g.validate()
    return g
