"""Tensor-operation DAG IR for CELLO schedule / buffer co-design.

The unit CELLO schedules is a DAG of *tensor operations* (einsums and
elementwise ops) over named tensors.  "Complex tensor reuse" means a tensor
in this DAG has multiple consumers at different reuse distances, so neither
pure producer→consumer fusion nor a pure cache captures all of its reuse.

This IR is deliberately small: enough structure for the reuse analyser
(`core.reuse`), the hybrid-buffer simulator (`core.buffer`) and the co-design
search (`core.schedule`) to reason about traffic, and enough metadata
(FLOPs, bytes) for the speedup/energy cost model (`core.costmodel`).
"""
from __future__ import annotations

import contextlib
import dataclasses
import enum
import math
import re
from typing import Dict, Iterator, List, Optional, Sequence, Tuple


class TensorKind(enum.Enum):
    INPUT = "input"          # supplied by the invoking context (activations in)
    WEIGHT = "weight"        # parameters: resident in HBM, read-only
    INTERMEDIATE = "inter"   # produced and consumed inside the DAG
    OUTPUT = "output"        # must be written back to HBM at the end


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """A named dense tensor in the op DAG.

    ``meta`` carries optional frontend annotations as a hashable
    ``((key, value), ...)`` tuple — e.g. the CSR pattern parameters of a
    sparse operand's sub-leaves, which let the pin search compute exact
    indptr-aligned row prefixes without re-deriving the pattern.
    """
    name: str
    shape: Tuple[int, ...]
    dtype_bytes: int = 2            # bf16 default
    kind: TensorKind = TensorKind.INTERMEDIATE
    meta: Tuple[Tuple[str, object], ...] = ()

    @property
    def elements(self) -> int:
        return int(math.prod(self.shape)) if self.shape else 1

    @property
    def bytes(self) -> int:
        return self.elements * self.dtype_bytes

    def meta_get(self, key: str, default=None):
        for k, v in self.meta:
            if k == key:
                return v
        return default


_EINSUM_RE = re.compile(r"^([a-zA-Z,\.]+)->([a-zA-Z]*)$")


def _parse_einsum(spec: str) -> Tuple[List[str], str]:
    m = _EINSUM_RE.match(spec.replace(" ", ""))
    if not m:
        raise ValueError(f"bad einsum spec: {spec!r}")
    lhs, rhs = m.groups()
    return lhs.split(","), rhs


@dataclasses.dataclass
class OpNode:
    """One tensor operation.

    ``spec`` is an einsum string for contractions ("mk,kn->mn"), or one of
    the pseudo-specs ``"ew"`` (elementwise over all inputs, output shape =
    first input), ``"reduce"`` (elementwise + reduction), ``"scan"``
    (sequential recurrence along the leading axis — unfusable across time
    without a dedicated kernel), ``"gather"`` (data-dependent addressing —
    reuse is *irregular*, the CELLO scheduler must leave it to the implicit
    region).
    """
    name: str
    spec: str
    inputs: Tuple[str, ...]
    output: str
    flops: int = 0                  # 2 * MACs for contractions
    # data-dependent ops (gather / top-k dispatch) have irregular reuse:
    # the co-designer may not pin them in the explicit region.
    irregular: bool = False

    @property
    def is_einsum(self) -> bool:
        return "->" in self.spec


class OpGraph:
    """A DAG of tensor ops with dense-shape metadata."""

    def __init__(self, name: str = "graph"):
        self.name = name
        self.tensors: Dict[str, TensorSpec] = {}
        self.ops: Dict[str, OpNode] = {}
        self._order: List[str] = []     # insertion order (a valid topo order)
        # maintained O(1) indices (tensor name -> producing/consuming ops)
        self._producer_of: Dict[str, OpNode] = {}
        self._consumers_of: Dict[str, List[OpNode]] = {}

    @classmethod
    @contextlib.contextmanager
    def build(cls, name: str = "graph") -> Iterator["GraphBuilder"]:
        """Context-manager builder; validates the finished graph on exit.

        Builder methods return the produced tensor's name, so DAG wiring
        flows through values instead of re-derived string keys::

            with OpGraph.build("mlp") as b:
                x = b.input("x", (128, 512))
                w = b.weight("w", (512, 512))
                y = b.einsum("mm", "mk,kn->mn", [x, w], "y",
                             out_kind=TensorKind.OUTPUT)
            graph = b.graph
        """
        builder = GraphBuilder(cls(name))
        yield builder
        builder.graph.validate()

    # -- construction -----------------------------------------------------
    def tensor(self, name: str, shape: Sequence[int], *, dtype_bytes: int = 2,
               kind: TensorKind = TensorKind.INTERMEDIATE,
               meta: Sequence[Tuple[str, object]] = ()) -> TensorSpec:
        if name in self.tensors:
            raise ValueError(f"duplicate tensor {name!r}")
        t = TensorSpec(name, tuple(int(s) for s in shape), dtype_bytes, kind,
                       tuple(meta))
        self.tensors[name] = t
        return t

    def einsum(self, name: str, spec: str, inputs: Sequence[str], output: str,
               *, dtype_bytes: int = 2,
               out_kind: TensorKind = TensorKind.INTERMEDIATE) -> OpNode:
        """Add an einsum node; infers the output shape and FLOPs."""
        in_specs, out_spec = _parse_einsum(spec)
        if len(in_specs) != len(inputs):
            raise ValueError(f"{name}: spec {spec!r} has {len(in_specs)} operands, "
                             f"got {len(inputs)} inputs")
        dim: Dict[str, int] = {}
        for sub, tname in zip(in_specs, inputs):
            t = self._expect(tname)
            if len(sub) != len(t.shape):
                raise ValueError(f"{name}: operand {tname} rank mismatch "
                                 f"({sub!r} vs shape {t.shape})")
            for ax, size in zip(sub, t.shape):
                if dim.setdefault(ax, size) != size:
                    raise ValueError(f"{name}: axis {ax} size mismatch")
        out_shape = tuple(dim[a] for a in out_spec)
        if output not in self.tensors:
            self.tensor(output, out_shape, dtype_bytes=dtype_bytes, kind=out_kind)
        macs = math.prod(dim.values())
        return self._add(OpNode(name, spec, tuple(inputs), output, flops=2 * macs))

    def elementwise(self, name: str, inputs: Sequence[str], output: str,
                    *, flops_per_elem: int = 1, dtype_bytes: int = 2,
                    out_shape: Optional[Sequence[int]] = None,
                    out_kind: TensorKind = TensorKind.INTERMEDIATE,
                    spec: str = "ew", irregular: bool = False,
                    flops: Optional[int] = None) -> OpNode:
        """Elementwise-family op.  ``flops`` (total) overrides the per-elem
        estimate — used by frontends whose ops (reductions, stencils) don't
        scale with the *output* element count."""
        t0 = self._expect(inputs[0])
        shape = tuple(out_shape) if out_shape is not None else t0.shape
        if output not in self.tensors:
            self.tensor(output, shape, dtype_bytes=dtype_bytes, kind=out_kind)
        if flops is None:
            flops = flops_per_elem * int(math.prod(shape))
        return self._add(OpNode(name, spec, tuple(inputs), output,
                                flops=int(flops), irregular=irregular))

    def _add(self, op: OpNode) -> OpNode:
        if op.name in self.ops:
            raise ValueError(f"duplicate op {op.name!r}")
        for t in op.inputs:
            self._expect(t)
        self.ops[op.name] = op
        self._order.append(op.name)
        # first writer wins, matching the original linear-scan lookup
        self._producer_of.setdefault(op.output, op)
        for t in dict.fromkeys(op.inputs):
            self._consumers_of.setdefault(t, []).append(op)
        return op

    def _expect(self, tname: str) -> TensorSpec:
        if tname not in self.tensors:
            raise KeyError(f"unknown tensor {tname!r}")
        return self.tensors[tname]

    # -- queries ----------------------------------------------------------
    def producer(self, tname: str) -> Optional[OpNode]:
        return self._producer_of.get(tname)

    def consumers(self, tname: str) -> List[OpNode]:
        return list(self._consumers_of.get(tname, ()))

    def topo_order(self) -> List[str]:
        """Insertion order (construction enforces def-before-use)."""
        return list(self._order)

    def all_topo_orders(self, limit: int = 200) -> List[List[str]]:
        """Enumerate topological orders (bounded); used by exhaustive search."""
        preds: Dict[str, set] = {o: set() for o in self.ops}
        for op in self.ops.values():
            for t in op.inputs:
                p = self.producer(t)
                if p is not None:
                    preds[op.name].add(p.name)
        out: List[List[str]] = []

        def rec(done: List[str], remaining: set):
            if len(out) >= limit:
                return
            if not remaining:
                out.append(list(done))
                return
            ready = sorted(o for o in remaining if preds[o] <= set(done))
            for o in ready:
                done.append(o)
                remaining.remove(o)
                rec(done, remaining)
                remaining.add(o)
                done.pop()

        rec([], set(self.ops))
        return out

    def validate(self) -> None:
        seen: set = set()
        defined = {t.name for t in self.tensors.values()
                   if t.kind in (TensorKind.INPUT, TensorKind.WEIGHT)}
        for oname in self._order:
            op = self.ops[oname]
            for t in op.inputs:
                if t not in defined:
                    raise ValueError(f"{oname}: input {t} used before defined")
            defined.add(op.output)
            seen.add(oname)
        # outputs must be produced
        for t in self.tensors.values():
            if t.kind == TensorKind.OUTPUT and self.producer(t.name) is None:
                raise ValueError(f"output tensor {t.name} has no producer")

    # -- stats ------------------------------------------------------------
    @property
    def total_flops(self) -> int:
        return sum(op.flops for op in self.ops.values())

    def compulsory_bytes(self) -> int:
        """Traffic lower bound: each INPUT/WEIGHT read once, OUTPUT written once."""
        total = 0
        for t in self.tensors.values():
            if t.kind in (TensorKind.INPUT, TensorKind.WEIGHT, TensorKind.OUTPUT):
                total += t.bytes
        return total

    def arithmetic_intensity_best(self) -> float:
        """Paper-style AI_best = FLOPs / compulsory traffic (bytes)."""
        return self.total_flops / max(1, self.compulsory_bytes())

    def __repr__(self) -> str:
        return (f"OpGraph({self.name!r}, {len(self.ops)} ops, "
                f"{len(self.tensors)} tensors, {self.total_flops:.3e} FLOPs)")


class GraphBuilder:
    """Value-flow wrapper over :class:`OpGraph` construction.

    Every method returns the name of the tensor it defined, so callers wire
    the DAG by passing results forward instead of re-assembling string keys.
    Obtained from :meth:`OpGraph.build`.
    """

    def __init__(self, graph: OpGraph):
        self.graph = graph

    # -- tensors ----------------------------------------------------------
    def input(self, name: str, shape: Sequence[int], *,
              dtype_bytes: int = 2) -> str:
        return self.graph.tensor(name, shape, dtype_bytes=dtype_bytes,
                                 kind=TensorKind.INPUT).name

    def weight(self, name: str, shape: Sequence[int], *,
               dtype_bytes: int = 2,
               meta: Sequence[Tuple[str, object]] = ()) -> str:
        return self.graph.tensor(name, shape, dtype_bytes=dtype_bytes,
                                 kind=TensorKind.WEIGHT, meta=meta).name

    def weights(self, prefix: str, names: Sequence[str],
                shape: Sequence[int], *, dtype_bytes: int = 2) -> List[str]:
        return [self.weight(f"{prefix}.{n}", shape, dtype_bytes=dtype_bytes)
                for n in names]

    # -- ops --------------------------------------------------------------
    def einsum(self, name: str, spec: str, inputs: Sequence[str],
               output: str, *, dtype_bytes: int = 2,
               out_kind: TensorKind = TensorKind.INTERMEDIATE) -> str:
        return self.graph.einsum(name, spec, inputs, output,
                                 dtype_bytes=dtype_bytes,
                                 out_kind=out_kind).output

    def elementwise(self, name: str, inputs: Sequence[str], output: str, *,
                    flops_per_elem: int = 1, dtype_bytes: int = 2,
                    out_shape: Optional[Sequence[int]] = None,
                    out_kind: TensorKind = TensorKind.INTERMEDIATE,
                    spec: str = "ew", irregular: bool = False,
                    flops: Optional[int] = None) -> str:
        return self.graph.elementwise(
            name, inputs, output, flops_per_elem=flops_per_elem,
            dtype_bytes=dtype_bytes, out_shape=out_shape, out_kind=out_kind,
            spec=spec, irregular=irregular, flops=flops).output

    def contract(self, name: str, inputs: Sequence[str], output: str,
                 out_shape: Sequence[int], flops: int, *,
                 dtype_bytes: int = 2,
                 out_kind: TensorKind = TensorKind.INTERMEDIATE,
                 irregular: bool = False) -> str:
        """Contraction with explicit output shape/FLOPs — covers broadcasty
        einsums the strict parser can't express (GQA score contractions)."""
        return self.graph.elementwise(
            name, inputs, output, out_shape=out_shape,
            dtype_bytes=dtype_bytes, out_kind=out_kind, spec="contract",
            irregular=irregular, flops=int(flops)).output

    def scan(self, name: str, inputs: Sequence[str], output: str,
             out_shape: Sequence[int], *, flops: Optional[int] = None,
             flops_per_elem: int = 0, dtype_bytes: int = 2,
             out_kind: TensorKind = TensorKind.INTERMEDIATE) -> str:
        """Sequential recurrence along the leading axis (spec='scan')."""
        return self.graph.elementwise(
            name, inputs, output, out_shape=out_shape,
            flops_per_elem=flops_per_elem, dtype_bytes=dtype_bytes,
            out_kind=out_kind, spec="scan", flops=flops).output
