"""CELLO core: schedule × hybrid implicit/explicit buffer co-design.

Public API:
  graph.OpGraph / TensorKind      — tensor-op DAG IR
  reuse.analyze                   — reuse distance/frequency analysis
  buffer.BufferConfig / simulate  — hybrid buffer traffic simulator
  schedule.co_design              — the joint search (the paper's technique)
  costmodel.HardwareModel / evaluate — speedup + energy model
  policy.CelloPlan                — lowering onto kernels + remat policies
  lowering.layer_graph            — per-arch analysis graphs
"""
from .graph import OpGraph, OpNode, TensorKind, TensorSpec
from .reuse import ReuseAnalysis, TensorReuse, analyze
from .buffer import BufferConfig, TrafficReport, simulate, sequential_groups
from .costmodel import HardwareModel, Metrics, V5E, evaluate
from .schedule import (CoDesignResult, EvaluatedSchedule, Schedule,
                       build_groups, choose_pins, co_design)
from .policy import CelloPlan, default_plan, plan_from_codesign
from .lowering import decode_graph, layer_graph

__all__ = [
    "OpGraph", "OpNode", "TensorKind", "TensorSpec",
    "ReuseAnalysis", "TensorReuse", "analyze",
    "BufferConfig", "TrafficReport", "simulate", "sequential_groups",
    "HardwareModel", "Metrics", "V5E", "evaluate",
    "CoDesignResult", "EvaluatedSchedule", "Schedule",
    "build_groups", "choose_pins", "co_design",
    "CelloPlan", "default_plan", "plan_from_codesign",
    "decode_graph", "layer_graph",
]
