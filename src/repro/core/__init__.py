"""CELLO core: schedule × hybrid implicit/explicit buffer co-design.

Public API (prefer the staged ``repro.api.Session`` front-end):
  graph.OpGraph / OpGraph.build   — tensor-op DAG IR + value-flow builder
  reuse.analyze                   — reuse distance/frequency analysis
  buffer.BufferConfig / simulate  — hybrid buffer traffic simulator
  search.run_codesign             — the joint search as a pass pipeline
  search.SearchStrategy           — pluggable candidate-order strategies
  costmodel.HardwareModel / evaluate — speedup + energy model
  policy.CelloPlan / lower_codesign — lowering onto kernels + remat policies
  lowering.layer_graph            — per-arch analysis graphs
  lowering.select_group_kernels   — fusion group → execution-kernel shapes

The 0.2-era deprecation shims (``co_design``, ``plan_from_codesign``,
``candidate_orders``) were removed in 0.4 — see docs/api_migration.md for
the name-by-name mapping onto the staged API.
"""
from .graph import GraphBuilder, OpGraph, OpNode, TensorKind, TensorSpec
from .reuse import ReuseAnalysis, TensorReuse, analyze
from .buffer import BufferConfig, TrafficReport, simulate, sequential_groups
from .costmodel import HardwareModel, Metrics, V5E, evaluate
from .schedule import (CoDesignResult, EvaluatedSchedule, Schedule,
                       build_groups, choose_pins)
from .search import (DEFAULT_SPLITS, EvaluatePass, FusionPass, OrderPass,
                     PinPass, SearchContext, SearchPoint, SearchStrategy,
                     SplitSweepPass, PASS_REGISTRY, STRATEGY_REGISTRY,
                     default_pipeline, get_strategy, register_pass,
                     register_strategy, run_codesign, run_pipeline)
from .policy import CelloPlan, default_plan, lower_codesign
from .lowering import (CarrySlot, ExecPlan, ExecUnit, GroupKernel,
                       ResidentSpan, RolledLoop, StreamPass, decode_graph,
                       detect_rolled_loop, flatten_units, fuse_units,
                       layer_graph, plan_execution, resident_spans,
                       select_group_kernels)

__all__ = [
    "GraphBuilder", "OpGraph", "OpNode", "TensorKind", "TensorSpec",
    "ReuseAnalysis", "TensorReuse", "analyze",
    "BufferConfig", "TrafficReport", "simulate", "sequential_groups",
    "HardwareModel", "Metrics", "V5E", "evaluate",
    "CoDesignResult", "EvaluatedSchedule", "Schedule",
    "build_groups", "choose_pins",
    "DEFAULT_SPLITS", "EvaluatePass", "FusionPass", "OrderPass", "PinPass",
    "SearchContext", "SearchPoint", "SearchStrategy", "SplitSweepPass",
    "PASS_REGISTRY", "STRATEGY_REGISTRY", "default_pipeline", "get_strategy",
    "register_pass", "register_strategy", "run_codesign", "run_pipeline",
    "CelloPlan", "default_plan", "lower_codesign",
    "CarrySlot", "ExecPlan", "ExecUnit", "GroupKernel", "ResidentSpan",
    "RolledLoop", "StreamPass", "decode_graph", "detect_rolled_loop",
    "flatten_units", "fuse_units", "layer_graph", "plan_execution",
    "resident_spans",
    "select_group_kernels",
]
