"""CELLO core: schedule × hybrid implicit/explicit buffer co-design.

Public API (prefer the staged ``repro.api.Session`` front-end):
  graph.OpGraph / OpGraph.build   — tensor-op DAG IR + value-flow builder
  reuse.analyze                   — reuse distance/frequency analysis
  buffer.BufferConfig / simulate  — hybrid buffer traffic simulator
  search.run_codesign             — the joint search as a pass pipeline
  search.SearchStrategy           — pluggable candidate-order strategies
  costmodel.HardwareModel / evaluate — speedup + energy model
  policy.CelloPlan / lower_codesign — lowering onto kernels + remat policies
  lowering.layer_graph            — per-arch analysis graphs

Deprecated shims (one release): ``co_design`` → ``search.run_codesign``,
``plan_from_codesign`` → ``policy.lower_codesign``.  Both warn and delegate;
results are identical.
"""
from .graph import GraphBuilder, OpGraph, OpNode, TensorKind, TensorSpec
from .reuse import ReuseAnalysis, TensorReuse, analyze
from .buffer import BufferConfig, TrafficReport, simulate, sequential_groups
from .costmodel import HardwareModel, Metrics, V5E, evaluate
from .schedule import (CoDesignResult, EvaluatedSchedule, Schedule,
                       build_groups, choose_pins, co_design)
from .search import (DEFAULT_SPLITS, EvaluatePass, FusionPass, OrderPass,
                     PinPass, SearchContext, SearchPoint, SearchStrategy,
                     SplitSweepPass, PASS_REGISTRY, STRATEGY_REGISTRY,
                     default_pipeline, get_strategy, register_pass,
                     register_strategy, run_codesign, run_pipeline)
from .policy import (CelloPlan, default_plan, lower_codesign,
                     plan_from_codesign)
from .lowering import decode_graph, layer_graph

__all__ = [
    "GraphBuilder", "OpGraph", "OpNode", "TensorKind", "TensorSpec",
    "ReuseAnalysis", "TensorReuse", "analyze",
    "BufferConfig", "TrafficReport", "simulate", "sequential_groups",
    "HardwareModel", "Metrics", "V5E", "evaluate",
    "CoDesignResult", "EvaluatedSchedule", "Schedule",
    "build_groups", "choose_pins", "co_design",
    "DEFAULT_SPLITS", "EvaluatePass", "FusionPass", "OrderPass", "PinPass",
    "SearchContext", "SearchPoint", "SearchStrategy", "SplitSweepPass",
    "PASS_REGISTRY", "STRATEGY_REGISTRY", "default_pipeline", "get_strategy",
    "register_pass", "register_strategy", "run_codesign", "run_pipeline",
    "CelloPlan", "default_plan", "lower_codesign", "plan_from_codesign",
    "decode_graph", "layer_graph",
]
