"""Speedup + energy cost model (the paper's two evaluation metrics).

Time is a per-group roofline: ``max(flops/peak, hbm_bytes/bw)`` summed over
the schedule (groups overlap compute with their own HBM streaming, but not
with other groups — conservative).  Energy is a linear model over FLOPs,
HBM bytes and on-chip bytes.

Default constants target a TPU v5e-class chip (same constants the roofline
analysis in EXPERIMENTS.md uses, so the two layers of the repo agree):
197 TFLOP/s bf16, 819 GB/s HBM.  Energy-per-byte/-flop constants are
representative 7nm-class figures and are explicitly parameters of the model,
not measurements.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from .buffer import TrafficReport
from .graph import OpGraph

GiB = 1 << 30


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 MXU
    hbm_bw: float = 819e9               # bytes/s
    vmem_bytes: int = 128 * (1 << 20)
    ici_bw: float = 50e9                # bytes/s per link
    # energy model (J per unit)
    e_flop: float = 0.3e-12             # per FLOP (bf16 MAC ≈ 0.6 pJ / 2)
    e_hbm_byte: float = 40e-12          # HBM access
    e_vmem_byte: float = 1.2e-12        # on-chip SRAM access
    e_ici_byte: float = 10e-12          # inter-chip link

    def time_group(self, flops: float, hbm_bytes: float) -> float:
        return max(flops / self.peak_flops, hbm_bytes / self.hbm_bw)


V5E = HardwareModel()


@dataclasses.dataclass
class Metrics:
    time_s: float
    energy_j: float
    hbm_bytes: int
    onchip_bytes: int
    flops: int
    ai: float                               # achieved arithmetic intensity

    def speedup_over(self, base: "Metrics") -> float:
        return base.time_s / self.time_s if self.time_s > 0 else float("inf")

    def energy_ratio_over(self, base: "Metrics") -> float:
        return base.energy_j / self.energy_j if self.energy_j > 0 else float("inf")


def evaluate(graph: OpGraph,
             groups: Sequence[Sequence[str]],
             report: TrafficReport,
             hw: HardwareModel = V5E,
             ici_bytes: int = 0) -> Metrics:
    """Score a (schedule, traffic) point.

    HBM traffic is apportioned to groups proportionally to the bytes each
    group's tensors moved (the simulator charges per-tensor; per-group
    attribution uses the group's op byte footprint as weights).
    """
    flops = graph.total_flops + report.recompute_flops
    total_hbm = report.hbm_total
    # group weights by footprint
    weights = []
    for g in groups:
        w = 0
        for oname in g:
            op = graph.ops[oname]
            for t in list(op.inputs) + [op.output]:
                w += graph.tensors[t].bytes
        weights.append(w)
    wsum = sum(weights) or 1
    time = 0.0
    for g, w in zip(groups, weights):
        g_flops = sum(graph.ops[o].flops for o in g)
        g_hbm = total_hbm * (w / wsum)
        time += hw.time_group(g_flops, g_hbm)
    time += ici_bytes / hw.ici_bw if ici_bytes else 0.0
    energy = (flops * hw.e_flop
              + total_hbm * hw.e_hbm_byte
              + report.onchip * hw.e_vmem_byte
              + ici_bytes * hw.e_ici_byte)
    return Metrics(time_s=time, energy_j=energy, hbm_bytes=total_hbm,
                   onchip_bytes=report.onchip, flops=flops,
                   ai=flops / max(1, total_hbm))
