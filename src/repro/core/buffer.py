"""Hybrid implicit/explicit buffer model.

The on-chip buffer (VMEM-class SRAM) is partitioned:

* **explicit region** (``explicit_frac`` of capacity) — a software-managed
  scratchpad.  The schedule *pins* tensors here with a planned lifetime
  ``[def_step, last_use_step]``; within that lifetime every access hits.
  Residency is deterministic: the co-design search (``core.schedule``)
  guarantees the peak of live pinned bytes never exceeds the region.

* **implicit region** (the rest) — a cache: LRU over fixed-size chunks with
  write-allocate / write-back semantics.  It captures reuse the schedule did
  not plan (data-dependent gathers, cross-group leftovers).  CELLO adds two
  scheduler→cache *hints* that a pure hardware cache lacks:

    - ``bypass`` for streams larger than the region (no thrash), and
    - ``last-use invalidation``: when the schedule knows a tensor is dead,
      its dirty chunks are dropped without writeback.

Fusion groups execute with their internal intermediates held in the explicit
region's working tile — those tensors never touch HBM or the implicit region
at all (this is what a Pallas kernel's BlockSpec residency gives us on TPU).

The simulator replays a grouped schedule and reports HBM / on-chip traffic;
``core.costmodel`` turns that into speedup and energy.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .graph import OpGraph, TensorKind

MiB = 1 << 20


@dataclasses.dataclass
class BufferConfig:
    capacity_bytes: int = 128 * MiB
    explicit_frac: float = 0.5
    chunk_bytes: int = 256 * 1024
    # CELLO hints (off ⇒ plain LRU cache, the "implicit-only" baseline)
    last_use_invalidate: bool = True
    bypass_streams: bool = True

    @property
    def explicit_bytes(self) -> int:
        return int(self.capacity_bytes * self.explicit_frac)

    @property
    def implicit_bytes(self) -> int:
        return self.capacity_bytes - self.explicit_bytes


@dataclasses.dataclass
class TrafficReport:
    hbm_read: int = 0
    hbm_write: int = 0
    onchip: int = 0                  # explicit-region (VMEM) bytes moved
    implicit_hits: int = 0
    implicit_misses: int = 0
    recompute_flops: int = 0
    per_tensor: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def hbm_total(self) -> int:
        return self.hbm_read + self.hbm_write

    def charge(self, tname: str, nbytes: int) -> None:
        self.per_tensor[tname] = self.per_tensor.get(tname, 0) + nbytes


class _ImplicitLRU:
    """Chunk-granular LRU with write-back and CELLO hints."""

    def __init__(self, capacity_bytes: int, chunk_bytes: int, report: TrafficReport):
        self.cap = capacity_bytes
        self.chunk = chunk_bytes
        self.rep = report
        self.used = 0
        # (tensor, chunk_idx) -> [size, dirty]
        self.lines: "OrderedDict[Tuple[str, int], List]" = OrderedDict()

    def _chunks(self, nbytes: int, tname: str) -> List[Tuple[int, int]]:
        # Cap chunk count per tensor at 256 to bound simulator cost.
        csz = max(self.chunk, -(-nbytes // 256))
        out, off, i = [], 0, 0
        while off < nbytes:
            sz = min(csz, nbytes - off)
            out.append((i, sz))
            off += sz
            i += 1
        return out

    def _evict_one(self) -> None:
        (key, (size, dirty)) = self.lines.popitem(last=False)
        self.used -= size
        if dirty:
            self.rep.hbm_write += size
            self.rep.charge(key[0], size)

    def access(self, tname: str, nbytes: int, write: bool) -> None:
        if nbytes == 0:
            return
        if nbytes > self.cap:
            # stream bypass: would thrash the whole region
            if write:
                self.rep.hbm_write += nbytes
            else:
                self.rep.hbm_read += nbytes
            self.rep.charge(tname, nbytes)
            self.rep.implicit_misses += 1
            return
        for idx, size in self._chunks(nbytes, tname):
            key = (tname, idx)
            if key in self.lines:
                line = self.lines[key]
                line[1] = line[1] or write
                self.lines.move_to_end(key)
                self.rep.implicit_hits += 1
                continue
            self.rep.implicit_misses += 1
            if not write:
                self.rep.hbm_read += size
                self.rep.charge(tname, size)
            # write-allocate without fetch; read-allocate after fetch
            while self.used + size > self.cap and self.lines:
                self._evict_one()
            self.lines[key] = [size, bool(write)]
            self.used += size

    def invalidate(self, tname: str) -> None:
        dead = [k for k in self.lines if k[0] == tname]
        for k in dead:
            size, _dirty = self.lines.pop(k)
            self.used -= size   # dropped without writeback: data is dead

    def flush(self) -> None:
        while self.lines:
            self._evict_one()


def simulate(graph: OpGraph,
             groups: Sequence[Sequence[str]],
             config: BufferConfig,
             pins: Optional[Dict[str, Tuple[int, int]]] = None,
             last_use: Optional[Dict[str, int]] = None) -> TrafficReport:
    """Replay a grouped schedule through the hybrid buffer.

    Args:
      graph: the op DAG.
      groups: schedule as a list of fusion groups (each a list of op names,
        singletons for unfused ops), in execution order.
      config: buffer partition.
      pins: tensor -> (first_group_idx, last_group_idx) explicit-region
        residency plan.  Validated against the explicit region's capacity.
      last_use: tensor -> last group index that reads it (enables the
        last-use-invalidation hint when ``config.last_use_invalidate``).

    When ``pins`` is a :class:`~repro.core.schedule.PinSet` carrying
    ``partial`` residency records (overbooked sparse operands), only the
    resident prefix occupies the explicit region: it fills once and hits
    on-chip afterwards, while the streamed tail is charged as direct HBM
    traffic on **every** pass that reads the tensor — which is exactly
    what lets the cost model (EvaluatePass) reject overbooking whenever
    the per-pass tail traffic dominates the prefix's captured reuse.
    """
    partial = dict(getattr(pins, "partial", None) or {})
    pins = dict(pins or {})

    def resident_bytes(t: str) -> int:
        pp = partial.get(t)
        return pp.resident_bytes if pp is not None \
            else graph.tensors[t].bytes

    rep = TrafficReport()
    lru = _ImplicitLRU(config.implicit_bytes, config.chunk_bytes, rep)

    # --- validate the pin plan against explicit capacity over time --------
    n_steps = len(groups)
    if pins:
        timeline = [0] * (n_steps + 1)
        for t, (a, b) in pins.items():
            timeline[a] += resident_bytes(t)
            timeline[min(b, n_steps - 1) + 1] -= resident_bytes(t)
        live, peak = 0, 0
        for d in timeline:
            live += d
            peak = max(peak, live)
        if peak > config.explicit_bytes:
            raise ValueError(
                f"pin plan peak {peak} B exceeds explicit region "
                f"{config.explicit_bytes} B")

    filled: Set[str] = set()

    if last_use is None:
        last_use = {}
        for gi, g in enumerate(groups):
            for oname in g:
                for t in graph.ops[oname].inputs:
                    last_use[t] = gi

    consumers_outside: Dict[str, bool] = {}
    for t in graph.tensors.values():
        consumers_outside[t.name] = True   # refined per group below

    for gi, g in enumerate(groups):
        gset = set(g)
        produced = {graph.ops[o].output for o in g}
        read_ext: List[str] = []
        internal: List[str] = []
        for oname in g:
            op = graph.ops[oname]
            for t in op.inputs:
                if t not in produced:
                    read_ext.append(t)
        for t in sorted(produced):
            cons = graph.consumers(t)
            kind = graph.tensors[t].kind
            if (cons and all(c.name in gset for c in cons)
                    and kind != TensorKind.OUTPUT):
                internal.append(t)

        # external reads
        for t in dict.fromkeys(read_ext):
            nbytes = graph.tensors[t].bytes
            pin = pins.get(t)
            if pin and pin[0] <= gi <= pin[1]:
                res = resident_bytes(t)
                tail = nbytes - res
                if t in filled:
                    rep.onchip += res             # explicit hit (prefix)
                else:
                    rep.hbm_read += res           # first fill (prefix)
                    rep.charge(t, res)
                    filled.add(t)
                if tail > 0:
                    # overbooked spill tail: streamed straight from HBM on
                    # every pass (never cached — it would thrash the LRU)
                    rep.hbm_read += tail
                    rep.charge(t, tail)
            else:
                lru.access(t, nbytes, write=False)
            if config.last_use_invalidate and last_use.get(t) == gi:
                lru.invalidate(t)

        # internal intermediates: live only inside the fused group (VMEM)
        for t in internal:
            rep.onchip += 2 * graph.tensors[t].bytes     # produce + consume

        # externally visible products
        for t in sorted(produced):
            if t in internal:
                continue
            spec = graph.tensors[t]
            pin = pins.get(t)
            if spec.kind == TensorKind.OUTPUT:
                rep.hbm_write += spec.bytes               # must land in HBM
                rep.charge(t, spec.bytes)
                if pin and pin[0] <= gi <= pin[1]:
                    filled.add(t)                          # also kept on-chip
            elif pin and pin[0] <= gi <= pin[1]:
                rep.onchip += spec.bytes                   # pinned: no HBM
                filled.add(t)
            else:
                lru.access(t, spec.bytes, write=True)

        # pins whose lifetime ended free their space implicitly (plan-level)
        for t, (a, b) in list(pins.items()):
            if b == gi and t in filled:
                filled.discard(t)

    if not config.last_use_invalidate:
        lru.flush()        # baseline cache writes dirty data back eventually
    # else: CELLO dropped dead data at last use; whatever survives in the
    # implicit region is still live-by-plan and need not move now.
    return rep


def sequential_groups(graph: OpGraph, order: Optional[Sequence[str]] = None
                      ) -> List[List[str]]:
    """Op-by-op schedule (no fusion): the sequential baselines."""
    order = list(order) if order is not None else graph.topo_order()
    return [[o] for o in order]
