"""CELLO co-design search: schedule (order × fusion × tiles) × buffer split.

The search jointly picks:

1. a topological **order** of the op DAG,
2. a **fusion grouping** — maximal producer→consumer chains whose internal
   intermediates stream through the explicit region tile-by-tile (on TPU a
   fusion group lowers to one Pallas kernel; the tile working-set check below
   is the BlockSpec feasibility check),
3. an explicit **pin set** — tensors held in the explicit region across their
   whole lifetime, chosen greedily by traffic-saved-per-pinned-byte, and
4. the **buffer split** — the fraction of on-chip capacity given to the
   explicit region, swept over ninths; the remainder is the implicit LRU.

Scoring is the hybrid-buffer simulation (`core.buffer`) fed to the
speedup/energy model (`core.costmodel`).  Three baselines are produced for
the paper-style comparison: implicit-only (plain cache, op-by-op),
explicit-only (scratchpad pinning, no cache), fused-only (fusion but all
capacity explicit).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from .buffer import BufferConfig, TrafficReport
from .costmodel import Metrics
from .graph import OpGraph, TensorKind
from .reuse import ReuseAnalysis

_MIN_TILE_ROWS = 8          # TPU sublane granularity


@dataclasses.dataclass(frozen=True)
class PartialPin:
    """Resident row-prefix of one member tensor of an overbooked sparse
    operand: rows ``[0, rows)`` of the operand stay in the explicit
    region, the remaining ``total_rows - rows`` stream per pass."""
    rows: int                # resident (indptr-aligned) row prefix
    total_rows: int
    entries: int             # nnz entries inside the resident prefix
    total_entries: int
    resident_bytes: int      # this member's resident prefix bytes
    total_bytes: int         # this member's full bytes

    @property
    def frac(self) -> float:
        return self.rows / max(1, self.total_rows)


class PinSet(dict):
    """Pin spans (``{tensor: (first_group, last_group)}``) plus optional
    per-tensor partial-residency info for overbooked sparse operands.

    Behaves exactly like the plain dict it always was — every consumer
    that only cares about spans keeps working; partial-aware layers read
    ``getattr(pins, "partial", {})``."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.partial: Dict[str, PartialPin] = {}


@dataclasses.dataclass
class Schedule:
    order: List[str]
    groups: List[List[str]]
    pins: Dict[str, Tuple[int, int]]
    config: BufferConfig

    @property
    def fused_op_count(self) -> int:
        return sum(len(g) for g in self.groups if len(g) > 1)


@dataclasses.dataclass
class EvaluatedSchedule:
    schedule: Schedule
    report: TrafficReport
    metrics: Metrics


@dataclasses.dataclass
class CoDesignResult:
    best: EvaluatedSchedule
    baselines: Dict[str, EvaluatedSchedule]
    split_sweep: Dict[float, Metrics]
    #: the overbook fraction the search ran with (0.0 = all-or-nothing)
    overbook: float = 0.0

    def speedup(self, baseline: str = "seq-implicit") -> float:
        return self.best.metrics.speedup_over(self.baselines[baseline].metrics)

    def energy_ratio(self, baseline: str = "seq-implicit") -> float:
        return self.baselines[baseline].metrics.energy_j / self.best.metrics.energy_j


# --------------------------------------------------------------------------
# fusion legality
# --------------------------------------------------------------------------

def _group_tile_working_set(graph: OpGraph, group: Sequence[str]) -> Tuple[int, int]:
    """(resident_bytes, per_row_bytes) for streaming the group tile-by-tile.

    Weights read inside the group must stay resident for every tile; internal
    and boundary activations stream along their leading axis.
    """
    gset = set(group)
    produced = {graph.ops[o].output for o in group}
    weights = set()
    streamed = set()
    full_resident = set()
    for oname in group:
        op = graph.ops[oname]
        if op.spec == "spmv":
            # the CSR kernel holds every operand whole across its row
            # tiles: the indptr/indices/data triple (rows are ragged) and
            # the gathered x (column access is data-dependent)
            full_resident.update(op.inputs)
            streamed.add(op.output)
            continue
        for t in op.inputs:
            if graph.tensors[t].kind == TensorKind.WEIGHT:
                weights.add(t)
            else:
                streamed.add(t)
        streamed.add(op.output)
    weights -= full_resident
    streamed -= full_resident
    # Weights are double-buffered tiles streamed along their largest axis
    # (128 wide — one MXU tile column/row), not fully resident.
    resident = sum(graph.tensors[t].bytes for t in full_resident)
    for t in weights:
        spec = graph.tensors[t]
        big = max(spec.shape) if spec.shape else 1
        tile = spec.bytes // max(1, big) * min(big, 128)
        resident += 2 * min(spec.bytes, tile)
    per_row = 0
    for t in streamed:
        spec = graph.tensors[t]
        # finest streamable granularity: tile along every axis except the
        # last (lane) one — this is what a Pallas BlockSpec grid gives us.
        if spec.shape:
            import math as _m
            rows = max(1, _m.prod(spec.shape[:-1]))
        else:
            rows = 1
        per_row += spec.bytes // rows
    return resident, per_row


def fusable(graph: OpGraph, group: Sequence[str], nxt: str,
            explicit_bytes: int) -> bool:
    """Can ``nxt`` join ``group`` as one explicit-region fusion group?"""
    op = graph.ops[nxt]
    if op.spec == "scan" or op.irregular:
        return False
    if any(graph.ops[o].spec == "scan" or graph.ops[o].irregular for o in group):
        return False
    produced = {graph.ops[o].output for o in group}
    if not any(t in produced for t in op.inputs):
        return False                      # must consume something from group
    # A fusion group is ONE tile-streaming pass: a tiled op cannot consume a
    # global reduction (rank-0 dot / norm result) produced in the same pass
    # — that value only exists after the pass completes.  Scalar→scalar
    # epilogues (beta = rs'/rs) are fine.  This is what stops an unrolled
    # Krylov solver from "fusing away" its cross-iteration reuse: each
    # reduction ends the kernel, so the operator must be re-read — unless
    # the co-designer pins it in the explicit region.
    if graph.tensors[op.output].shape != ():
        scalars = {graph.ops[o].output for o in group
                   if graph.tensors[graph.ops[o].output].shape == ()}
        if any(t in scalars for t in op.inputs):
            return False
    resident, per_row = _group_tile_working_set(graph, list(group) + [nxt])
    return resident + _MIN_TILE_ROWS * per_row <= explicit_bytes


def build_groups(graph: OpGraph, order: Sequence[str],
                 explicit_bytes: int) -> List[List[str]]:
    """Greedy maximal fusion chains along the order."""
    groups: List[List[str]] = []
    cur: List[str] = []
    for oname in order:
        if cur and fusable(graph, cur, oname, explicit_bytes):
            cur.append(oname)
        else:
            if cur:
                groups.append(cur)
            cur = [oname]
    if cur:
        groups.append(cur)
    return groups


# --------------------------------------------------------------------------
# pin selection
# --------------------------------------------------------------------------

def _group_index(groups: Sequence[Sequence[str]]) -> Dict[str, int]:
    gi = {}
    for i, g in enumerate(groups):
        for o in g:
            gi[o] = i
    return gi


def sparse_operand_groups(graph: OpGraph) -> List[Tuple[str, ...]]:
    """CSR leaf triples read together by an spmv op.

    Each triple (``A.indptr``, ``A.indices``, ``A.data``) is one *pin
    unit*: the CSR kernel streams all three together, so a partial pin
    saves nothing, and pin-or-not is exactly the density-aware question
    "does the operand's nnz footprint fit the explicit region?".
    """
    groups: List[Tuple[str, ...]] = []
    seen = set()
    for op in graph.ops.values():
        if op.spec != "spmv":
            continue
        members = tuple(op.inputs[:3])
        if members not in seen:
            seen.add(members)
            groups.append(members)
    return groups


def _operand_cum_entries(graph: OpGraph, grp: Sequence[str]) -> List[int]:
    """Cumulative nnz per row prefix of a CSR triple: ``cum[r]`` = stored
    entries in rows ``[0, r)``.  Exact when the frontend recorded the
    pattern metadata on the sub-leaves; uniform apportionment otherwise.
    """
    by_role = {graph.tensors[t].meta_get("role"): graph.tensors[t]
               for t in grp}
    ip = by_role.get("indptr", graph.tensors[grp[0]])
    ix = by_role.get("indices", graph.tensors[grp[1]])
    n = int(ip.shape[0]) - 1
    total = int(ix.shape[0])
    pattern = ip.meta_get("pattern")
    if pattern is not None:
        try:
            from ..frontends.sparse import row_counts
            kw = {k: ip.meta_get(k) for k in ("density", "bandwidth")
                  if ip.meta_get(k) is not None}
            counts = row_counts(pattern, n, **kw)
            cum = [0]
            for c in counts:
                cum.append(cum[-1] + int(c))
            if cum[-1] == total:
                return cum
        except (ImportError, ValueError):
            pass
    # no (usable) pattern metadata: apportion entries uniformly over rows
    return [total * r // n for r in range(n + 1)]


def _prefix_plan(graph: OpGraph, grp: Sequence[str], explicit_bytes: int,
                 fits) -> "Dict[str, PartialPin] | None":
    """Largest indptr-aligned row prefix of the triple that fits both the
    capacity and the pin timeline (``fits(nbytes)``), as per-member
    :class:`PartialPin` records — or None when not even one row fits.

    The full ``indptr`` stays resident (tail tiles need row offsets too,
    and it is O(n) small); ``indices``/``data`` keep their first
    ``cum[r]`` entries resident and stream the tail per pass.
    """
    roles = ("indptr", "indices", "data")
    by_role = dict(zip(roles, (graph.tensors[t] for t in grp)))
    for t in grp:                       # metadata roles win over position
        spec = graph.tensors[t]
        if spec.meta_get("role") in roles:
            by_role[spec.meta_get("role")] = spec
    ip, ix, dv = by_role["indptr"], by_role["indices"], by_role["data"]
    cum = _operand_cum_entries(graph, grp)
    n = len(cum) - 1
    per_entry = ix.dtype_bytes + dv.dtype_bytes

    def prefix_bytes(r: int) -> int:
        return ip.bytes + cum[r] * per_entry

    # prefix_bytes is monotone in r and fits() monotone in nbytes, so the
    # largest feasible prefix binary-searches
    lo, hi, best = 1, n - 1, 0
    while lo <= hi:
        mid = (lo + hi) // 2
        b = prefix_bytes(mid)
        if b <= explicit_bytes and fits(b):
            best, lo = mid, mid + 1
        else:
            hi = mid - 1
    if best < 1 or cum[best] < 1:
        return None
    r, e = best, cum[best]
    return {
        ip.name: PartialPin(r, n, e, cum[n], ip.bytes, ip.bytes),
        ix.name: PartialPin(r, n, e, cum[n], e * ix.dtype_bytes, ix.bytes),
        dv.name: PartialPin(r, n, e, cum[n], e * dv.dtype_bytes, dv.bytes),
    }


def choose_pins(graph: OpGraph, groups: Sequence[Sequence[str]],
                analysis: ReuseAnalysis, explicit_bytes: int,
                overbook: float = 0.0) -> Dict[str, Tuple[int, int]]:
    """Greedy pinning under a liveness-aware capacity timeline.

    Two candidate orderings are tried and the statically-better pin set is
    kept: traffic-saved-per-pinned-*byte* (density — best when many small
    tensors compete) and *absolute* traffic saved (best when one large
    operator dominates — an HPC solver's ``(n×n)`` matrix at near-capacity
    size is starved by density greedy, because any small vector committed
    first blocks the exact fit).  Ties keep the density set.

    Sparse operands pin *density-aware*: the CSR sub-leaf triple of one
    operand (:func:`sparse_operand_groups`) is a pin unit whose combined
    **nnz footprint** is what must fit — so a sparse ``A`` pins whenever
    its stored bytes fit capacity, even when its dense ``n²`` silhouette
    never would.

    With ``overbook > 0`` the unit is no longer all-or-nothing: a triple
    whose footprint exceeds the explicit region by at most that fraction
    (``total <= explicit_bytes * (1 + overbook)``) pins the largest
    indptr-aligned **row prefix** that truly fits, and the spill tail
    streams per pass (recorded in the returned :class:`PinSet`'s
    ``partial`` map).  ``overbook=0`` reproduces the all-or-nothing
    behavior bit-for-bit.
    """
    gi = _group_index(groups)
    member_of: Dict[str, Tuple[str, ...]] = {}
    for grp in sparse_operand_groups(graph):
        for t in grp:
            member_of[t] = grp
    internal = set()
    for g in groups:
        gset = set(g)
        for oname in g:
            t = graph.ops[oname].output
            cons = graph.consumers(t)
            if (cons and all(c.name in gset for c in cons)
                    and graph.tensors[t].kind != TensorKind.OUTPUT):
                internal.add(t)

    n = len(groups)

    def greedy(candidates) -> Tuple[Dict[str, Tuple[int, int]], int]:
        timeline = [0] * (n + 1)

        def fits(a: int, b: int, nbytes: int) -> bool:
            running = 0
            for i in range(n + 1):
                running += timeline[i]
                if a <= i <= b and running + nbytes > explicit_bytes:
                    return False
            return True

        def span(cand) -> Tuple[int, int]:
            first = (0 if cand.def_step is None
                     else gi[analysis.order[cand.def_step]])
            last = (gi[analysis.order[cand.uses[-1]]] if cand.uses
                    else first)
            return first, last

        def commit(name: str, first: int, last: int, nbytes: int) -> None:
            timeline[first] += nbytes
            timeline[min(last, n - 1) + 1] -= nbytes
            pins[name] = (first, last)

        pins: PinSet = PinSet()
        saved = 0
        decided: Dict[Tuple[str, ...], bool] = {}
        for cand in candidates:
            if cand.pin_value() <= 0 or cand.name in internal:
                continue
            grp = member_of.get(cand.name)
            if grp is not None:
                # density-aware: the operand's combined nnz footprint must
                # fit over the union of member spans (all-or-nothing at
                # overbook=0; a row prefix inside the overbook window)
                if grp in decided:
                    continue
                members = [analysis.tensors[m] for m in grp]
                total = sum(graph.tensors[m.name].bytes for m in members)
                spans = [span(m) for m in members]
                gf = min(a for a, _ in spans)
                gl = max(b for _, b in spans)
                ok = total <= explicit_bytes and fits(gf, gl, total)
                if ok:
                    decided[grp] = True
                    for m, (a, b) in zip(members, spans):
                        commit(m.name, a, b, graph.tensors[m.name].bytes)
                        saved += m.traffic_if_missed()
                    continue
                window = explicit_bytes + int(explicit_bytes * overbook)
                plan = (_prefix_plan(graph, grp, explicit_bytes,
                                     lambda nb: fits(gf, gl, nb))
                        if overbook > 0 and total <= window else None)
                decided[grp] = plan is not None
                if plan is not None:
                    for m, (a, b) in zip(members, spans):
                        pp = plan[m.name]
                        commit(m.name, a, b, pp.resident_bytes)
                        pins.partial[m.name] = pp
                        # the resident prefix captures that fraction of
                        # the operand's would-be-missed traffic
                        saved += int(m.traffic_if_missed()
                                     * pp.resident_bytes / pp.total_bytes)
                continue
            spec = graph.tensors[cand.name]
            if spec.bytes > explicit_bytes:
                continue
            first, last = span(cand)
            if fits(first, last, spec.bytes):
                commit(cand.name, first, last, spec.bytes)
                saved += cand.traffic_if_missed()
        return pins, saved

    by_density = analysis.ranked_pin_candidates()
    by_absolute = sorted(by_density,
                         key=lambda t: (-t.traffic_if_missed(), t.bytes,
                                        t.name))
    dense_pins, dense_saved = greedy(by_density)
    if by_absolute == by_density:
        return dense_pins
    abs_pins, abs_saved = greedy(by_absolute)
    return abs_pins if abs_saved > dense_saved else dense_pins


# The 0.2-era shims (``co_design``, ``candidate_orders``) were removed in
# 0.4 after their promised one-release deprecation window: use
# ``repro.api.Session`` / ``repro.core.search.run_codesign`` and
# ``core.search.get_strategy(...).orders()`` — see docs/api_migration.md.
