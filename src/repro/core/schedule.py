"""CELLO co-design search: schedule (order × fusion × tiles) × buffer split.

The search jointly picks:

1. a topological **order** of the op DAG,
2. a **fusion grouping** — maximal producer→consumer chains whose internal
   intermediates stream through the explicit region tile-by-tile (on TPU a
   fusion group lowers to one Pallas kernel; the tile working-set check below
   is the BlockSpec feasibility check),
3. an explicit **pin set** — tensors held in the explicit region across their
   whole lifetime, chosen greedily by traffic-saved-per-pinned-byte, and
4. the **buffer split** — the fraction of on-chip capacity given to the
   explicit region, swept over ninths; the remainder is the implicit LRU.

Scoring is the hybrid-buffer simulation (`core.buffer`) fed to the
speedup/energy model (`core.costmodel`).  Three baselines are produced for
the paper-style comparison: implicit-only (plain cache, op-by-op),
explicit-only (scratchpad pinning, no cache), fused-only (fusion but all
capacity explicit).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from .buffer import BufferConfig, TrafficReport
from .costmodel import Metrics
from .graph import OpGraph, TensorKind
from .reuse import ReuseAnalysis

_MIN_TILE_ROWS = 8          # TPU sublane granularity


@dataclasses.dataclass
class Schedule:
    order: List[str]
    groups: List[List[str]]
    pins: Dict[str, Tuple[int, int]]
    config: BufferConfig

    @property
    def fused_op_count(self) -> int:
        return sum(len(g) for g in self.groups if len(g) > 1)


@dataclasses.dataclass
class EvaluatedSchedule:
    schedule: Schedule
    report: TrafficReport
    metrics: Metrics


@dataclasses.dataclass
class CoDesignResult:
    best: EvaluatedSchedule
    baselines: Dict[str, EvaluatedSchedule]
    split_sweep: Dict[float, Metrics]

    def speedup(self, baseline: str = "seq-implicit") -> float:
        return self.best.metrics.speedup_over(self.baselines[baseline].metrics)

    def energy_ratio(self, baseline: str = "seq-implicit") -> float:
        return self.baselines[baseline].metrics.energy_j / self.best.metrics.energy_j


# --------------------------------------------------------------------------
# fusion legality
# --------------------------------------------------------------------------

def _group_tile_working_set(graph: OpGraph, group: Sequence[str]) -> Tuple[int, int]:
    """(resident_bytes, per_row_bytes) for streaming the group tile-by-tile.

    Weights read inside the group must stay resident for every tile; internal
    and boundary activations stream along their leading axis.
    """
    gset = set(group)
    produced = {graph.ops[o].output for o in group}
    weights = set()
    streamed = set()
    full_resident = set()
    for oname in group:
        op = graph.ops[oname]
        if op.spec == "spmv":
            # the CSR kernel holds every operand whole across its row
            # tiles: the indptr/indices/data triple (rows are ragged) and
            # the gathered x (column access is data-dependent)
            full_resident.update(op.inputs)
            streamed.add(op.output)
            continue
        for t in op.inputs:
            if graph.tensors[t].kind == TensorKind.WEIGHT:
                weights.add(t)
            else:
                streamed.add(t)
        streamed.add(op.output)
    weights -= full_resident
    streamed -= full_resident
    # Weights are double-buffered tiles streamed along their largest axis
    # (128 wide — one MXU tile column/row), not fully resident.
    resident = sum(graph.tensors[t].bytes for t in full_resident)
    for t in weights:
        spec = graph.tensors[t]
        big = max(spec.shape) if spec.shape else 1
        tile = spec.bytes // max(1, big) * min(big, 128)
        resident += 2 * min(spec.bytes, tile)
    per_row = 0
    for t in streamed:
        spec = graph.tensors[t]
        # finest streamable granularity: tile along every axis except the
        # last (lane) one — this is what a Pallas BlockSpec grid gives us.
        if spec.shape:
            import math as _m
            rows = max(1, _m.prod(spec.shape[:-1]))
        else:
            rows = 1
        per_row += spec.bytes // rows
    return resident, per_row


def fusable(graph: OpGraph, group: Sequence[str], nxt: str,
            explicit_bytes: int) -> bool:
    """Can ``nxt`` join ``group`` as one explicit-region fusion group?"""
    op = graph.ops[nxt]
    if op.spec == "scan" or op.irregular:
        return False
    if any(graph.ops[o].spec == "scan" or graph.ops[o].irregular for o in group):
        return False
    produced = {graph.ops[o].output for o in group}
    if not any(t in produced for t in op.inputs):
        return False                      # must consume something from group
    # A fusion group is ONE tile-streaming pass: a tiled op cannot consume a
    # global reduction (rank-0 dot / norm result) produced in the same pass
    # — that value only exists after the pass completes.  Scalar→scalar
    # epilogues (beta = rs'/rs) are fine.  This is what stops an unrolled
    # Krylov solver from "fusing away" its cross-iteration reuse: each
    # reduction ends the kernel, so the operator must be re-read — unless
    # the co-designer pins it in the explicit region.
    if graph.tensors[op.output].shape != ():
        scalars = {graph.ops[o].output for o in group
                   if graph.tensors[graph.ops[o].output].shape == ()}
        if any(t in scalars for t in op.inputs):
            return False
    resident, per_row = _group_tile_working_set(graph, list(group) + [nxt])
    return resident + _MIN_TILE_ROWS * per_row <= explicit_bytes


def build_groups(graph: OpGraph, order: Sequence[str],
                 explicit_bytes: int) -> List[List[str]]:
    """Greedy maximal fusion chains along the order."""
    groups: List[List[str]] = []
    cur: List[str] = []
    for oname in order:
        if cur and fusable(graph, cur, oname, explicit_bytes):
            cur.append(oname)
        else:
            if cur:
                groups.append(cur)
            cur = [oname]
    if cur:
        groups.append(cur)
    return groups


# --------------------------------------------------------------------------
# pin selection
# --------------------------------------------------------------------------

def _group_index(groups: Sequence[Sequence[str]]) -> Dict[str, int]:
    gi = {}
    for i, g in enumerate(groups):
        for o in g:
            gi[o] = i
    return gi


def sparse_operand_groups(graph: OpGraph) -> List[Tuple[str, ...]]:
    """CSR leaf triples read together by an spmv op.

    Each triple (``A.indptr``, ``A.indices``, ``A.data``) is one *pin
    unit*: the CSR kernel streams all three together, so a partial pin
    saves nothing, and pin-or-not is exactly the density-aware question
    "does the operand's nnz footprint fit the explicit region?".
    """
    groups: List[Tuple[str, ...]] = []
    seen = set()
    for op in graph.ops.values():
        if op.spec != "spmv":
            continue
        members = tuple(op.inputs[:3])
        if members not in seen:
            seen.add(members)
            groups.append(members)
    return groups


def choose_pins(graph: OpGraph, groups: Sequence[Sequence[str]],
                analysis: ReuseAnalysis, explicit_bytes: int
                ) -> Dict[str, Tuple[int, int]]:
    """Greedy pinning under a liveness-aware capacity timeline.

    Two candidate orderings are tried and the statically-better pin set is
    kept: traffic-saved-per-pinned-*byte* (density — best when many small
    tensors compete) and *absolute* traffic saved (best when one large
    operator dominates — an HPC solver's ``(n×n)`` matrix at near-capacity
    size is starved by density greedy, because any small vector committed
    first blocks the exact fit).  Ties keep the density set.

    Sparse operands pin *density-aware*: the CSR sub-leaf triple of one
    operand (:func:`sparse_operand_groups`) is an all-or-nothing unit
    whose combined **nnz footprint** is what must fit — so a sparse ``A``
    pins whenever its stored bytes fit capacity, even when its dense
    ``n²`` silhouette never would, and never pins partially.
    """
    gi = _group_index(groups)
    member_of: Dict[str, Tuple[str, ...]] = {}
    for grp in sparse_operand_groups(graph):
        for t in grp:
            member_of[t] = grp
    internal = set()
    for g in groups:
        gset = set(g)
        for oname in g:
            t = graph.ops[oname].output
            cons = graph.consumers(t)
            if (cons and all(c.name in gset for c in cons)
                    and graph.tensors[t].kind != TensorKind.OUTPUT):
                internal.add(t)

    n = len(groups)

    def greedy(candidates) -> Tuple[Dict[str, Tuple[int, int]], int]:
        timeline = [0] * (n + 1)

        def fits(a: int, b: int, nbytes: int) -> bool:
            running = 0
            for i in range(n + 1):
                running += timeline[i]
                if a <= i <= b and running + nbytes > explicit_bytes:
                    return False
            return True

        def span(cand) -> Tuple[int, int]:
            first = (0 if cand.def_step is None
                     else gi[analysis.order[cand.def_step]])
            last = (gi[analysis.order[cand.uses[-1]]] if cand.uses
                    else first)
            return first, last

        def commit(name: str, first: int, last: int, nbytes: int) -> None:
            timeline[first] += nbytes
            timeline[min(last, n - 1) + 1] -= nbytes
            pins[name] = (first, last)

        pins: Dict[str, Tuple[int, int]] = {}
        saved = 0
        decided: Dict[Tuple[str, ...], bool] = {}
        for cand in candidates:
            if cand.pin_value() <= 0 or cand.name in internal:
                continue
            grp = member_of.get(cand.name)
            if grp is not None:
                # density-aware, all-or-nothing: the operand's combined
                # nnz footprint must fit over the union of member spans
                if grp in decided:
                    continue
                members = [analysis.tensors[m] for m in grp]
                total = sum(graph.tensors[m.name].bytes for m in members)
                spans = [span(m) for m in members]
                gf = min(a for a, _ in spans)
                gl = max(b for _, b in spans)
                ok = total <= explicit_bytes and fits(gf, gl, total)
                decided[grp] = ok
                if ok:
                    for m, (a, b) in zip(members, spans):
                        commit(m.name, a, b, graph.tensors[m.name].bytes)
                        saved += m.traffic_if_missed()
                continue
            spec = graph.tensors[cand.name]
            if spec.bytes > explicit_bytes:
                continue
            first, last = span(cand)
            if fits(first, last, spec.bytes):
                commit(cand.name, first, last, spec.bytes)
                saved += cand.traffic_if_missed()
        return pins, saved

    by_density = analysis.ranked_pin_candidates()
    by_absolute = sorted(by_density,
                         key=lambda t: (-t.traffic_if_missed(), t.bytes,
                                        t.name))
    dense_pins, dense_saved = greedy(by_density)
    if by_absolute == by_density:
        return dense_pins
    abs_pins, abs_saved = greedy(by_absolute)
    return abs_pins if abs_saved > dense_saved else dense_pins


# The 0.2-era shims (``co_design``, ``candidate_orders``) were removed in
# 0.4 after their promised one-release deprecation window: use
# ``repro.api.Session`` / ``repro.core.search.run_codesign`` and
# ``core.search.get_strategy(...).orders()`` — see docs/api_migration.md.
