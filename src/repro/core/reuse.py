"""Reuse-distance / reuse-frequency analysis over a schedule order.

For every tensor, given a schedule (a sequence of op executions), compute:

* ``uses``            — ordered op indices where the tensor is read,
* ``def_step``        — op index where the tensor is produced (None for
                        graph inputs/weights: they are live from step 0),
* ``reuse_distances`` — for each consecutive (use_i, use_{i+1}) pair, the
                        volume (bytes) of *other* tensors touched in between.
                        This is the classic stack-distance proxy that
                        predicts whether an implicit (cache-like) region of
                        capacity C would hit: distance < C ⇒ likely hit.
* ``frequency``       — total number of reads.

The co-design search uses these to decide *explicit pinning* (small distance
variance, high frequency, regular access ⇒ pin) versus *implicit* residency
(irregular / data-dependent reuse ⇒ leave to the LRU region), and to order
pin candidates by traffic-saved-per-pinned-byte.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from .graph import OpGraph, TensorKind


@dataclasses.dataclass
class TensorReuse:
    name: str
    bytes: int
    def_step: Optional[int]
    uses: List[int]
    reuse_distances: List[int]          # bytes of intervening traffic
    irregular: bool                      # touched by a data-dependent op

    @property
    def frequency(self) -> int:
        return len(self.uses)

    @property
    def max_distance(self) -> int:
        return max(self.reuse_distances, default=0)

    @property
    def lifetime(self) -> Optional[range]:
        """[def, last_use] as schedule-step range; None if never used."""
        if not self.uses:
            return None
        start = self.def_step if self.def_step is not None else 0
        return range(start, self.uses[-1] + 1)

    def traffic_if_missed(self) -> int:
        """HBM bytes if every reuse misses (re-read per use)."""
        return self.bytes * max(0, self.frequency - 1)

    def pin_value(self) -> float:
        """Traffic saved per pinned byte (greedy pin ordering key)."""
        if self.bytes == 0 or self.irregular:
            return 0.0
        return self.traffic_if_missed() / self.bytes


@dataclasses.dataclass
class ReuseAnalysis:
    order: List[str]
    tensors: Dict[str, TensorReuse]

    def ranked_pin_candidates(self) -> List[TensorReuse]:
        """Pinnable tensors, best value first (ties: smaller first)."""
        cands = [t for t in self.tensors.values()
                 if t.frequency >= 1 and not t.irregular and t.bytes > 0]
        return sorted(cands, key=lambda t: (-t.pin_value(), t.bytes, t.name))


def analyze(graph: OpGraph, order: Optional[Sequence[str]] = None) -> ReuseAnalysis:
    order = list(order) if order is not None else graph.topo_order()
    if set(order) != set(graph.ops):
        raise ValueError("order must be a permutation of graph ops")

    # Which tensors are read by a data-dependent op (irregular reuse)?
    irregular = set()
    for op in graph.ops.values():
        if op.irregular:
            irregular.update(op.inputs)
            irregular.add(op.output)

    def_step: Dict[str, Optional[int]] = {
        t.name: (None if t.kind in (TensorKind.INPUT, TensorKind.WEIGHT) else -1)
        for t in graph.tensors.values()}
    uses: Dict[str, List[int]] = {t: [] for t in graph.tensors}
    # bytes touched at each step (for distance computation)
    step_bytes: List[int] = []
    touched_at: List[List[str]] = []

    for step, oname in enumerate(order):
        op = graph.ops[oname]
        names = list(op.inputs) + [op.output]
        touched_at.append(names)
        step_bytes.append(sum(graph.tensors[n].bytes for n in set(names)))
        for t in op.inputs:
            uses[t].append(step)
        if def_step.get(op.output) == -1:
            def_step[op.output] = step

    prefix = [0]
    for b in step_bytes:
        prefix.append(prefix[-1] + b)

    out: Dict[str, TensorReuse] = {}
    for tname, ts in graph.tensors.items():
        u = uses[tname]
        dists: List[int] = []
        # distance from def to first use counts too (must survive that long)
        anchor = def_step[tname]
        points = ([] if anchor in (None, -1) else [anchor]) + u
        for a, b in zip(points, points[1:]):
            # bytes touched strictly between the two accesses
            dists.append(max(0, prefix[b] - prefix[a + 1]))
        out[tname] = TensorReuse(
            name=tname, bytes=ts.bytes, def_step=def_step[tname],
            uses=u, reuse_distances=dists, irregular=tname in irregular)
    return ReuseAnalysis(order=order, tensors=out)
