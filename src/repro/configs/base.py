"""ArchConfig: one dataclass drives the model zoo, the CELLO analyser,
the dry-run ``input_specs`` and the smoke tests.

Every assigned architecture registers an exact config (from the assignment
table) plus a ``reduced()`` variant used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

_REGISTRY: Dict[str, "ArchConfig"] = {}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    mode: str                    # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 ⇒ d_model // n_heads
    activation: str = "swiglu"   # swiglu | geglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # attention structure
    window: Optional[int] = None          # sliding-window size (None = full)
    encoder_only: bool = False            # bidirectional, no decode
    cross_attn_every: int = 0             # vlm: cross-attn layer period
    vision_seq: int = 0                   # vlm: #patch embeddings
    # hybrid (recurrentgemma): layer pattern period; indices with attention
    hybrid_period: int = 0                # e.g. 3 ⇒ [rglru, rglru, attn]
    hybrid_attn_index: int = 2
    # ssm (rwkv6)
    attention_free: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding/LM-head shard
        over any TP axis ≤ 256 (Megatron-style vocab padding).  Labels are
        always < vocab, so padding columns only ever receive gradient
        pressure toward -inf — harmless."""
        return -(-self.vocab // 256) * 256

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded state?"""
        return self.attention_free or self.hybrid_period > 0 or self.window is not None

    def layer_kinds(self) -> List[str]:
        """Per-layer block kind: 'attn' | 'rglru' | 'rwkv' | 'xattn'."""
        kinds = []
        for i in range(self.n_layers):
            if self.attention_free:
                kinds.append("rwkv")
            elif self.hybrid_period:
                kinds.append("attn" if i % self.hybrid_period == self.hybrid_attn_index
                             else "rglru")
            elif self.cross_attn_every and (i % self.cross_attn_every
                                            == self.cross_attn_every - 1):
                kinds.append("xattn")
            else:
                kinds.append("attn")
        return kinds

    def supported_shapes(self) -> List[str]:
        out = ["train_4k", "prefill_32k"]
        if not self.encoder_only:
            out.append("decode_32k")
            if self.subquadratic:
                out.append("long_500k")
        return out

    # parameter counts -------------------------------------------------
    def params_per_layer(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        attn = q + kv + o
        gated = self.activation in ("swiglu", "geglu")
        ff_in = (2 if gated else 1) * self.d_model * self.d_ff
        ff_out = self.d_ff * self.d_model
        if self.is_moe:
            ffn = self.n_experts * (ff_in + ff_out) + d * self.n_experts
        else:
            ffn = ff_in + ff_out
        norms = 2 * d
        kinds = self.layer_kinds()
        # non-attention blocks replace attn params
        rglru = 3 * d * d // 1 if any(k == "rglru" for k in kinds) else 0
        per_kind = {
            "attn": attn + ffn + norms,
            "xattn": attn + ffn + norms + kv,     # extra cross K/V proj
            "rglru": (2 * d * d + 2 * d * d) + ffn + norms,  # in/out proj + gates
            "rwkv": (4 * d * d + d * d) + ffn + norms,       # r,k,v,o,g proj
        }
        total = sum(per_kind[k] for k in kinds)
        return total // self.n_layers if self.n_layers else 0

    def total_params(self) -> int:
        kinds = self.layer_kinds()
        d, hd = self.d_model, self.resolved_head_dim
        gated = self.activation in ("swiglu", "geglu")
        ff_in = (2 if gated else 1) * self.d_model * self.d_ff
        ff_out = self.d_ff * self.d_model
        ffn = (self.n_experts * (ff_in + ff_out) + d * self.n_experts
               if self.is_moe else ff_in + ff_out)
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        per = {
            "attn": attn + ffn + 2 * d,
            "xattn": attn + ffn + 2 * d + 2 * d * self.n_kv_heads * hd,
            "rglru": 4 * d * d + ffn + 2 * d,
            "rwkv": 5 * d * d + ffn + 2 * d,
        }
        body = sum(per[k] for k in kinds)
        embed = self.vocab * d
        head = self.vocab * d          # untied LM head
        return body + embed + head

    def active_params(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6·N_active·D)."""
        if not self.is_moe:
            return self.total_params()
        d = self.d_model
        gated = self.activation in ("swiglu", "geglu")
        ff_in = (2 if gated else 1) * self.d_model * self.d_ff
        ff_out = self.d_ff * self.d_model
        dense_ffn = self.top_k * (ff_in + ff_out) + d * self.n_experts
        full_ffn = self.n_experts * (ff_in + ff_out) + d * self.n_experts
        return self.total_params() - self.n_layers * (full_ffn - dense_ffn)

    # reduced config for CPU smoke tests --------------------------------
    def reduced(self) -> "ArchConfig":
        return dataclasses.replace(
            self,
            n_layers=max(2, min(3, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 1,
            head_dim=16,
            d_ff=96 if not self.is_moe else 32,
            vocab=128,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            window=min(self.window, 32) if self.window else None,
            vision_seq=16 if self.vision_seq else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            hybrid_period=self.hybrid_period,
            name=self.name + "-smoke",
        )


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> List[str]:
    return sorted(_REGISTRY)
