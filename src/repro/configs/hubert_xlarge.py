"""hubert-xlarge — encoder-only audio transformer (wav2vec2-style backbone).
The conv feature-extractor frontend is a STUB: input_specs() provides
precomputed frame embeddings. No decode step exists (encoder-only).
[arXiv:2106.07447; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,               # CTC output vocabulary
    activation="gelu",
    encoder_only=True,
    source="arXiv:2106.07447",
))
