"""Architecture configs: the 10 assigned architectures + registry."""
from .base import (ArchConfig, ShapeSpec, SHAPES, get_config, list_archs,
                   register)
from . import (recurrentgemma_2b, llama_3_2_vision_11b, rwkv6_7b,
               moonshot_v1_16b_a3b, granite_moe_1b_a400m, gemma_7b,
               h2o_danube_1_8b, minitron_8b, granite_3_8b, hubert_xlarge)

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "get_config", "list_archs",
           "register"]
