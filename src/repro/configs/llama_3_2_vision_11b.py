"""llama-3.2-vision-11b — cross-attention image layers every 5th layer.
Vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (4 tiles x 1601 patches, projected to d_model).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128_256,
    activation="swiglu",
    cross_attn_every=5,      # 8 cross-attention layers in 40
    vision_seq=6404,         # 4 tiles x 1601 patch embeddings (stubbed)
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
))
