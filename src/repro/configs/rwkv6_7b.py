"""rwkv6-7b (Finch) — attention-free, data-dependent decay time-mix.
[arXiv:2404.05892; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,              # wkv head_size = 64 => 4096/64 heads
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab=65_536,
    activation="relu2",      # RWKV channel-mix: relu(x W_k)^2 W_v
    attention_free=True,
    source="arXiv:2404.05892",
))
