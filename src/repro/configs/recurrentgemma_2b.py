"""recurrentgemma-2b — RG-LRU + local attention hybrid, 1:2 attn:recurrent.
[arXiv:2402.19427; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,            # MQA on the local-attention layers
    head_dim=256,
    d_ff=7680,
    vocab=256_000,
    activation="geglu",
    window=2048,             # local attention window
    hybrid_period=3,         # [rglru, rglru, attn] repeating (1:2)
    hybrid_attn_index=2,
    source="arXiv:2402.19427",
))
