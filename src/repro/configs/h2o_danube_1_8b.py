"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab=32_000,
    activation="swiglu",
    window=4096,             # sliding-window attention
    source="arXiv:2401.16818",
))
