"""minitron-8b — pruned nemotron; squared-ReLU MLP. [arXiv:2407.14679; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=256_000,
    activation="relu2",      # nemotron-family squared ReLU (non-gated)
    source="arXiv:2407.14679",
))
