"""Serving: prefill + decode steps and a batched generation driver."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..core.policy import CelloPlan
from ..models import decode_step, forward, init_cache, set_mesh_context
from . import shardings as shd

PyTree = Any


def make_prefill_fn(cfg: ArchConfig, plan: CelloPlan, *,
                    unroll: bool = False):
    def prefill(params, tokens, frames=None, img=None):
        logits, _ = forward(params, cfg, plan, tokens, frames=frames,
                            img=img, mode="prefill", unroll=unroll)
        return logits
    return prefill


def make_decode_fn(cfg: ArchConfig, plan: CelloPlan, *,
                   unroll: bool = False):
    def serve_step(params, cache, tokens, pos):
        return decode_step(params, cache, cfg, plan, tokens, pos,
                           unroll=unroll)
    return serve_step


def jit_decode_step(cfg: ArchConfig, plan: CelloPlan, mesh: Mesh,
                    batch: int, seq_len: int, *, unroll: bool = False):
    """AOT-ready decode step with cache/params shardings bound."""
    set_mesh_context(mesh)
    _, p_shardings = shd.params_for(cfg, mesh)
    _, c_shardings = shd.cache_for(cfg, mesh, batch, seq_len)
    tok_sh = shd.batch_sharding(mesh, 2, batch)
    logits_sh = NamedSharding(mesh, P(None, None, "model"))
    return jax.jit(
        make_decode_fn(cfg, plan, unroll=unroll),
        in_shardings=(p_shardings, c_shardings, tok_sh,
                      NamedSharding(mesh, P())),
        out_shardings=(logits_sh, c_shardings),
        donate_argnums=(1,),
    )


def greedy_generate(params, cfg: ArchConfig, plan: CelloPlan,
                    prompt: jnp.ndarray, n_new: int,
                    cache_len: Optional[int] = None) -> jnp.ndarray:
    """Batched greedy decoding (CPU-scale driver for examples/tests).

    prompt: (B, P) int32.  Returns (B, P + n_new).
    """
    B, Plen = prompt.shape
    Z = cache_len or (Plen + n_new)
    cache = init_cache(cfg, B, Z)
    step = jax.jit(make_decode_fn(cfg, plan))
    toks = prompt
    # feed the prompt token-by-token (simple driver; a production server
    # would run a batched prefill and hand the cache to decode)
    logits = None
    for t in range(Plen):
        logits, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
    for t in range(n_new):
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        toks = jnp.concatenate([toks, nxt], axis=1)
        if t < n_new - 1:
            logits, cache = step(params, cache, nxt,
                                 jnp.int32(Plen + t))
    return toks


@dataclasses.dataclass
class ServeStats:
    tokens_generated: int
    steps: int
    wall_s: float

    @property
    def tok_per_s(self) -> float:
        return self.tokens_generated / max(self.wall_s, 1e-9)
