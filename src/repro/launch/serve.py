"""Serving: prefill + decode steps and a batched generation driver."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..core.policy import CelloPlan
from ..models import decode_step, forward, init_cache, set_mesh_context
from . import shardings as shd

PyTree = Any


def make_prefill_fn(cfg: ArchConfig, plan: CelloPlan, *,
                    unroll: bool = False):
    def prefill(params, tokens, frames=None, img=None):
        logits, _ = forward(params, cfg, plan, tokens, frames=frames,
                            img=img, mode="prefill", unroll=unroll)
        return logits
    return prefill


def make_decode_fn(cfg: ArchConfig, plan: CelloPlan, *,
                   unroll: bool = False):
    def serve_step(params, cache, tokens, pos):
        return decode_step(params, cache, cfg, plan, tokens, pos,
                           unroll=unroll)
    return serve_step


def jit_decode_step(cfg: ArchConfig, plan: CelloPlan, mesh: Mesh,
                    batch: int, seq_len: int, *, unroll: bool = False):
    """AOT-ready decode step with cache/params shardings bound."""
    set_mesh_context(mesh)
    _, p_shardings = shd.params_for(cfg, mesh)
    _, c_shardings = shd.cache_for(cfg, mesh, batch, seq_len)
    tok_sh = shd.batch_sharding(mesh, 2, batch)
    logits_sh = NamedSharding(mesh, P(None, None, "model"))
    return jax.jit(
        make_decode_fn(cfg, plan, unroll=unroll),
        in_shardings=(p_shardings, c_shardings, tok_sh,
                      NamedSharding(mesh, P())),
        out_shardings=(logits_sh, c_shardings),
        donate_argnums=(1,),
    )


def greedy_generate(params, cfg: ArchConfig, plan: CelloPlan,
                    prompt: jnp.ndarray, n_new: int,
                    cache_len: Optional[int] = None, *,
                    step_fn=None) -> jnp.ndarray:
    """Batched greedy decoding (CPU-scale driver for examples/tests).

    prompt: (B, P) int32.  Returns (B, P + n_new).  ``step_fn`` lets a
    caller supply an already-jitted decode step (stable across calls);
    otherwise one is built and jitted fresh here.
    """
    B, Plen = prompt.shape
    Z = cache_len or (Plen + n_new)
    cache = init_cache(cfg, B, Z)
    step = step_fn if step_fn is not None else \
        jax.jit(make_decode_fn(cfg, plan))
    toks = prompt
    # feed the prompt token-by-token (simple driver; a production server
    # would run a batched prefill and hand the cache to decode)
    logits = None
    for t in range(Plen):
        logits, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
    for t in range(n_new):
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        toks = jnp.concatenate([toks, nxt], axis=1)
        if t < n_new - 1:
            logits, cache = step(params, cache, nxt,
                                 jnp.int32(Plen + t))
    return toks


@dataclasses.dataclass(frozen=True)
class ServeBundle:
    """Serving entry points bound to one (cfg, plan) pair.

    Produced by ``repro.api.CompiledPlan.serve()`` — the Session-era way to
    reach the serving stack; the ``make_*_fn`` helpers above remain for
    callers that already hold a plan.
    """
    cfg: ArchConfig
    plan: CelloPlan
    unroll: bool = False

    # cached: stable function identity, so jax.jit(bundle.decode_fn) hits
    # its trace cache instead of recompiling per access
    @functools.cached_property
    def prefill_fn(self):
        return make_prefill_fn(self.cfg, self.plan, unroll=self.unroll)

    @functools.cached_property
    def decode_fn(self):
        return make_decode_fn(self.cfg, self.plan, unroll=self.unroll)

    def jit_decode(self, mesh: Mesh, batch: int, seq_len: int):
        return jit_decode_step(self.cfg, self.plan, mesh, batch, seq_len,
                               unroll=self.unroll)

    @functools.cached_property
    def _jitted_decode_fn(self):
        return jax.jit(self.decode_fn)

    def generate(self, params, prompt: jnp.ndarray, n_new: int,
                 cache_len: Optional[int] = None) -> jnp.ndarray:
        # drive the bundle's own (unroll-respecting) decode step; the jitted
        # wrapper is cached so repeat generate() calls reuse its trace cache
        return greedy_generate(params, self.cfg, self.plan, prompt, n_new,
                               cache_len=cache_len,
                               step_fn=self._jitted_decode_fn)


def make_serving(cfg: ArchConfig, plan: CelloPlan, *,
                 unroll: bool = False) -> ServeBundle:
    return ServeBundle(cfg=cfg, plan=plan, unroll=unroll)


@dataclasses.dataclass
class ServeStats:
    tokens_generated: int
    steps: int
    wall_s: float

    @property
    def tok_per_s(self) -> float:
        return self.tokens_generated / max(self.wall_s, 1e-9)
