"""Sharding rules: logical axes → NamedShardings, plus per-cell input specs.

Logical axis resolution:
  "model"          → the "model" mesh axis (TP / EP)
  "batch" / "data" → ("pod", "data") when the pod axis exists, else ("data",)
Param/optimizer/cache spec trees come from the model zoo; this module binds
them to a concrete mesh and builds the ShapeDtypeStruct stand-ins the
dry-run lowers against (weak-type-correct, shardable, no allocation).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec
from ..models import (cache_pspecs, init_cache, init_params, param_pspecs)
from ..models.common import COMPUTE_DTYPE

PyTree = Any


def _resolve_axis(mesh: Mesh, axis):
    if axis is None:
        return None
    if axis == "model":
        return "model" if "model" in mesh.axis_names else None
    if axis in ("batch", "data"):
        axes = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
        return axes or None
    return axis


def resolve_tree(mesh: Mesh, logical_tree: PyTree,
                 shapes: Optional[PyTree] = None) -> PyTree:
    """Logical spec tree (tuples) → NamedSharding tree.

    With ``shapes`` (a matching eval_shape tree), axes whose mesh extent
    does not divide the dimension are dropped (left replicated) — e.g.
    recurrentgemma's 10 attention heads cannot shard over model=16."""
    def axis_size(ax) -> int:
        if ax is None:
            return 1
        if isinstance(ax, (tuple, list)):
            n = 1
            for a in ax:
                n *= mesh.shape[a]
            return n
        return mesh.shape[ax]

    def one(t, shape=None):
        axes = [_resolve_axis(mesh, a) for a in t]
        if shape is not None:
            dims = tuple(shape.shape)
            axes += [None] * (len(dims) - len(axes))
            axes = [a if a is not None and d % axis_size(a) == 0 else None
                    for a, d in zip(axes, dims)]
        return NamedSharding(mesh, P(*axes))

    if shapes is None:
        return jax.tree.map(one, logical_tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    flat_specs, treedef = jax.tree.flatten(
        logical_tree, is_leaf=lambda x: isinstance(x, tuple))
    flat_shapes = treedef.flatten_up_to(shapes)
    return jax.tree.unflatten(
        treedef, [one(s, sh) for s, sh in zip(flat_specs, flat_shapes)])


def shaped(tree_shapes: PyTree, tree_shardings: PyTree) -> PyTree:
    """eval_shape output × sharding tree → ShapeDtypeStruct-with-sharding."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_shapes, tree_shardings)


def batch_sharding(mesh: Mesh, ndim: int, batch_dim_size: int
                   ) -> NamedSharding:
    ax = _resolve_axis(mesh, "batch")
    size = 1
    if ax:
        for a in ax:
            size *= mesh.shape[a]
    if ax is None or batch_dim_size % size != 0:
        ax = None                      # batch too small to shard (e.g. B=1)
    return NamedSharding(mesh, P(ax, *([None] * (ndim - 1))))


def params_for(cfg: ArchConfig, mesh: Mesh) -> Tuple[PyTree, PyTree]:
    """(ShapeDtypeStruct params tree, NamedSharding tree) — no allocation."""
    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.random.PRNGKey(0))
    shardings = resolve_tree(mesh, param_pspecs(cfg), shapes)
    return shaped(shapes, shardings), shardings


def cache_for(cfg: ArchConfig, mesh: Mesh, batch: int, seq_len: int
              ) -> Tuple[PyTree, PyTree]:
    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, seq_len))
    tp = mesh.shape.get("model", 1)
    shardings = resolve_tree(
        mesh, cache_pspecs(cfg, batch, seq_len=seq_len, tp=tp), shapes)
    return shaped(shapes, shardings), shardings


def input_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh
                ) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of one cell."""
    B, S = shape.global_batch, shape.seq_len
    tok = lambda s: jax.ShapeDtypeStruct(
        s, jnp.int32, sharding=batch_sharding(mesh, len(s), s[0]))
    out: Dict[str, Any] = {}
    if shape.mode in ("train", "prefill"):
        if cfg.family == "audio":
            out["frames"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), COMPUTE_DTYPE,
                sharding=batch_sharding(mesh, 3, B))
            out["tokens"] = tok((B, S))     # ids still drive the loss target
        else:
            out["tokens"] = tok((B, S))
        if cfg.family == "vlm":
            out["img"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_seq, cfg.d_model), COMPUTE_DTYPE,
                sharding=batch_sharding(mesh, 3, B))
        if shape.mode == "train":
            out["labels"] = tok((B, S))
    else:                                    # decode
        out["tokens"] = tok((B, 1))
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
        cache_sds, cache_shardings = cache_for_split(cfg, mesh, B, S)
        out["cache"] = cache_sds
        out["cache_shardings"] = cache_shardings
    return out


# ---------------------------------------------------------------------------
# split (per-layer-leaf) form for the dry-run
# ---------------------------------------------------------------------------
# XLA's cost analysis charges a slice of a stacked (L, ...) leaf at the full
# stacked size, so the unrolled dry-run would over-report memory traffic by
# ~L×.  The dry-run therefore lowers against a *split* tree: one leaf per
# layer.  Production execution keeps the stacked/scan form.

def _split_tree(shapes_periods, pspecs_periods, n_periods):
    def strip(s):
        return jax.ShapeDtypeStruct(s.shape[1:], s.dtype)
    layers = []
    for i in range(n_periods):
        layers.append(jax.tree.map(strip, shapes_periods))
    def unlift(spec):
        return tuple(spec)[1:]
    specs = jax.tree.map(unlift, pspecs_periods,
                         is_leaf=lambda x: isinstance(x, tuple))
    return layers, [specs] * n_periods


def params_for_split(cfg: ArchConfig, mesh: Mesh,
                     dtype=None) -> Tuple[PyTree, PyTree]:
    from ..models import period_structure
    from ..models.common import PARAM_DTYPE
    dt = dtype if dtype is not None else PARAM_DTYPE
    shapes = jax.eval_shape(lambda k: init_params(k, cfg, dtype=dt),
                            jax.random.PRNGKey(0))
    pspecs = param_pspecs(cfg)
    _, n_periods, _ = period_structure(cfg)
    if n_periods > 0:
        shapes = dict(shapes)
        pspecs = dict(pspecs)
        shapes["periods"], pspecs["periods"] = _split_tree(
            shapes["periods"], pspecs["periods"], n_periods)
    shardings = resolve_tree(mesh, pspecs, shapes)
    return shaped(shapes, shardings), shardings


def cache_for_split(cfg: ArchConfig, mesh: Mesh, batch: int, seq_len: int
                    ) -> Tuple[PyTree, PyTree]:
    from ..models import period_structure
    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, seq_len))
    tp = mesh.shape.get("model", 1)
    pspecs = cache_pspecs(cfg, batch, seq_len=seq_len, tp=tp)
    _, n_periods, _ = period_structure(cfg)
    if n_periods > 0:
        shapes = dict(shapes)
        pspecs = dict(pspecs)
        shapes["periods"], pspecs["periods"] = _split_tree(
            shapes["periods"], pspecs["periods"], n_periods)
    shardings = resolve_tree(mesh, pspecs, shapes)
    return shaped(shapes, shardings), shardings
