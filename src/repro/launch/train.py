"""Training step + loop: microbatch accumulation, CELLO remat policy,
ZeRO-1 sharded optimizer, optional cross-pod gradient compression, and the
fault-tolerant driver used by the examples.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..core.policy import CelloPlan
from ..models import forward, init_params, param_pspecs, set_mesh_context
from ..optim import (AdamWConfig, adamw_init, adamw_update, zero1_pspecs)
from . import shardings as shd

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    accum_steps: int = 1
    remat: bool = True
    unroll: bool = False                 # dry-run sets True (cost analysis)
    zero1: bool = True
    donate: bool = True


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token CE in nats. logits (B,S,Vp) f32; labels (B,S) int32."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -ll.mean()


def make_loss_fn(cfg: ArchConfig, plan: CelloPlan, train_cfg: TrainConfig):
    policy = plan.checkpoint_policy() if train_cfg.remat else None

    def loss_fn(params, batch):
        logits, _ = forward(
            params, cfg, plan, batch["tokens"],
            frames=batch.get("frames"), img=batch.get("img"),
            mode="train", remat_policy=policy, unroll=train_cfg.unroll)
        return cross_entropy(logits, batch["labels"])

    return loss_fn


def make_train_step(cfg: ArchConfig, plan: CelloPlan,
                    opt_cfg: AdamWConfig,
                    train_cfg: TrainConfig = TrainConfig()):
    """Pure train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). Jit/shard it via `jit_train_step`."""
    loss_fn = make_loss_fn(cfg, plan, train_cfg)
    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state, batch):
        if train_cfg.accum_steps > 1:
            a = train_cfg.accum_steps

            def micro(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = grad_fn(params, mb)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, grads_acc, grads)), None

            micro_batch = jax.tree.map(
                lambda x: x.reshape((a, x.shape[0] // a) + x.shape[1:]),
                batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros(()), zeros), micro_batch)
            loss = loss / a
            grads = jax.tree.map(lambda g: g / a, grads)
        else:
            loss, grads = grad_fn(params, batch)
        params, opt_state, info = adamw_update(opt_cfg, grads, opt_state,
                                               params)
        metrics = {"loss": loss, "lr": info["lr"],
                   "grad_norm": info["grad_norm"]}
        return params, opt_state, metrics

    return train_step


def optimizer_shardings(cfg: ArchConfig, mesh: Mesh,
                        zero1: bool = True) -> PyTree:
    """NamedSharding tree for the AdamW state (ZeRO-1 over the data axis)."""
    pshapes = jax.eval_shape(lambda k: init_params(k, cfg),
                             jax.random.PRNGKey(0))
    pspecs = param_pspecs(cfg)
    if zero1:
        data_size = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                data_size *= mesh.shape[a]
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        mspecs = zero1_pspecs(pspecs, pshapes, data_size, data_axes)
    else:
        mspecs = pspecs
    moments = shd.resolve_tree(mesh, mspecs, pshapes)
    return {"m": moments, "v": moments,
            "count": NamedSharding(mesh, P())}


def zero1_shardings(params_sds: PyTree, p_shardings: PyTree, mesh: Mesh,
                    zero1: bool = True) -> PyTree:
    """Moment shardings derived from (possibly split) param shardings."""
    if not zero1:
        moments = p_shardings
    else:
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        data_size = 1
        for a in data_axes:
            data_size *= mesh.shape[a]

        def one(sharding, sds):
            spec = tuple(sharding.spec) + (None,) * (
                len(sds.shape) - len(sharding.spec))
            out = list(spec)
            for i, (ax, dim) in enumerate(zip(spec, sds.shape)):
                if ax is None and dim % data_size == 0 and dim >= data_size:
                    out[i] = data_axes
                    break
            return NamedSharding(mesh, P(*out))

        moments = jax.tree.map(one, p_shardings, params_sds)
    return {"m": moments, "v": moments, "count": NamedSharding(mesh, P())}


def jit_train_step(cfg: ArchConfig, plan: CelloPlan, opt_cfg: AdamWConfig,
                   mesh: Mesh, train_cfg: TrainConfig = TrainConfig(),
                   batch_specs: Optional[Dict] = None,
                   p_shardings: Optional[PyTree] = None,
                   o_shardings: Optional[PyTree] = None):
    """AOT-ready jitted train step with full in/out shardings."""
    set_mesh_context(mesh)
    if p_shardings is None:
        _, p_shardings = shd.params_for(cfg, mesh)
    if o_shardings is None:
        o_shardings = optimizer_shardings(cfg, mesh, train_cfg.zero1)
    if batch_specs is None:
        raise ValueError("batch_specs required (from shardings.input_specs)")
    b_shardings = jax.tree.map(lambda s: s.sharding, batch_specs)
    metric_shardings = {k: NamedSharding(mesh, P())
                        for k in ("loss", "lr", "grad_norm")}
    step = make_train_step(cfg, plan, opt_cfg, train_cfg)
    return jax.jit(
        step,
        in_shardings=(p_shardings, o_shardings, b_shardings),
        out_shardings=(p_shardings, o_shardings, metric_shardings),
        donate_argnums=(0, 1) if train_cfg.donate else (),
    )


# ---------------------------------------------------------------------------
# training loop (single-process driver used by examples/tests)
# ---------------------------------------------------------------------------

def train_loop(cfg: ArchConfig, plan: CelloPlan, opt_cfg: AdamWConfig, *,
               data_iter, n_steps: int, params=None, opt_state=None,
               start_step: int = 0,
               checkpointer=None, checkpoint_every: int = 0,
               straggler=None,
               log_every: int = 10,
               train_cfg: TrainConfig = TrainConfig(donate=False),
               seed: int = 0) -> Dict[str, Any]:
    """CPU-scale loop (no mesh): init → step* → metrics history."""
    set_mesh_context(None)
    if params is None:
        params = init_params(jax.random.PRNGKey(seed), cfg)
    if opt_state is None:
        opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, plan, opt_cfg, train_cfg))
    history = []
    for step in range(start_step, n_steps):
        inputs, labels = next(data_iter)
        batch = {"tokens": jnp.asarray(inputs), "labels": jnp.asarray(labels)}
        if cfg.family == "audio":
            # stub frontend: frame embeddings derived deterministically
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(step), (inputs.shape[0], inputs.shape[1],
                                           cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["img"] = jax.random.normal(
                jax.random.PRNGKey(step), (inputs.shape[0], cfg.vision_seq,
                                           cfg.d_model), jnp.bfloat16)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        if straggler is not None:
            straggler.record(dt)
        history.append({"step": step, "loss": loss, "time_s": dt})
        if log_every and (step % log_every == 0 or step == n_steps - 1):
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  {dt*1e3:.0f} ms")
        if checkpointer is not None and checkpoint_every and \
                (step + 1) % checkpoint_every == 0:
            checkpointer.save(step + 1,
                              {"params": params, "opt": opt_state},
                              extra={"step": step + 1})
    if checkpointer is not None:
        checkpointer.wait()
    return {"params": params, "opt_state": opt_state, "history": history}
