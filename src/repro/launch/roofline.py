"""Roofline accounting from compiled dry-run artifacts.

Hardware constants (TPU v5e-class, also used by core.costmodel):
  197 TFLOP/s bf16 per chip · 819 GB/s HBM · ~50 GB/s/link ICI.

Conventions:
  * XLA's post-SPMD module is per-device, so cost_analysis flops/bytes are
    per-device; the roofline terms below therefore divide by per-chip peaks
    directly (equivalent to global/(chips × peak) for balanced shards).
  * Collective traffic is parsed from the optimized HLO text: for each
    all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute we take the *result* shape (per-device) and the
    replica-group size N, and charge ring-algorithm bytes per chip:
        all-gather       (N-1)/N × result
        all-reduce       2 (N-1)/N × result
        reduce-scatter   (N-1) × result        (operand = N × result)
        all-to-all       (N-1)/N × result
        collective-permute   1 × result
  * The collective term assumes one ICI link per direction (conservative;
    a 2D torus can stripe across 2–3 links — noted in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

from ..configs.base import ArchConfig, ShapeSpec

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_ARRAY_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,\s]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _array_bytes(text: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(1, len(ids))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # replica_groups=[G,N]<=[...]  → G groups of N
        return max(1, int(m.group(2)))
    return 2      # conservative default


_RING_FACTOR = {
    "all-gather": lambda n: (n - 1) / n,
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "reduce-scatter": lambda n: float(n - 1),
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


def parse_collectives(hlo_text: str) -> Dict[str, float]:
    """Per-chip collective bytes by op type (+ 'total')."""
    out: Dict[str, float] = {k: 0.0 for k in _RING_FACTOR}
    count: Dict[str, int] = {k: 0 for k in _RING_FACTOR}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _array_bytes(m.group("result"))
        n = _group_size(line)
        out[op] += nbytes * _RING_FACTOR[op](n)
        count[op] += 1
    out["total"] = sum(out[k] for k in _RING_FACTOR)
    for k, c in count.items():
        out[f"n_{k}"] = c
    return out


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill/decode), N active."""
    n = cfg.active_params() if cfg.is_moe else cfg.total_params()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token per seq


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    n_chips: int
    model_flops_total: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_chip * self.n_chips
        return self.model_flops_total / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the dominant term
        were the wall clock: compute_s / bound_s."""
        return self.compute_s / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> Dict:
        return {**dataclasses.asdict(self), "dominant": self.dominant,
                "bound_s": self.bound_s,
                "useful_flops_ratio": self.useful_flops_ratio,
                "roofline_fraction": self.roofline_fraction}


def roofline(flops_per_chip: float, bytes_per_chip: float,
             coll_bytes_per_chip: float, n_chips: int,
             model_flops_total: float) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops_per_chip / PEAK_FLOPS,
        memory_s=bytes_per_chip / HBM_BW,
        collective_s=coll_bytes_per_chip / ICI_BW,
        flops_per_chip=flops_per_chip,
        bytes_per_chip=bytes_per_chip,
        coll_bytes_per_chip=coll_bytes_per_chip,
        n_chips=n_chips,
        model_flops_total=model_flops_total)
