import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.

Per cell this driver:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. builds ShapeDtypeStruct stand-ins for params / optimizer / cache /
     batch (no allocation anywhere),
  3. lowers + compiles the step function —
       train_4k      → full train_step (fwd + bwd + AdamW/ZeRO-1),
       prefill_32k   → forward,
       decode_*      → decode_step (one token against the cache),
  4. prints compiled.memory_analysis() (fits-per-device proof) and
     cost_analysis() (FLOPs/bytes for §Roofline),
  5. parses collective bytes from the optimized HLO and writes the JSON
     consumed by benchmarks/bench_roofline.py and EXPERIMENTS.md.

Layers are *unrolled* here (``unroll=True``) so XLA's cost analysis counts
every layer — a `while` body is costed once, not ×trip-count.  Production
execution uses the scan form; both lower through identical per-layer HLO.

Hillclimbing knobs (used by §Perf): ``--attention naive`` reproduces the
paper's sequential/implicit-only baseline; ``--no-remat``, ``--no-zero1``,
``--accum`` toggle the corresponding optimisations.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..api import Session
from ..configs import SHAPES, get_config, list_archs
from ..configs.base import ArchConfig, ShapeSpec
from ..core.policy import CelloPlan
from ..models import decode_step, forward, set_mesh_context
from ..optim import AdamWConfig, adamw_init
from . import shardings as shd
from .mesh import make_production_mesh
from .roofline import model_flops, parse_collectives, roofline
from .train import TrainConfig, jit_train_step


def _plan_for(cfg: ArchConfig, shape: ShapeSpec, attention: str,
              ) -> CelloPlan:
    plan = Session(cfg).default_plan(seq=shape.seq_len).plan
    if attention == "naive":
        plan = dataclasses.replace(plan, use_flash_attention=False,
                                   use_fused_mlp=False,
                                   notes="seq-implicit baseline")
    return plan


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *,
               attention: str = "flash", remat: bool = True,
               zero1: bool = True, accum: int = 1,
               kv_block: Optional[int] = None,
               cache_dus: bool = False,
               moe_cf: Optional[float] = None,
               serve_dtype: str = "f32") -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name not in cfg.supported_shapes():
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": ("encoder-only: no decode step"
                           if cfg.encoder_only else
                           "full-attention arch: 500k decode skipped "
                           "(see DESIGN.md)")}
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_mesh_context(mesh)
    n_chips = mesh.devices.size
    plan = _plan_for(cfg, shape, attention)
    if kv_block:
        plan = dataclasses.replace(plan, kv_block=kv_block)
    if cache_dus:
        plan = dataclasses.replace(plan, cache_select_update=False)
    if moe_cf is not None:
        plan = dataclasses.replace(plan, moe_capacity_factor=moe_cf)
    specs = shd.input_specs(cfg, shape, mesh)
    dt = (jnp.bfloat16 if serve_dtype == "bf16" and shape.mode == "decode"
          else None)
    params_sds, p_shardings = shd.params_for_split(cfg, mesh, dtype=dt)

    t0 = time.time()
    if shape.mode == "train":
        opt_cfg = AdamWConfig()
        train_cfg = TrainConfig(remat=remat, unroll=True, zero1=zero1,
                                accum_steps=accum, donate=True)
        from .train import zero1_shardings
        o_shardings = zero1_shardings(params_sds, p_shardings, mesh, zero1)
        opt_sds = shd.shaped(
            jax.eval_shape(lambda p: adamw_init(p), params_sds), o_shardings)
        batch = {k: v for k, v in specs.items()}
        fn = jit_train_step(cfg, plan, opt_cfg, mesh, train_cfg,
                            batch_specs=batch, p_shardings=p_shardings,
                            o_shardings=o_shardings)
        lowered = fn.lower(params_sds, opt_sds, batch)
    elif shape.mode == "prefill":
        def prefill(params, batch):
            logits, _ = forward(params, cfg, plan, batch["tokens"],
                                frames=batch.get("frames"),
                                img=batch.get("img"),
                                mode="prefill", unroll=True)
            return logits
        batch = dict(specs)
        b_shardings = jax.tree.map(lambda s: s.sharding, batch)
        out_sh = NamedSharding(mesh, P(None, None, "model"))
        lowered = jax.jit(prefill, in_shardings=(p_shardings, b_shardings),
                          out_shardings=out_sh).lower(params_sds, batch)
    else:  # decode
        cache_sds = specs["cache"]
        c_shardings = specs["cache_shardings"]

        def serve_step(params, cache, tokens, pos):
            return decode_step(params, cache, cfg, plan, tokens, pos,
                               unroll=True)
        logits_sh = NamedSharding(mesh, P(None, None, "model"))
        lowered = jax.jit(
            serve_step,
            in_shardings=(p_shardings, c_shardings,
                          specs["tokens"].sharding, NamedSharding(mesh, P())),
            out_shardings=(logits_sh, c_shardings),
            donate_argnums=(1,),
        ).lower(params_sds, cache_sds, specs["tokens"], specs["pos"])
    lower_s = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    print(mem)                                   # proves it fits
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    print({k: ca.get(k) for k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    terms = roofline(float(ca.get("flops", 0.0)),
                     float(ca.get("bytes accessed", 0.0)),
                     coll["total"], n_chips, model_flops(cfg, shape))
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "n_chips": n_chips,
        "attention": attention, "remat": remat, "zero1": zero1,
        "cache_dus": cache_dus,
        "accum": accum, "kv_block": plan.kv_block,
        "lower_s": round(lower_s, 2), "compile_s": round(compile_s, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
        },
        "cost": {"flops_per_chip": float(ca.get("flops", 0.0)),
                 "bytes_per_chip": float(ca.get("bytes accessed", 0.0))},
        "collectives": coll,
        "roofline": terms.to_dict(),
        "hlo_bytes": len(hlo),
    }
    return result


def run_cells(args) -> int:
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ({"single": [False], "multi": [True],
               "both": [False, True]})[args.mesh]
    os.makedirs(args.outdir, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tagpart = f"__{args.tag}" if args.tag else ""
                name = (f"{arch}__{shape}__"
                        f"{'multi' if multi else 'single'}{tagpart}.json")
                out_path = os.path.join(args.outdir, name)
                if args.skip_existing and os.path.exists(out_path):
                    print(f"[skip-existing] {name}")
                    continue
                print(f"=== {arch} × {shape} × "
                      f"{'multi' if multi else 'single'} ===", flush=True)
                try:
                    res = lower_cell(arch, shape, multi,
                                     attention=args.attention,
                                     remat=not args.no_remat,
                                     zero1=not args.no_zero1,
                                     accum=args.accum,
                                     kv_block=args.kv_block,
                                     cache_dus=args.cache_dus,
                                     moe_cf=args.moe_cf,
                                     serve_dtype=args.serve_dtype)
                except Exception as e:           # a failure here is a bug
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "error", "error": repr(e)}
                    failures += 1
                if res.get("status") == "ok":
                    r = res["roofline"]
                    print(f"  compute {r['compute_s']*1e3:9.3f} ms | "
                          f"memory {r['memory_s']*1e3:9.3f} ms | "
                          f"collective {r['collective_s']*1e3:9.3f} ms | "
                          f"dominant {r['dominant']}", flush=True)
                with open(out_path, "w") as f:
                    json.dump(res, f, indent=1)
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--tag", default="",
                    help="suffix for §Perf hillclimb variants")
    ap.add_argument("--attention", choices=["flash", "naive"],
                    default="flash")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--kv-block", type=int, default=None)
    ap.add_argument("--cache-dus", action="store_true",
                    help="baseline: dynamic_update_slice cache writes")
    ap.add_argument("--moe-cf", type=float, default=None,
                    help="MoE capacity factor override")
    ap.add_argument("--serve-dtype", choices=["f32", "bf16"], default="f32",
                    help="param dtype for decode cells (serving precision)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    failures = run_cells(args)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
