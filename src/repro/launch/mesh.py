"""Mesh construction (function, not module constant — importing this module
never touches jax device state)."""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Production mesh: 16×16 = 256 chips per pod; 2 pods when multi_pod.

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
    DP runs over ("pod", "data"), TP/EP over "model"."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    devs = jax.devices()[: data * model]
    import numpy as np
    return Mesh(np.array(devs).reshape(data, model), ("data", "model"))


def make_solver_mesh(n_shards: int, *, axis: str = "shards") -> Mesh:
    """1-D mesh for row-block sharded solver plans (``partition_plan``).

    On CPU hosts the device count is 1 unless forced:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (how CI runs
    the distributed suite on one runner)."""
    devs = jax.devices()
    if n_shards > len(devs):
        raise ValueError(
            f"need {n_shards} devices for {n_shards} shards, have "
            f"{len(devs)} (on CPU, force more with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_shards})")
    import numpy as np
    return Mesh(np.array(devs[:n_shards]), (axis,))


def shard_map_compat(fn, mesh: Mesh, in_specs, out_specs):
    """`shard_map` across the jax versions this repo supports: the entry
    point moved from ``jax.experimental.shard_map`` to ``jax.shard_map``,
    and the replication-check kwarg was renamed ``check_rep`` ->
    ``check_vma``.  The check is disabled either way: solver shard bodies
    mix pallas calls and collectives the checker cannot see through."""
    import inspect
    try:
        from jax import shard_map                          # jax >= 0.6
    except ImportError:                                    # pragma: no cover
        from jax.experimental.shard_map import shard_map
    params = inspect.signature(shard_map).parameters
    kw = ({"check_vma": False} if "check_vma" in params
          else {"check_rep": False})
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, **kw)
