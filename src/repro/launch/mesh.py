"""Mesh construction (function, not module constant — importing this module
never touches jax device state)."""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Production mesh: 16×16 = 256 chips per pod; 2 pods when multi_pod.

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
    DP runs over ("pod", "data"), TP/EP over "model"."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    devs = jax.devices()[: data * model]
    import numpy as np
    return Mesh(np.array(devs).reshape(data, model), ("data", "model"))
