"""Sharded checkpointing with elastic restore and an async writer.

Layout: ``<dir>/step_<k>/{meta.json, arrays/<flat-key>.npy}`` plus a
``COMMITTED`` marker written last — a crash mid-write never corrupts the
latest checkpoint (restore only considers committed steps).

Elasticity: arrays are stored in full (gathered) form with their logical
PartitionSpec recorded in meta.json; `load_checkpoint` re-shards onto
*whatever mesh is current*, so a run checkpointed on N chips restores onto
M chips unchanged.  (At true 1000-node scale the gather becomes per-shard
tensorstore writes; the commit protocol and the reshard-on-restore logic —
the parts this repo exercises — stay identical.)

The async writer snapshots device arrays to host, then writes on a worker
thread off the training critical path; `wait()` joins before the next save.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _key_of(path) -> str:
    return "--".join(_SAFE.sub("_", str(p)) for p in path)


def _flatten_with_paths(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_key_of(path)] = np.asarray(jax.device_get(leaf))
    return flat


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    extra: Optional[Dict] = None) -> str:
    """Write a committed checkpoint; returns its path."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
    flat = _flatten_with_paths(tree)
    meta = {"step": step, "extra": extra or {},
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()}}
    for k, v in flat.items():
        np.save(os.path.join(tmp, "arrays", k + ".npy"), v)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "COMMITTED")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, target: PyTree,
                    shardings: Optional[PyTree] = None
                    ) -> Tuple[PyTree, Dict]:
    """Restore into the structure of ``target``; re-shard with ``shardings``
    (same tree structure, leaves NamedSharding or None) for elastic resume."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_leaves = (jax.tree.leaves(
        shardings, is_leaf=lambda x: x is None or hasattr(x, "spec"))
        if shardings is not None else [None] * len(leaves_p))
    out = []
    for (pth, leaf), shd in zip(leaves_p, shard_leaves):
        key = _key_of(pth)
        arr = np.load(os.path.join(path, "arrays", key + ".npy"))
        expect = tuple(np.shape(leaf))
        if tuple(arr.shape) != expect:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"target {expect}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr, dtype=np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), out), meta["extra"]


class AsyncCheckpointer:
    """Snapshot-then-write checkpointing off the critical path."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None
             ) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)          # snapshot on caller thread

        def _write():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:          # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n)
             for n in os.listdir(self.directory)) if m)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
