"""NumPy-like tensor-expression builder that lowers to ``core.OpGraph``.

A :class:`Program` records a DAG of :class:`ExprNode`\\ s; :class:`Expr` is
the user-facing handle with operator overloads, so HPC kernels read like the
math they implement::

    p = Program("cg")
    A = p.operator("A", (n, n))          # WEIGHT: resident, reused
    b = p.input("b", (n,))
    x = p.input("x0", (n,), init="zeros")
    r = b - A @ x
    rs = p.dot(r, r)

``Program.to_graph()`` lowers the DAG through ``OpGraph.build()``:

* leaves become ``INPUT`` / ``WEIGHT`` tensors, marked outputs ``OUTPUT``,
* contractions (``matmul`` / ``dot`` / ``einsum``) lower as einsum ops so
  the strict parser re-derives shapes and FLOPs (2 × MACs),
* everything else lowers as elementwise-family ops with explicit output
  shape and FLOP counts (``axpy`` = 2 FLOP/elem, ``stencil2d`` = 6, …),
* data-dependent ``gather`` is marked *irregular*: the co-designer must
  leave its reuse to the implicit region.

Node names double as both the produced tensor's name and the op's name in
the lowered graph (the two namespaces are disjoint in ``OpGraph``), so pins
and fusion groups in ``plan.explain()`` read as ``A``, ``p1``, ``r2`` …

Precision note: ``dtype_bytes`` (default fp64 — this is HPC) feeds the
traffic/energy *model* only; the ``reference`` interpreter executes in
JAX's default float precision regardless.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import math
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple, Union

from ..core.graph import OpGraph, TensorKind, _parse_einsum

F64 = 8
F32 = 4
I32 = 4

#: expr-op -> OpGraph pseudo-spec for the non-einsum lowerings
_SPEC = {
    "add": "ew", "sub": "ew", "mul": "ew", "div": "ew", "neg": "ew",
    "axpy": "ew", "dot": "reduce", "norm": "reduce",
    "stencil2d": "stencil2d", "gather": "gather", "spmv": "spmv",
}

#: FLOPs per output element for the simple elementwise ops
_EW_FLOPS = {"add": 1, "sub": 1, "mul": 1, "div": 1, "neg": 1, "axpy": 2,
             "stencil2d": 6, "gather": 0}

Shape = Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class ExprNode:
    """One node of the expression DAG (leaf or op)."""
    name: str
    op: str                           # "input" | "operator" | op kind
    inputs: Tuple[str, ...]           # names of the operand nodes
    shape: Shape
    dtype_bytes: int
    flops: int = 0
    irregular: bool = False
    params: Tuple[Tuple[str, Any], ...] = ()   # sorted, hashable extras

    @property
    def is_leaf(self) -> bool:
        return self.op in ("input", "operator")

    def param(self, key: str, default: Any = None) -> Any:
        return dict(self.params).get(key, default)


class Expr:
    """Handle to one node of a :class:`Program` (supports ``+ - * / @``)."""
    __slots__ = ("program", "name")

    def __init__(self, program: "Program", name: str):
        self.program = program
        self.name = name

    @property
    def node(self) -> ExprNode:
        return self.program.nodes[self.name]

    @property
    def shape(self) -> Shape:
        return self.node.shape

    @property
    def dtype_bytes(self) -> int:
        return self.node.dtype_bytes

    # -- operator sugar ---------------------------------------------------
    def __add__(self, other): return self.program.add(self, other)
    def __radd__(self, other): return self.program.add(other, self)
    def __sub__(self, other): return self.program.sub(self, other)
    def __rsub__(self, other): return self.program.sub(other, self)
    def __mul__(self, other): return self.program.mul(self, other)
    def __rmul__(self, other): return self.program.mul(other, self)
    def __truediv__(self, other): return self.program.div(self, other)
    def __rtruediv__(self, other): return self.program.div(other, self)
    def __matmul__(self, other): return self.program.matmul(self, other)
    def __neg__(self): return self.program.neg(self)

    def __repr__(self) -> str:
        n = self.node
        return f"Expr({self.name!r}, {n.op}, shape={n.shape})"


def _as_params(params: Dict[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    return tuple(sorted(params.items()))


class SparseOperand:
    """Handle to a CSR sparse operator: three typed sub-leaves.

    ``Program.sparse_operator`` registers ``name.indptr`` (int32,
    ``(n+1,)``), ``name.indices`` (int32, ``(nnz,)``) and ``name.data``
    (float, ``(nnz,)``) as ordinary operator leaves — the analysis layers
    see three tensors whose combined *nnz footprint* (not the dense ``n²``
    silhouette) competes for buffer capacity.  Consume it with
    ``Program.spmv`` (or ``A @ x``); :meth:`diag_inv` lazily registers a
    fourth derived leaf holding ``1/diag(A)`` for Jacobi-style sweeps.
    """
    __slots__ = ("program", "name", "shape", "nnz", "pattern", "_meta",
                 "indptr", "indices", "data")

    def __init__(self, program: "Program", name: str, shape: Shape,
                 nnz: int, pattern: str, meta: Dict[str, Any],
                 indptr: "Expr", indices: "Expr", data: "Expr"):
        self.program = program
        self.name = name
        self.shape = shape
        self.nnz = nnz
        self.pattern = pattern
        self._meta = dict(meta)
        self.indptr = indptr
        self.indices = indices
        self.data = data

    @property
    def density(self) -> float:
        return self.nnz / (self.shape[0] * self.shape[1])

    @property
    def leaf_names(self) -> Tuple[str, str, str]:
        return (self.indptr.name, self.indices.name, self.data.name)

    def diag_inv(self) -> "Expr":
        """The ``1/diag(A)`` vector as a derived leaf (``name.dinv``)."""
        dname = f"{self.name}.dinv"
        if dname not in self.program.nodes:
            self.program._register(ExprNode(
                dname, "operator", (), (self.shape[0],),
                self.data.dtype_bytes,
                params=_as_params({**self._meta, "role": "dinv"})))
        return Expr(self.program, dname)

    def __matmul__(self, x: "Expr") -> "Expr":
        return self.program.spmv(self, x)

    def __repr__(self) -> str:
        return (f"SparseOperand({self.name!r}, {self.pattern}, "
                f"shape={self.shape}, nnz={self.nnz}, "
                f"density={self.density:.2e})")


class Program:
    """A buildable expression DAG, lowerable to :class:`OpGraph`."""

    def __init__(self, name: str = "program"):
        self.name = name
        self.nodes: Dict[str, ExprNode] = {}
        self._order: List[str] = []       # insertion order = a topo order
        self.outputs: List[str] = []
        self._counts: Dict[str, int] = {}
        self._bodies: List[List[str]] = []   # per-iteration node names
        self._cur_body: Optional[List[str]] = None

    # -- node plumbing ----------------------------------------------------
    def _register(self, node: ExprNode) -> Expr:
        if node.name in self.nodes:
            raise ValueError(f"duplicate node name {node.name!r}")
        self.nodes[node.name] = node
        self._order.append(node.name)
        if self._cur_body is not None:
            self._cur_body.append(node.name)
        return Expr(self, node.name)

    @contextlib.contextmanager
    def iteration(self) -> Iterator[None]:
        """Record the nodes built inside as one solver-iteration body.

        Unrolled solver loops wrap each iteration in this context; the
        recorded bodies (:meth:`iteration_bodies`) let the execution layer
        recognize the repeated per-iteration structure and run it *rolled*
        (one compiled body under ``lax.fori_loop``) instead of dispatching
        every unrolled copy.  Purely metadata: the DAG, its schedule, and
        its numerics are identical with or without the annotation.
        """
        if self._cur_body is not None:
            raise ValueError("iteration() contexts do not nest")
        self._cur_body = []
        try:
            yield
        finally:
            self._bodies.append(self._cur_body)
            self._cur_body = None

    def iteration_bodies(self) -> List[List[str]]:
        """Recorded per-iteration node names (copies; possibly empty)."""
        return [list(b) for b in self._bodies]

    def _autoname(self, op: str) -> str:
        while True:
            i = self._counts.get(op, 0)
            self._counts[op] = i + 1
            name = f"{op}_{i}"
            if name not in self.nodes:
                return name

    def _expr(self, x: Union["Expr", float, int]) -> "Expr":
        """Coerce a Python scalar operand into a rank-0 ``const`` input."""
        if isinstance(x, Expr):
            if x.program is not self:
                raise ValueError("operands belong to different Programs")
            return x
        if isinstance(x, (int, float)):
            return self.input(self._autoname("const"), (),
                              init="const", value=float(x))
        raise TypeError(f"cannot use {type(x).__name__} as an operand")

    # -- leaves -----------------------------------------------------------
    def input(self, name: str, shape: Sequence[int], *,
              dtype_bytes: int = F64, init: str = "randn",
              **init_params: Any) -> Expr:
        """A graph input (activations-in; re-supplied per invocation)."""
        return self._register(ExprNode(
            name, "input", (), tuple(int(s) for s in shape), dtype_bytes,
            params=_as_params({"init": init, **init_params})))

    def operator(self, name: str, shape: Sequence[int], *,
                 dtype_bytes: int = F64, init: str = "randn",
                 **init_params: Any) -> Expr:
        """A resident, read-only operator (lowered as ``WEIGHT``): the
        sparse-matrix / tensor operand reused across solver iterations."""
        return self._register(ExprNode(
            name, "operator", (), tuple(int(s) for s in shape), dtype_bytes,
            params=_as_params({"init": init, **init_params})))

    # alias matching the LLM-side vocabulary
    weight = operator

    def sparse_operator(self, name: str, shape: Sequence[int], *,
                        pattern: str = "laplacian5",
                        density: Optional[float] = None,
                        bandwidth: Optional[int] = None,
                        dtype_bytes: int = F64) -> SparseOperand:
        """A resident CSR sparse operator: three typed sub-leaves.

        ``pattern`` picks the deterministic generator
        (``repro.frontends.sparse``): ``laplacian5`` (SPD 5-point grid
        Laplacian; ``n`` must be a perfect square), ``banded`` (SPD, needs
        ``bandwidth``), ``random`` / ``skewed`` (diagonally dominant,
        need ``density``).  The exact ``nnz`` is computed here, at build
        time, so the sub-leaf shapes — and with them every FLOP/byte
        annotation downstream — are nnz-based, not ``n²``-based.
        """
        from .sparse import pattern_nnz       # numpy-only helper module
        shape = tuple(int(s) for s in shape)
        if len(shape) != 2 or shape[0] != shape[1]:
            raise ValueError(f"sparse_operator needs a square (n, n) "
                             f"shape, got {shape}")
        n = shape[0]
        nnz = pattern_nnz(pattern, n, density=density, bandwidth=bandwidth)
        meta = {"init": "csr", "pattern": pattern, "rows": n, "cols": n,
                "nnz": nnz, "density": density, "bandwidth": bandwidth}
        ip = self._register(ExprNode(
            f"{name}.indptr", "operator", (), (n + 1,), I32,
            params=_as_params({**meta, "role": "indptr"})))
        ix = self._register(ExprNode(
            f"{name}.indices", "operator", (), (nnz,), I32,
            params=_as_params({**meta, "role": "indices"})))
        dv = self._register(ExprNode(
            f"{name}.data", "operator", (), (nnz,), dtype_bytes,
            params=_as_params({**meta, "role": "data"})))
        return SparseOperand(self, name, shape, nnz, pattern, meta,
                             ip, ix, dv)

    def spmv(self, A: SparseOperand, x: "Expr",
             name: Optional[str] = None) -> Expr:
        """CSR sparse matrix–vector product ``A @ x`` → ``(rows,)``.

        FLOPs are nnz-based (``2·nnz``: one multiply-add per stored
        entry); the op reads the three CSR sub-leaves plus ``x``, so the
        reuse analysis sees the operand's true byte footprint.
        """
        if not isinstance(A, SparseOperand):
            raise TypeError(f"spmv needs a SparseOperand (from "
                            f"Program.sparse_operator), got "
                            f"{type(A).__name__}")
        if A.program is not self:
            raise ValueError("operands belong to different Programs")
        x = self._expr(x)
        if x.shape != (A.shape[1],):
            raise ValueError(f"spmv: {A.name} is {A.shape}, x is "
                             f"{x.shape}; need x of shape "
                             f"({A.shape[1]},)")
        return self._register(ExprNode(
            name or self._autoname("spmv"), "spmv",
            (*A.leaf_names, x.name), (A.shape[0],),
            max(A.data.dtype_bytes, x.dtype_bytes), flops=2 * A.nnz,
            params=_as_params({"nnz": A.nnz, "rows": A.shape[0],
                               "cols": A.shape[1]})))

    # -- contractions -----------------------------------------------------
    def einsum(self, spec: str, *operands: "Expr",
               name: Optional[str] = None) -> Expr:
        """General einsum; shapes/FLOPs re-derived by the strict parser."""
        ops = [self._expr(o) for o in operands]
        in_specs, out_spec = _parse_einsum(spec)
        if len(in_specs) != len(ops):
            raise ValueError(f"einsum {spec!r}: {len(in_specs)} operands "
                             f"in spec, got {len(ops)}")
        dim: Dict[str, int] = {}
        for sub, e in zip(in_specs, ops):
            if len(sub) != len(e.shape):
                raise ValueError(f"einsum {spec!r}: operand {e.name} rank "
                                 f"mismatch ({sub!r} vs {e.shape})")
            for ax, size in zip(sub, e.shape):
                if dim.setdefault(ax, size) != size:
                    raise ValueError(f"einsum {spec!r}: axis {ax!r} size "
                                     f"mismatch")
        shape = tuple(dim[a] for a in out_spec)
        flops = 2 * int(math.prod(dim.values()))
        return self._register(ExprNode(
            name or self._autoname("einsum"), "einsum",
            tuple(e.name for e in ops), shape,
            max(e.dtype_bytes for e in ops), flops=flops,
            params=_as_params({"spec": spec})))

    def matmul(self, a: "Expr", b: "Expr",
               name: Optional[str] = None) -> Expr:
        """Matrix/vector product — the skewed ``(n×n)·(n×1)`` workhorse."""
        a, b = self._expr(a), self._expr(b)
        ra, rb = len(a.shape), len(b.shape)
        spec = {(2, 2): "ab,bc->ac", (2, 1): "ab,b->a",
                (1, 2): "a,ab->b", (1, 1): "a,a->"}.get((ra, rb))
        if spec is None:
            raise ValueError(f"matmul supports rank 1/2 operands, got "
                             f"{a.shape} @ {b.shape}")
        node = self.einsum(spec, a, b, name=name or self._autoname("matmul"))
        # rewrite the op tag so the DAG reads as matmuls, not raw einsums
        nd = self.nodes[node.name]
        self.nodes[node.name] = dataclasses.replace(nd, op="matmul")
        return node

    def dot(self, x: "Expr", y: "Expr", name: Optional[str] = None) -> Expr:
        """Inner product of two vectors → rank-0 scalar tensor."""
        x, y = self._expr(x), self._expr(y)
        if len(x.shape) != 1 or x.shape != y.shape:
            raise ValueError(f"dot needs equal-length vectors, got "
                             f"{x.shape} · {y.shape}")
        return self._register(ExprNode(
            name or self._autoname("dot"), "dot", (x.name, y.name), (),
            max(x.dtype_bytes, y.dtype_bytes), flops=2 * x.shape[0]))

    def norm(self, x: "Expr", name: Optional[str] = None) -> Expr:
        """Euclidean norm → rank-0 scalar tensor."""
        x = self._expr(x)
        return self._register(ExprNode(
            name or self._autoname("norm"), "norm", (x.name,), (),
            x.dtype_bytes, flops=2 * max(1, int(math.prod(x.shape))) + 1))

    # -- elementwise family -----------------------------------------------
    def _binary(self, op: str, a, b, name: Optional[str]) -> Expr:
        a, b = self._expr(a), self._expr(b)
        if a.shape == b.shape:
            shape = a.shape
        elif a.shape == ():
            shape = b.shape
        elif b.shape == ():
            shape = a.shape
        else:
            raise ValueError(f"{op}: shapes {a.shape} and {b.shape} do not "
                             "broadcast (equal or scalar only)")
        flops = _EW_FLOPS[op] * max(1, int(math.prod(shape)))
        return self._register(ExprNode(
            name or self._autoname(op), op, (a.name, b.name), shape,
            max(a.dtype_bytes, b.dtype_bytes), flops=flops))

    def add(self, a, b, name: Optional[str] = None) -> Expr:
        return self._binary("add", a, b, name)

    def sub(self, a, b, name: Optional[str] = None) -> Expr:
        return self._binary("sub", a, b, name)

    def mul(self, a, b, name: Optional[str] = None) -> Expr:
        return self._binary("mul", a, b, name)

    def div(self, a, b, name: Optional[str] = None) -> Expr:
        return self._binary("div", a, b, name)

    def neg(self, x, name: Optional[str] = None) -> Expr:
        x = self._expr(x)
        return self._register(ExprNode(
            name or self._autoname("neg"), "neg", (x.name,), x.shape,
            x.dtype_bytes, flops=max(1, int(math.prod(x.shape)))))

    def axpy(self, alpha, x: "Expr", y: "Expr",
             name: Optional[str] = None) -> Expr:
        """``alpha * x + y`` — alpha may be a scalar Expr or a Python float."""
        alpha, x, y = self._expr(alpha), self._expr(x), self._expr(y)
        if alpha.shape != ():
            raise ValueError(f"axpy alpha must be scalar, got {alpha.shape}")
        if x.shape != y.shape:
            raise ValueError(f"axpy: x {x.shape} vs y {y.shape}")
        flops = _EW_FLOPS["axpy"] * max(1, int(math.prod(x.shape)))
        return self._register(ExprNode(
            name or self._autoname("axpy"), "axpy",
            (alpha.name, x.name, y.name), x.shape,
            max(x.dtype_bytes, y.dtype_bytes), flops=flops))

    def scale(self, alpha, x: "Expr", name: Optional[str] = None) -> Expr:
        return self.mul(self._expr(alpha), x, name=name)

    # -- structured / irregular ops ---------------------------------------
    def stencil2d(self, u: "Expr", f: Optional["Expr"] = None, *,
                  h2: float = 1.0, name: Optional[str] = None) -> Expr:
        """One Jacobi 5-point sweep on a 2-D grid (periodic boundaries):
        ``u' = 0.25 * (N + S + E + W + h2 * f)``."""
        u = self._expr(u)
        if len(u.shape) != 2:
            raise ValueError(f"stencil2d needs a 2-D grid, got {u.shape}")
        if f is not None:
            f = self._expr(f)
            if f.shape != u.shape:
                raise ValueError(f"stencil2d: f {f.shape} vs u {u.shape}")
        ins = (u.name,) if f is None else (u.name, f.name)
        flops = _EW_FLOPS["stencil2d"] * int(math.prod(u.shape))
        return self._register(ExprNode(
            name or self._autoname("stencil2d"), "stencil2d", ins, u.shape,
            u.dtype_bytes, flops=flops, params=_as_params({"h2": h2})))

    def gather(self, x: "Expr", idx: "Expr",
               name: Optional[str] = None) -> Expr:
        """Data-dependent row gather ``x[idx]`` — *irregular*: its reuse
        cannot be planned, so the co-designer must leave it implicit."""
        x, idx = self._expr(x), self._expr(idx)
        # an index leaf must draw from the gathered tensor's rows, or the
        # reference oracle would generate out-of-range indices that
        # jnp.take silently clamps
        ind = idx.node
        if ind.is_leaf and ind.param("init") == "indices":
            high = ind.param("high")
            if high is None:
                self.nodes[idx.name] = dataclasses.replace(
                    ind, params=_as_params({**dict(ind.params),
                                            "high": int(x.shape[0])}))
            elif int(high) > x.shape[0]:
                raise ValueError(
                    f"gather: index leaf {idx.name!r} ranges to {high} but "
                    f"{x.name} has {x.shape[0]} rows; pass an explicit "
                    "high= no larger than every gathered tensor")
        shape = tuple(idx.shape) + tuple(x.shape[1:])
        return self._register(ExprNode(
            name or self._autoname("gather"), "gather",
            (x.name, idx.name), shape, x.dtype_bytes,
            flops=0, irregular=True))

    # -- outputs & lowering -------------------------------------------------
    def output(self, *exprs: "Expr") -> None:
        """Mark expressions as graph outputs (written back to HBM)."""
        for e in exprs:
            e = self._expr(e)
            if e.node.is_leaf:
                raise ValueError(f"output {e.name!r} is a leaf; outputs "
                                 "must be produced by an op")
            if e.name not in self.outputs:
                self.outputs.append(e.name)

    def to_graph(self, name: Optional[str] = None) -> OpGraph:
        """Lower the expression DAG to the analysis-level ``OpGraph``."""
        if not self.outputs:
            raise ValueError(f"program {self.name!r} has no outputs; call "
                             "Program.output(...) before lowering")
        out_set = set(self.outputs)
        with OpGraph.build(name or self.name) as b:
            for nname in self._order:
                nd = self.nodes[nname]
                if nd.op == "input":
                    b.input(nname, nd.shape, dtype_bytes=nd.dtype_bytes)
                elif nd.op == "operator":
                    # carry the leaf's params (CSR pattern metadata etc.)
                    # so the pin search can reason about row structure
                    b.weight(nname, nd.shape, dtype_bytes=nd.dtype_bytes,
                             meta=nd.params)
                else:
                    kind = (TensorKind.OUTPUT if nname in out_set
                            else TensorKind.INTERMEDIATE)
                    if nd.op in ("matmul", "einsum"):
                        b.einsum(nname, nd.param("spec"), list(nd.inputs),
                                 nname, dtype_bytes=nd.dtype_bytes,
                                 out_kind=kind)
                    else:
                        b.elementwise(nname, list(nd.inputs), nname,
                                      dtype_bytes=nd.dtype_bytes,
                                      out_shape=nd.shape, out_kind=kind,
                                      spec=_SPEC[nd.op],
                                      irregular=nd.irregular,
                                      flops=nd.flops)
        return b.graph

    def fingerprint(self) -> str:
        """Content hash over nodes + outputs (cache-key component for
        frontend-built graphs)."""
        h = hashlib.sha256()
        for nname in self._order:
            h.update(repr(dataclasses.astuple(self.nodes[nname])).encode())
        h.update(repr(self.outputs).encode())
        return h.hexdigest()

    def leaves(self) -> List[ExprNode]:
        return [self.nodes[n] for n in self._order if self.nodes[n].is_leaf]

    def schedulable_order(self) -> List[str]:
        """The non-leaf node names in build order (a valid topo order) —
        the op universe every schedule must permute."""
        return [n for n in self._order if not self.nodes[n].is_leaf]

    def __repr__(self) -> str:
        n_ops = sum(1 for nd in self.nodes.values() if not nd.is_leaf)
        return (f"Program({self.name!r}, {n_ops} ops, "
                f"{len(self.nodes) - n_ops} leaves, "
                f"{len(self.outputs)} outputs)")
