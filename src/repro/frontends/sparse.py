"""Deterministic CSR pattern / value generators for sparse operands.

A sparse operator leaf (``Program.sparse_operator``) is three typed
sub-leaves — ``A.indptr`` (int32, ``(n+1,)``), ``A.indices`` (int32,
``(nnz,)``), ``A.data`` (float, ``(nnz,)``) — whose *shapes* must be known
at DAG-build time.  This module is therefore the single source of truth for
both sides of that contract:

* :func:`pattern_nnz` / :func:`row_counts` — the exact nonzero count of a
  pattern, computed at build time to size the sub-leaves,
* :func:`csr_component` — the deterministic values ``make_feeds`` generates
  at feed time (same per-(seed, operand) stream as every other leaf; the
  three sub-leaves of one operand share one stream so they describe one
  matrix).

Patterns (all square, diagonal always present):

``laplacian5``
    The 5-point Laplacian of a ``g×g`` grid with Dirichlet boundaries
    (``n = g²``): 4 on the diagonal, −1 per grid neighbour.  Exactly
    symmetric positive definite — the canonical Krylov test operator.
    ``nnz = 5n − 4g`` (boundary rows lose neighbours).

``banded``
    All ``|i − j| ≤ bandwidth``; off-diagonal values are symmetric random
    draws and the diagonal is ``1 + Σ|row off-diagonals|``, so the matrix
    is symmetric strictly diagonally dominant ⇒ SPD.
    ``nnz = n(2b+1) − b(b+1)``.

``random``
    Uniform density: every row gets ``max(1, round(density·n))`` entries
    (diagonal + random distinct columns).  Values are random with a
    dominant diagonal; *not* symmetric — use it for BiCGStab/Jacobi-style
    solvers or reuse analysis, not CG convergence claims.

``skewed``
    Power-law row populations (row ``r`` weight ``1/√(r+1)``) at a target
    overall density — the skewed-density regime Tailors-style buffer
    policies care about.  Same value model as ``random``.

Everything here is plain NumPy (no scipy); :func:`csr_to_dense` is the
explicit densifier tests and docs use as the scipy-free reference.
"""
from __future__ import annotations

import functools
import hashlib
import math
from typing import Dict, Optional

import numpy as np

PATTERNS = ("laplacian5", "banded", "random", "skewed")


def rng_for(seed: int, name: str) -> np.random.Generator:
    """Deterministic per-(seed, name) generator (same scheme as
    ``frontends.reference``)."""
    h = hashlib.sha256(f"{seed}\0{name}".encode()).digest()
    return np.random.default_rng(int.from_bytes(h[:8], "little"))


def _grid_side(n: int) -> int:
    g = math.isqrt(n)
    if g * g != n:
        raise ValueError(f"laplacian5 needs a square grid: n={n} is not a "
                         "perfect square")
    return g


def row_counts(pattern: str, n: int, *, density: Optional[float] = None,
               bandwidth: Optional[int] = None) -> np.ndarray:
    """Per-row nonzero counts of a pattern — exact, deterministic, and
    computable at DAG-build time (no value generation involved)."""
    if n < 1:
        raise ValueError(f"sparse operator needs n >= 1, got {n}")
    if pattern == "laplacian5":
        g = _grid_side(n)
        i, j = np.divmod(np.arange(n), g)
        return (1 + (i > 0) + (i < g - 1) + (j > 0)
                + (j < g - 1)).astype(np.int64)
    if pattern == "banded":
        if bandwidth is None or bandwidth < 1 or bandwidth >= n:
            raise ValueError(f"banded pattern needs 1 <= bandwidth < n, "
                             f"got bandwidth={bandwidth!r} (n={n})")
        r = np.arange(n)
        return np.minimum(r, bandwidth) + np.minimum(n - 1 - r,
                                                     bandwidth) + 1
    if pattern == "random":
        if density is None or not 0.0 < density <= 1.0:
            raise ValueError(f"random pattern needs 0 < density <= 1, "
                             f"got {density!r}")
        k = min(n, max(1, int(round(density * n))))
        return np.full(n, k, np.int64)
    if pattern == "skewed":
        if density is None or not 0.0 < density <= 1.0:
            raise ValueError(f"skewed pattern needs 0 < density <= 1, "
                             f"got {density!r}")
        w = 1.0 / np.sqrt(np.arange(n) + 1.0)
        target = density * n * n
        return np.clip(np.floor(target * w / w.sum()).astype(np.int64),
                       1, n)
    raise ValueError(f"unknown sparse pattern {pattern!r}; "
                     f"have {PATTERNS}")


def pattern_nnz(pattern: str, n: int, *, density: Optional[float] = None,
                bandwidth: Optional[int] = None) -> int:
    """Exact nonzero count of a pattern (sizes the CSR sub-leaves)."""
    return int(row_counts(pattern, n, density=density,
                          bandwidth=bandwidth).sum())


@functools.lru_cache(maxsize=16)
def _components(pattern: str, n: int, density: Optional[float],
                bandwidth: Optional[int], seed: int,
                operand: str) -> Dict[str, np.ndarray]:
    """Build the full CSR of one operand: indptr/indices/data/dinv.

    Values are generated in float64 (cast to the requested dtype by the
    caller) from one rng stream keyed by (seed, operand name), so the three
    sub-leaves — drawn through separate ``make_feeds`` calls — always
    describe the same matrix.  Cached: one operand is typically read as
    3–4 leaves per feed build.
    """
    rng = rng_for(seed, operand)
    counts = row_counts(pattern, n, density=density, bandwidth=bandwidth)
    nnz = int(counts.sum())
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    indices = np.empty(nnz, np.int64)
    data = np.empty(nnz, np.float64)

    if pattern == "laplacian5":
        g = _grid_side(n)
        pos = 0
        for r in range(n):
            i, j = divmod(r, g)
            cols = [r - g] * (i > 0) + [r - 1] * (j > 0) + [r] \
                + [r + 1] * (j < g - 1) + [r + g] * (i < g - 1)
            k = len(cols)
            indices[pos:pos + k] = cols
            data[pos:pos + k] = np.where(np.asarray(cols) == r, 4.0, -1.0)
            pos += k
    elif pattern == "banded":
        # symmetric off-diagonal values: v(i, j) = V[min(i, j), |i - j|]
        V = rng.standard_normal((n, bandwidth + 1))
        pos = 0
        for r in range(n):
            lo, hi = max(0, r - bandwidth), min(n - 1, r + bandwidth)
            cols = np.arange(lo, hi + 1)
            k = cols.size
            indices[pos:pos + k] = cols
            data[pos:pos + k] = V[np.minimum(cols, r), np.abs(cols - r)]
            pos += k
    else:                                  # random / skewed
        pos = 0
        for r in range(n):
            k = int(counts[r])
            if k >= n:
                cols = np.arange(n)
            else:
                off = rng.choice(n - 1, size=k - 1, replace=False)
                off = np.where(off >= r, off + 1, off)   # skip the diagonal
                cols = np.sort(np.append(off, r))
            indices[pos:pos + k] = cols
            data[pos:pos + k] = rng.standard_normal(k)
            pos += k

    # dominant positive diagonal: 1 + Σ|row off-diagonals| keeps every
    # pattern's iteration stable (and makes the symmetric ones SPD)
    diag_mask = indices == np.repeat(np.arange(n), counts)
    if pattern != "laplacian5":
        rowsum = np.add.reduceat(np.abs(np.where(diag_mask, 0.0, data)),
                                 indptr[:-1])
        data[diag_mask] = 1.0 + rowsum
    dinv = 1.0 / data[diag_mask]
    return {"indptr": indptr.astype(np.int32),
            "indices": indices.astype(np.int32),
            "data": data, "dinv": dinv}


def csr_component(node, seed: int, dtype) -> np.ndarray:
    """The feed value of one CSR sub-leaf (``make_feeds``'s ``init="csr"``
    rule).  ``node`` is the sub-leaf's ExprNode; its params carry the
    pattern and the ``role`` (indptr | indices | data | dinv)."""
    operand = node.name.rsplit(".", 1)[0]
    comp = _components(node.param("pattern"), int(node.param("rows")),
                       node.param("density"), node.param("bandwidth"),
                       int(seed), operand)
    role = node.param("role")
    if role not in comp:
        raise ValueError(f"{node.name}: unknown CSR role {role!r}")
    arr = comp[role]
    if role in ("indptr", "indices"):
        return arr.copy()                 # index leaves stay int32
    return arr.astype(dtype)              # float64 -> requested width


def csr_to_dense(indptr: np.ndarray, indices: np.ndarray,
                 data: np.ndarray, shape) -> np.ndarray:
    """Explicit scipy-free densifier — the reference tests compare sparse
    results against ``csr_to_dense(...) @ x``."""
    rows, cols = shape
    out = np.zeros((rows, cols), np.asarray(data).dtype)
    indptr = np.asarray(indptr)
    counts = np.diff(indptr)
    out[np.repeat(np.arange(rows), counts), np.asarray(indices)] = data
    return out
