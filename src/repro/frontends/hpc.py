"""Paper-style HPC workload library on the expression frontend.

These are the DAGs CELLO's headline numbers are claimed on: Krylov solvers
and tensor kernels with *skewed-shape* operators (an ``(n×n)`` matrix
against ``(n,)`` vectors) and *cross-iteration* reuse the schedule alone
cannot capture — the operator ``A`` is re-read every iteration, the
direction/residual vectors chain across iterations with multiple consumers
each.  Solver loops are unrolled to ``iters`` iterations so the reuse is
visible to the (loop-free) op-DAG analysis.

Sizing convention: tensors default to fp64 (``dtype_bytes=8``).  At the
paper-scale ``n=4096`` the CG operator is exactly 128 MiB — the size of the
whole v5e-class on-chip buffer — so an implicit-only (pure LRU) buffer
thrashes on it every iteration while CELLO pins it in the explicit region
and reads it from HBM once.

Every builder returns a :class:`~repro.frontends.expr.Program`; reach them
through ``Session(...).trace(workload=<name>, **params)`` or directly via
:func:`build_workload`.
"""
from __future__ import annotations

import inspect
from typing import Callable, Dict, List

from .expr import Expr, Program


def _require_positive(**params: int) -> None:
    for key, val in params.items():
        if not isinstance(val, int) or val < 1:
            raise ValueError(f"{key} must be a positive int, got {val!r}")


def _sparse_tag(pattern: str, density, bandwidth) -> str:
    tag = pattern
    if density is not None:
        tag += f"_d{density}"
    if bandwidth is not None:
        tag += f"_b{bandwidth}"
    return tag


def cg(n: int = 4096, iters: int = 4) -> Program:
    """Conjugate Gradient on an SPD operator, ``iters`` unrolled iterations.

    Cross-iteration reuse: ``A`` feeds every iteration's matvec; each
    ``p_k`` has four consumers (matvec, curvature dot, x- and p-updates);
    each ``r_k`` has three.
    """
    _require_positive(n=n, iters=iters)
    p = Program(f"cg_n{n}_k{iters}")
    A = p.operator("A", (n, n), init="spd")
    b = p.input("b", (n,))
    x = p.input("x0", (n,), init="zeros")
    r = p.sub(b, p.matmul(A, x, name="Ax0"), name="r0")
    pk = r                                  # p0 aliases r0
    rs = p.dot(r, r, name="rs0")
    for k in range(iters):
        with p.iteration():
            Ap = p.matmul(A, pk, name=f"Ap{k}")
            pAp = p.dot(pk, Ap, name=f"pAp{k}")
            alpha = p.div(rs, pAp, name=f"alpha{k}")
            x = p.axpy(alpha, pk, x, name=f"x{k + 1}")
            r = p.axpy(p.neg(alpha, name=f"nalpha{k}"), Ap, r,
                       name=f"r{k + 1}")
            rs_new = p.dot(r, r, name=f"rs{k + 1}")
            beta = p.div(rs_new, rs, name=f"beta{k}")
            pk = p.axpy(beta, pk, r, name=f"p{k + 1}")
            rs = rs_new
    p.output(x, r)
    return p


def bicgstab(n: int = 4096, iters: int = 3) -> Program:
    """BiCGStab: two skewed matvecs per iteration plus the shadow residual
    ``rhat`` read every iteration (another long-range pin candidate)."""
    _require_positive(n=n, iters=iters)
    p = Program(f"bicgstab_n{n}_k{iters}")
    A = p.operator("A", (n, n), init="spd")
    b = p.input("b", (n,))
    x = p.input("x0", (n,), init="zeros")
    r = p.sub(b, p.matmul(A, x, name="Ax0"), name="r0")
    rhat = r                                # shadow residual, fixed
    pk = r
    rho = p.dot(rhat, r, name="rho0")
    for k in range(iters):
        with p.iteration():
            v = p.matmul(A, pk, name=f"v{k}")
            alpha = p.div(rho, p.dot(rhat, v, name=f"rhv{k}"),
                          name=f"alpha{k}")
            s = p.axpy(p.neg(alpha, name=f"nalpha{k}"), v, r, name=f"s{k}")
            t = p.matmul(A, s, name=f"t{k}")
            omega = p.div(p.dot(t, s, name=f"ts{k}"),
                          p.dot(t, t, name=f"tt{k}"), name=f"omega{k}")
            x = p.axpy(omega, s, p.axpy(alpha, pk, x, name=f"xh{k}"),
                       name=f"x{k + 1}")
            r = p.axpy(p.neg(omega, name=f"nomega{k}"), t, s,
                       name=f"r{k + 1}")
            rho_new = p.dot(rhat, r, name=f"rho{k + 1}")
            beta = p.mul(p.div(rho_new, rho, name=f"rr{k}"),
                         p.div(alpha, omega, name=f"ao{k}"), name=f"beta{k}")
            pk = p.axpy(beta,
                        p.axpy(p.neg(omega, name=f"nomega2_{k}"), v, pk,
                               name=f"pv{k}"),
                        r, name=f"p{k + 1}")
            rho = rho_new
    p.output(x, r)
    return p


def gmres(n: int = 4096, restart: int = 8) -> Program:
    """GMRES(m) inner loop: Arnoldi with modified Gram–Schmidt.  ``A`` is
    read ``m`` times; basis vector ``v_i`` is re-read by every later
    orthogonalization step — triangular, growing-distance reuse."""
    _require_positive(n=n, restart=restart)
    m = restart
    p = Program(f"gmres_n{n}_m{m}")
    A = p.operator("A", (n, n), init="spd")
    b = p.input("b", (n,))
    x = p.input("x0", (n,), init="zeros")
    r = p.sub(b, p.matmul(A, x, name="Ax0"), name="r0")
    beta = p.norm(r, name="beta0")
    vs: List[Expr] = [p.div(r, beta, name="v0")]
    h_last = beta
    for j in range(m):
        # each Arnoldi step is recorded as an iteration body even though
        # the growing orthogonalization loop makes the bodies structurally
        # distinct: the roll detector must prove them identical (it will
        # refuse here) rather than assume it
        with p.iteration():
            w = p.matmul(A, vs[j], name=f"w{j}")
            for i in range(j + 1):
                hij = p.dot(vs[i], w, name=f"h{i}_{j}")
                w = p.axpy(p.neg(hij, name=f"nh{i}_{j}"), vs[i], w,
                           name=f"w{j}_{i}")
            h_last = p.norm(w, name=f"h{j + 1}_{j}")
            vs.append(p.div(w, h_last, name=f"v{j + 1}"))
    p.output(vs[-1], h_last)
    return p


def jacobi2d(n: int = 4096, sweeps: int = 8) -> Program:
    """Jacobi 5-point relaxation on an ``(n×n)`` grid: the source term
    ``f`` is re-read by every sweep while the iterates chain through."""
    _require_positive(n=n, sweeps=sweeps)
    p = Program(f"jacobi2d_n{n}_s{sweeps}")
    u = p.input("u0", (n, n))
    f = p.input("f", (n, n))
    for k in range(sweeps):
        with p.iteration():
            u = p.stencil2d(u, f, name=f"u{k + 1}")
    p.output(u)
    return p


def power_iteration(n: int = 4096, iters: int = 8) -> Program:
    """Power iteration: one skewed matvec + normalization per iteration;
    ``A`` is the sole cross-iteration reuse, read ``iters`` times."""
    _require_positive(n=n, iters=iters)
    p = Program(f"power_n{n}_k{iters}")
    A = p.operator("A", (n, n), init="spd")
    x = p.input("x0", (n,))
    lam = None
    for k in range(iters):
        with p.iteration():
            y = p.matmul(A, x, name=f"y{k}")
            lam = p.norm(y, name=f"lam{k}")
            x = p.div(y, lam, name=f"x{k + 1}")
    p.output(x, lam)
    return p


def mttkrp(i: int = 256, j: int = 256, k: int = 256,
           rank: int = 64) -> Program:
    """Two-mode MTTKRP (one ALS half-sweep): both contractions re-read the
    dense tensor ``X`` and share the factor ``C``; the second mode also
    consumes the first's output, chaining the reuse."""
    _require_positive(i=i, j=j, k=k, rank=rank)
    p = Program(f"mttkrp_{i}x{j}x{k}_r{rank}")
    X = p.operator("X", (i, j, k))
    B = p.input("B", (j, rank))
    C = p.input("C", (k, rank))
    m1 = p.einsum("ijk,jr,kr->ir", X, B, C, name="M1")
    m2 = p.einsum("ijk,ir,kr->jr", X, m1, C, name="M2")
    p.output(m1, m2)
    return p


def cg_sparse(n: int = 4096, iters: int = 4, *,
              pattern: str = "laplacian5",
              density: float = None, bandwidth: int = None) -> Program:
    """Conjugate Gradient with a CSR sparse operator.

    Identical iteration structure to :func:`cg`, but the matvec is an
    nnz-costed ``spmv`` over the operand's CSR triple — the cross-
    iteration reuse the co-designer must capture is the *nnz footprint*
    (``A.indptr + A.indices + A.data``), not a dense ``n²`` silhouette.
    Default pattern is the SPD 5-point Laplacian (``n`` must be a perfect
    square); ``banded`` is also SPD, ``random``/``skewed`` are diagonally
    dominant only (use them for reuse/bench studies, not CG convergence).
    """
    _require_positive(n=n, iters=iters)
    tag = _sparse_tag(pattern, density, bandwidth)
    p = Program(f"cg_sparse_n{n}_k{iters}_{tag}")
    A = p.sparse_operator("A", (n, n), pattern=pattern, density=density,
                          bandwidth=bandwidth)
    b = p.input("b", (n,))
    x = p.input("x0", (n,), init="zeros")
    r = p.sub(b, p.spmv(A, x, name="Ax0"), name="r0")
    pk = r                                  # p0 aliases r0
    rs = p.dot(r, r, name="rs0")
    for k in range(iters):
        with p.iteration():
            Ap = p.spmv(A, pk, name=f"Ap{k}")
            pAp = p.dot(pk, Ap, name=f"pAp{k}")
            alpha = p.div(rs, pAp, name=f"alpha{k}")
            x = p.axpy(alpha, pk, x, name=f"x{k + 1}")
            r = p.axpy(p.neg(alpha, name=f"nalpha{k}"), Ap, r,
                       name=f"r{k + 1}")
            rs_new = p.dot(r, r, name=f"rs{k + 1}")
            beta = p.div(rs_new, rs, name=f"beta{k}")
            pk = p.axpy(beta, pk, r, name=f"p{k + 1}")
            rs = rs_new
    p.output(x, r)
    return p


def bicgstab_sparse(n: int = 4096, iters: int = 3, *,
                    pattern: str = "laplacian5",
                    density: float = None,
                    bandwidth: int = None) -> Program:
    """BiCGStab with a CSR sparse operator: two nnz-costed spmv per
    iteration; works on the nonsymmetric ``random``/``skewed`` patterns
    too (they are diagonally dominant)."""
    _require_positive(n=n, iters=iters)
    tag = _sparse_tag(pattern, density, bandwidth)
    p = Program(f"bicgstab_sparse_n{n}_k{iters}_{tag}")
    A = p.sparse_operator("A", (n, n), pattern=pattern, density=density,
                          bandwidth=bandwidth)
    b = p.input("b", (n,))
    x = p.input("x0", (n,), init="zeros")
    r = p.sub(b, p.spmv(A, x, name="Ax0"), name="r0")
    rhat = r                                # shadow residual, fixed
    pk = r
    rho = p.dot(rhat, r, name="rho0")
    for k in range(iters):
        with p.iteration():
            v = p.spmv(A, pk, name=f"v{k}")
            alpha = p.div(rho, p.dot(rhat, v, name=f"rhv{k}"),
                          name=f"alpha{k}")
            s = p.axpy(p.neg(alpha, name=f"nalpha{k}"), v, r, name=f"s{k}")
            t = p.spmv(A, s, name=f"t{k}")
            omega = p.div(p.dot(t, s, name=f"ts{k}"),
                          p.dot(t, t, name=f"tt{k}"), name=f"omega{k}")
            x = p.axpy(omega, s, p.axpy(alpha, pk, x, name=f"xh{k}"),
                       name=f"x{k + 1}")
            r = p.axpy(p.neg(omega, name=f"nomega{k}"), t, s,
                       name=f"r{k + 1}")
            rho_new = p.dot(rhat, r, name=f"rho{k + 1}")
            beta = p.mul(p.div(rho_new, rho, name=f"rr{k}"),
                         p.div(alpha, omega, name=f"ao{k}"), name=f"beta{k}")
            pk = p.axpy(beta,
                        p.axpy(p.neg(omega, name=f"nomega2_{k}"), v, pk,
                               name=f"pv{k}"),
                        r, name=f"p{k + 1}")
            rho = rho_new
    p.output(x, r)
    return p


def jacobi_sparse(n: int = 4096, sweeps: int = 8, *,
                  pattern: str = "laplacian5",
                  density: float = None, bandwidth: int = None) -> Program:
    """Jacobi relaxation on a CSR operator:
    ``x' = x + D⁻¹ (b − A x)``.  The operand's CSR triple *and* the
    derived ``A.dinv`` leaf are re-read every sweep — four co-scheduled
    pin candidates whose combined footprint is nnz-sized."""
    _require_positive(n=n, sweeps=sweeps)
    tag = _sparse_tag(pattern, density, bandwidth)
    p = Program(f"jacobi_sparse_n{n}_s{sweeps}_{tag}")
    A = p.sparse_operator("A", (n, n), pattern=pattern, density=density,
                          bandwidth=bandwidth)
    dinv = A.diag_inv()
    b = p.input("b", (n,))
    x = p.input("x0", (n,), init="zeros")
    for k in range(sweeps):
        with p.iteration():
            Ax = p.spmv(A, x, name=f"Ax{k}")
            r = p.sub(b, Ax, name=f"r{k}")
            x = p.add(x, p.mul(dinv, r, name=f"dr{k}"), name=f"x{k + 1}")
    p.output(x)
    return p


WORKLOADS: Dict[str, Callable[..., Program]] = {
    "cg": cg,
    "bicgstab": bicgstab,
    "gmres": gmres,
    "jacobi2d": jacobi2d,
    "power_iteration": power_iteration,
    "mttkrp": mttkrp,
    "cg_sparse": cg_sparse,
    "bicgstab_sparse": bicgstab_sparse,
    "jacobi_sparse": jacobi_sparse,
}


def list_workloads() -> List[str]:
    return sorted(WORKLOADS)


def build_workload(name: str, **params) -> Program:
    """Instantiate a registered workload; unknown names/params raise with
    the available choices spelled out."""
    if name not in WORKLOADS:
        raise KeyError(f"unknown HPC workload {name!r}; "
                       f"have {list_workloads()}")
    builder = WORKLOADS[name]
    sig = inspect.signature(builder)
    bad = set(params) - set(sig.parameters)
    if bad:
        raise TypeError(f"workload {name!r} got unexpected params "
                        f"{sorted(bad)}; accepts "
                        f"{sorted(sig.parameters)}")
    return builder(**params)
