"""Deterministic feeds (and numerics re-exports) for expression programs.

Leaf values come from :func:`make_feeds`: deterministic per (seed, leaf
name), honoring each leaf's ``init`` hint (``spd`` builds a well-conditioned
symmetric positive-definite operator so unrolled Krylov iterations stay
finite; ``csr`` builds one component of a sparse operand's CSR triple via
the pattern generators in ``repro.frontends.sparse``; ``zeros`` / ``ones``
/ ``const`` / ``indices`` / ``randn`` cover the rest).  ``dtype`` picks the float width of the generated leaves —
pass ``np.float64`` (with ``jax_enable_x64`` on) to validate the fp64-modeled
Krylov workloads at their modeled precision instead of silently downcasting
to float32.

The interpreter that used to live here is now the ``reference`` execution
backend (``repro.exec.reference``); :func:`evaluate` / :func:`execute_plan`
are re-exported for compatibility and remain the numerical oracle every
lowered plan is validated against.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Iterable, Optional

import numpy as np

from ..exec.reference import evaluate, execute_plan      # noqa: F401
from .expr import ExprNode, Program

__all__ = ["make_feeds", "evaluate", "execute_plan"]


def _rng_for(seed: int, name: str) -> np.random.Generator:
    h = hashlib.sha256(f"{seed}\0{name}".encode()).digest()
    return np.random.default_rng(int.from_bytes(h[:8], "little"))


def _init_leaf(node: ExprNode, seed: int,
               dtype: np.dtype = np.float32) -> np.ndarray:
    rng = _rng_for(seed, node.name)
    init = node.param("init", "randn")
    shape = node.shape
    if init == "zeros":
        return np.zeros(shape, dtype)
    if init == "ones":
        return np.ones(shape, dtype)
    if init == "const":
        return np.full(shape, node.param("value", 0.0), dtype)
    if init == "indices":
        high = int(node.param("high", max(1, shape[0] if shape else 1)))
        return rng.integers(0, high, size=shape).astype(np.int32)
    if init == "csr":
        # CSR sub-leaf of a sparse operator: the three (or four, with
        # dinv) sub-leaves of one operand share a single generator stream
        # keyed by the *operand* name, so they describe one matrix
        from .sparse import csr_component
        return csr_component(node, seed, dtype)
    if init == "spd":
        if len(shape) != 2 or shape[0] != shape[1]:
            raise ValueError(f"{node.name}: init='spd' needs a square "
                             f"matrix, got {shape}")
        n = shape[0]
        m = rng.standard_normal((n, n))
        return ((m @ m.T) / n + np.eye(n)).astype(dtype)
    if init == "randn":
        return rng.standard_normal(shape).astype(dtype)
    raise ValueError(f"{node.name}: unknown init hint {init!r}")


def make_feeds(program: Program, seed: int = 0, *,
               dtype: Optional[np.dtype] = None,
               only: Optional[Iterable[str]] = None
               ) -> Dict[str, np.ndarray]:
    """Deterministic values for every leaf (inputs and operators).

    ``dtype`` sets the float width of the generated leaves (integer
    ``indices`` leaves — including CSR ``indptr``/``indices`` sub-leaves —
    stay int32).  Default float32 — JAX's default float
    precision; pass ``np.float64`` under ``jax_enable_x64`` to validate
    fp64-modeled workloads at full width.  The random draws are identical
    across dtypes (same generator stream, cast at the end), so fp32 and
    fp64 feeds describe the same mathematical problem.

    ``only`` restricts generation to a subset of leaf names — the serving
    layer uses it to build a bucket's shared operator feeds once and then
    only the cheap per-request input leaves per request.  Each leaf is
    keyed by (seed, name), so a subset's values are identical to the same
    leaves from a full ``make_feeds`` call.
    """
    dtype = np.dtype(dtype if dtype is not None else np.float32)
    if dtype.kind != "f":
        raise ValueError(f"make_feeds dtype must be a float dtype, "
                         f"got {dtype}")
    leaves = program.leaves()
    if only is not None:
        want = set(only)
        unknown = want - {nd.name for nd in leaves}
        if unknown:
            raise KeyError(f"make_feeds only= names are not leaves of "
                           f"{program.name!r}: {sorted(unknown)}")
        leaves = [nd for nd in leaves if nd.name in want]
    return {nd.name: _init_leaf(nd, seed, dtype) for nd in leaves}
