"""Numerical reference executor for expression-DAG programs.

A ``jax.numpy`` interpreter over :class:`~repro.frontends.expr.Program`:
every lowered ``CompiledPlan`` for a frontend graph is validated against
this oracle (``CompiledPlan.run()`` executes the *scheduled* op order
through the same per-op rules, so plan output must match reference output
bit-for-bit — ops are pure, only the execution order differs).

Leaf values come from :func:`make_feeds`: deterministic per (seed, leaf
name), honoring each leaf's ``init`` hint (``spd`` builds a well-conditioned
symmetric positive-definite operator so unrolled Krylov iterations stay
finite; ``zeros`` / ``ones`` / ``const`` / ``indices`` / ``randn`` cover
the rest).  Execution uses JAX's default float precision — the frontend's
``dtype_bytes`` annotations drive the traffic/energy model, not the math.
"""
from __future__ import annotations

import hashlib
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .expr import ExprNode, Program


def _rng_for(seed: int, name: str) -> np.random.Generator:
    h = hashlib.sha256(f"{seed}\0{name}".encode()).digest()
    return np.random.default_rng(int.from_bytes(h[:8], "little"))


def _init_leaf(node: ExprNode, seed: int) -> np.ndarray:
    rng = _rng_for(seed, node.name)
    init = node.param("init", "randn")
    shape = node.shape
    if init == "zeros":
        return np.zeros(shape, np.float32)
    if init == "ones":
        return np.ones(shape, np.float32)
    if init == "const":
        return np.full(shape, node.param("value", 0.0), np.float32)
    if init == "indices":
        high = int(node.param("high", max(1, shape[0] if shape else 1)))
        return rng.integers(0, high, size=shape).astype(np.int32)
    if init == "spd":
        if len(shape) != 2 or shape[0] != shape[1]:
            raise ValueError(f"{node.name}: init='spd' needs a square "
                             f"matrix, got {shape}")
        n = shape[0]
        m = rng.standard_normal((n, n))
        return ((m @ m.T) / n + np.eye(n)).astype(np.float32)
    if init == "randn":
        return rng.standard_normal(shape).astype(np.float32)
    raise ValueError(f"{node.name}: unknown init hint {init!r}")


def make_feeds(program: Program, seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic values for every leaf (inputs and operators)."""
    return {nd.name: _init_leaf(nd, seed) for nd in program.leaves()}


def _eval_node(node: ExprNode, ins: List[Any]):
    import jax.numpy as jnp
    op = node.op
    if op == "matmul":
        return ins[0] @ ins[1]
    if op == "einsum":
        return jnp.einsum(node.param("spec"), *ins)
    if op == "dot":
        return jnp.dot(ins[0], ins[1])
    if op == "norm":
        return jnp.sqrt(jnp.dot(jnp.ravel(ins[0]), jnp.ravel(ins[0])))
    if op == "add":
        return ins[0] + ins[1]
    if op == "sub":
        return ins[0] - ins[1]
    if op == "mul":
        return ins[0] * ins[1]
    if op == "div":
        return ins[0] / ins[1]
    if op == "neg":
        return -ins[0]
    if op == "axpy":
        return ins[0] * ins[1] + ins[2]
    if op == "stencil2d":
        u = ins[0]
        out = 0.25 * (jnp.roll(u, 1, 0) + jnp.roll(u, -1, 0)
                      + jnp.roll(u, 1, 1) + jnp.roll(u, -1, 1))
        if len(ins) > 1:
            out = out + 0.25 * float(node.param("h2", 1.0)) * ins[1]
        return out
    if op == "gather":
        return jnp.take(ins[0], ins[1], axis=0)
    raise NotImplementedError(f"reference rule missing for op {op!r}")


def execute_plan(program: Program, *, order: Optional[Sequence[str]] = None,
                 feeds: Optional[Dict[str, np.ndarray]] = None,
                 seed: int = 0, return_all: bool = False) -> Dict[str, Any]:
    """Execute the program's ops in ``order`` (default: build order).

    ``order`` is the flattened schedule from a co-designed plan; it must be
    a topological permutation of the program's ops — validated here, since
    a schedule that reads an unproduced tensor is a lowering bug, not a
    numerics question.
    """
    vals: Dict[str, Any] = {}
    op_names = [n for n in program._order if not program.nodes[n].is_leaf]
    order = list(order) if order is not None else op_names
    if sorted(order) != sorted(op_names):
        raise ValueError(f"order is not a permutation of {program.name!r} "
                         "ops")
    feeds = dict(feeds) if feeds is not None else make_feeds(program, seed)
    for nd in program.leaves():
        if nd.name not in feeds:
            raise KeyError(f"feeds missing leaf {nd.name!r}")
        vals[nd.name] = feeds[nd.name]
    # free dead intermediates as execution passes their last consumer —
    # paper-scale grids (jacobi2d n=4096 keeps 64 MiB per sweep) would
    # otherwise all stay resident until the end of the run
    last_use: Dict[str, int] = {}
    for step, nname in enumerate(order):
        for t in program.nodes[nname].inputs:
            last_use[t] = step
    keep = set(program.outputs) if not return_all else set(vals) | set(order)
    for step, nname in enumerate(order):
        node = program.nodes[nname]
        missing = [i for i in node.inputs if i not in vals]
        if missing:
            raise ValueError(f"schedule order not topological: {nname} "
                             f"reads unproduced {missing}")
        vals[nname] = _eval_node(node, [vals[i] for i in node.inputs])
        if not return_all:
            for t in set(node.inputs):
                if last_use[t] == step and t not in keep:
                    del vals[t]
    if return_all:
        return vals
    return {o: vals[o] for o in program.outputs}


def evaluate(program: Program,
             feeds: Optional[Dict[str, np.ndarray]] = None, *,
             seed: int = 0, return_all: bool = False) -> Dict[str, Any]:
    """Reference evaluation in the program's natural (build) order."""
    return execute_plan(program, order=None, feeds=feeds, seed=seed,
                        return_all=return_all)
