"""`repro.frontends` — workload frontends for the CELLO co-designer.

The core toolchain reasons over :class:`repro.core.OpGraph`; until now the
only producer of such graphs was the LLM arch registry (`core.lowering`).
This package opens the paper's *other* workload class — HPC DAGs with
skewed-shape operators and complex cross-iteration reuse:

  ``expr``       — a small NumPy-like tensor-expression builder whose DAGs
                   lower to ``OpGraph`` via ``OpGraph.build()`` with correct
                   ``TensorKind`` tagging and FLOP/byte annotations, so the
                   reuse / buffer / cost-model layers work unchanged,
  ``hpc``        — a library of paper-style workloads built on it (CG,
                   BiCGStab, GMRES(m), Jacobi 2-D sweep, power iteration,
                   MTTKRP — plus CSR-sparse variants ``cg_sparse`` /
                   ``bicgstab_sparse`` / ``jacobi_sparse``), each
                   parameterized by size / skew / sparsity pattern,
  ``sparse``     — deterministic CSR pattern/value generators (5-point
                   Laplacian, banded, random, skewed density) shared by
                   the build-time nnz sizing and the feed-time values,
  ``reference``  — deterministic per-leaf feeds (``make_feeds``, with a
                   ``dtype`` knob for fp64 validation) plus re-exports of
                   the numerical oracle, which now lives with the other
                   execution backends in ``repro.exec``.

Entry points: ``Session(...).trace(workload="cg", n=4096, iters=4)`` or
``Session.from_graph(program)`` — both flow through the standard
``analyze → codesign → lower`` stages and the codesign disk cache; the
lowered plan executes via ``plan.run(backend="reference" | "pallas")``.
"""
from .expr import Expr, ExprNode, Program, SparseOperand
from .hpc import (WORKLOADS, bicgstab, bicgstab_sparse, build_workload, cg,
                  cg_sparse, gmres, jacobi2d, jacobi_sparse, list_workloads,
                  mttkrp, power_iteration)
from .reference import evaluate, execute_plan, make_feeds
from .sparse import csr_to_dense, pattern_nnz

__all__ = [
    "Expr", "ExprNode", "Program", "SparseOperand",
    "WORKLOADS", "build_workload", "list_workloads",
    "cg", "bicgstab", "gmres", "jacobi2d", "power_iteration", "mttkrp",
    "cg_sparse", "bicgstab_sparse", "jacobi_sparse",
    "evaluate", "execute_plan", "make_feeds",
    "csr_to_dense", "pattern_nnz",
]
