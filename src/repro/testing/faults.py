"""Deterministic fault injection at named sites.

Production code declares *sites* — stable names at the places failures
happen in the wild — by calling :func:`check` (raise / delay) or
:func:`corrupt_text` / :func:`corrupt_bytes` (payload mangling) with the
site name plus context labels::

    faults.check("exec.compile", backend="pallas")
    blob = faults.corrupt_text("codesign.cache", blob)

When no rules are armed these are a single ``if not _RULES`` — safe on
hot paths.  Tests arm rules with the :func:`inject` context manager, and
operators / CI arm them process-wide with the ``CELLO_FAULTS``
environment variable (parsed once at import; re-read with
:func:`configure_from_env`)::

    CELLO_FAULTS="exec.compile@pallas=fail:x3,serve.dispatch=slow:0.05"

Spec grammar (comma-separated clauses)::

    site[@qualifier]=kind[:seconds][:xN][:skipK]

* ``site`` — the exact site name; ``@qualifier`` additionally requires
  the qualifier to appear among the call's context-label values (so
  ``exec.compile@pallas`` arms the pallas backend only).
* ``kind`` — ``fail`` (raise :class:`InjectedFault`), ``slow`` (sleep
  ``seconds``, default 0.01), or ``corrupt`` (truncate the payload at a
  ``corrupt_*`` site).
* ``xN`` — fire on at most N matching calls (default: every call).
* ``skipK`` — let the first K matching calls through unharmed.

Counting is per-rule, under a lock, so a spec like ``fail:x3`` means
*exactly* the first three matching calls fail — deterministic by
construction, which is what lets the chaos suite assert precise
retry/breaker/fallback behaviour.  Every fired rule bumps the
``faults.injected`` counter (labels: site, kind) on the ``repro.obs``
registry.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import time
from typing import Dict, Iterator, List, Optional

from .. import obs

__all__ = [
    "InjectedFault", "FaultRule", "check", "corrupt_bytes", "corrupt_text",
    "inject", "inject_spec", "parse_spec", "configure_from_env", "clear",
    "active", "rules",
]

ENV_VAR = "CELLO_FAULTS"

_INJECTED = obs.registry().counter(
    "faults.injected", "fault-injection rules fired (labels: site, kind)")


class InjectedFault(RuntimeError):
    """The exception raised by an armed ``fail`` rule."""


@dataclasses.dataclass
class FaultRule:
    """One armed fault: where it bites, what it does, and how often."""
    site: str
    kind: str = "fail"                 # fail | slow | corrupt
    qualifier: Optional[str] = None    # must appear among ctx label values
    delay_s: float = 0.01              # slow only
    times: Optional[int] = None        # fire at most this many times
    skip: int = 0                      # let the first K matches through
    message: str = ""
    seen: int = 0                      # matching calls observed
    fired: int = 0                     # matching calls actually harmed

    def _matches(self, site: str, ctx: Dict[str, object]) -> bool:
        if self.site != site:
            return False
        if self.qualifier is None:
            return True
        return any(str(v) == self.qualifier for v in ctx.values())

    def _should_fire(self) -> bool:
        """Call with the module lock held; advances this rule's counters."""
        self.seen += 1
        if self.seen <= self.skip:
            return False
        if self.times is not None and self.fired >= self.times:
            return False
        self.fired += 1
        return True


_LOCK = threading.Lock()
_RULES: List[FaultRule] = []


def active() -> bool:
    """True when any rule is armed (cheap, lock-free)."""
    return bool(_RULES)


def rules() -> List[FaultRule]:
    """Snapshot of the armed rules (the live objects — read their
    ``seen`` / ``fired`` counters, don't mutate)."""
    with _LOCK:
        return list(_RULES)


def clear() -> None:
    """Disarm everything (including ``CELLO_FAULTS`` rules)."""
    with _LOCK:
        _RULES.clear()


def _arm(rule: FaultRule) -> FaultRule:
    with _LOCK:
        _RULES.append(rule)
    return rule


def _disarm(rule: FaultRule) -> None:
    with _LOCK:
        with contextlib.suppress(ValueError):
            _RULES.remove(rule)


def check(site: str, **ctx) -> None:
    """Fault hook for ``fail`` / ``slow`` rules.  No-op unless armed."""
    if not _RULES:
        return
    delays: List[float] = []
    raised: Optional[FaultRule] = None
    with _LOCK:
        for rule in _RULES:
            if rule.kind == "corrupt" or not rule._matches(site, ctx):
                continue
            if not rule._should_fire():
                continue
            _INJECTED.inc(site=site, kind=rule.kind)
            if rule.kind == "slow":
                delays.append(rule.delay_s)
            else:
                raised = rule
                break
    for d in delays:
        time.sleep(d)
    if raised is not None:
        raise InjectedFault(
            raised.message
            or f"injected fault at {site} ({ctx or 'no context'})")


def corrupt_bytes(site: str, data: bytes, **ctx) -> bytes:
    """Fault hook for payload corruption: an armed ``corrupt`` rule
    truncates the payload to half its length (never valid JSON/pickle
    past trivial sizes).  Returns the payload unchanged when unarmed."""
    if not _RULES:
        return data
    with _LOCK:
        for rule in _RULES:
            if rule.kind != "corrupt" or not rule._matches(site, ctx):
                continue
            if not rule._should_fire():
                continue
            _INJECTED.inc(site=site, kind="corrupt")
            return data[: len(data) // 2]
    return data


def corrupt_text(site: str, data: str, **ctx) -> str:
    """:func:`corrupt_bytes` for text payloads."""
    if not _RULES:
        return data
    out = corrupt_bytes(site, data.encode("utf-8"), **ctx)
    return out.decode("utf-8", errors="ignore")


# -- spec parsing ------------------------------------------------------
def _parse_clause(clause: str) -> FaultRule:
    site_part, sep, action = clause.partition("=")
    if not sep or not site_part or not action:
        raise ValueError(f"bad fault clause {clause!r}: want "
                         "site[@qualifier]=kind[:seconds][:xN][:skipK]")
    site, _, qualifier = site_part.partition("@")
    toks = action.split(":")
    kind = toks[0]
    if kind not in ("fail", "slow", "corrupt"):
        raise ValueError(f"bad fault kind {kind!r} in {clause!r}: "
                         "want fail, slow or corrupt")
    rule = FaultRule(site=site.strip(), kind=kind,
                     qualifier=qualifier.strip() or None)
    for tok in toks[1:]:
        tok = tok.strip()
        if not tok:
            continue
        if tok.startswith("x") and tok[1:].isdigit():
            rule.times = int(tok[1:])
        elif tok.startswith("skip") and tok[4:].isdigit():
            rule.skip = int(tok[4:])
        else:
            try:
                rule.delay_s = float(tok)
            except ValueError:
                raise ValueError(
                    f"bad fault option {tok!r} in {clause!r}: want a "
                    "seconds float, xN, or skipK") from None
    return rule


def parse_spec(spec: str) -> List[FaultRule]:
    """Parse a ``CELLO_FAULTS`` spec into rules (without arming them)."""
    out = []
    for clause in spec.split(","):
        clause = clause.strip()
        if clause:
            out.append(_parse_clause(clause))
    return out


@contextlib.contextmanager
def inject(site: str, kind: str = "fail", *, qualifier: str = None,
           delay_s: float = 0.01, times: Optional[int] = None,
           skip: int = 0, message: str = "") -> Iterator[FaultRule]:
    """Arm one rule for the duration of a ``with`` block.  ``site`` may
    carry an inline ``@qualifier`` (``inject("exec.compile@pallas")``)."""
    if "@" in site and qualifier is None:
        site, _, qualifier = site.partition("@")
    rule = _arm(FaultRule(site=site, kind=kind, qualifier=qualifier,
                          delay_s=delay_s, times=times, skip=skip,
                          message=message))
    try:
        yield rule
    finally:
        _disarm(rule)


@contextlib.contextmanager
def inject_spec(spec: str) -> Iterator[List[FaultRule]]:
    """Arm a full ``CELLO_FAULTS``-grammar spec for a ``with`` block."""
    armed = [_arm(r) for r in parse_spec(spec)]
    try:
        yield armed
    finally:
        for r in armed:
            _disarm(r)


def configure_from_env(env: Optional[Dict[str, str]] = None
                       ) -> List[FaultRule]:
    """Arm rules from ``CELLO_FAULTS`` (idempotent per call: previously
    env-armed rules are replaced, ``inject``-armed ones are kept)."""
    spec = (env if env is not None else os.environ).get(ENV_VAR, "")
    with _LOCK:
        _RULES[:] = [r for r in _RULES if not getattr(r, "_from_env", False)]
    armed = []
    for rule in parse_spec(spec):
        rule._from_env = True  # type: ignore[attr-defined]
        armed.append(_arm(rule))
    return armed


configure_from_env()
