"""Test-support machinery that ships with the library.

``repro.testing.faults`` is the deterministic fault-injection harness:
production code calls :func:`~repro.testing.faults.check` /
:func:`~repro.testing.faults.corrupt_text` at named sites, and tests (or
the ``CELLO_FAULTS`` environment variable) arm rules that fail, delay,
or corrupt exactly the calls they name.  See ``docs/robustness.md``.
"""
from . import faults

__all__ = ["faults"]
