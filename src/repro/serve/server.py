"""`Server`: an async request queue that coalesces solves into batches.

Callers :meth:`~Server.submit` :class:`~repro.serve.router.SolveRequest`\\ s
and get back ``concurrent.futures.Future``\\ s; one worker thread drains the
queue, grouping same-bucket requests into a single
:class:`~repro.serve.batched.BatchedPlan` dispatch.  Two knobs trade
latency for throughput:

* ``max_batch_size`` — a batch closes as soon as this many same-bucket
  requests are queued;
* ``max_wait_us`` — a batch also closes once its oldest request has waited
  this long, so a trickle of traffic is not stalled fishing for batchmates.

``policy`` picks which bucket the worker drains next: ``oldest`` (default,
longest-waiting head request) or ``round_robin`` (least-recently-served
non-empty bucket — no bucket starves under sustained hot-bucket load).

All JAX work happens on the one worker thread (routing, compiles and
dispatches never race each other); ``submit`` only canonicalizes the
bucket key — invalid requests raise in the caller, never poison the queue.
Execution errors propagate through each affected request's future.

``stats()`` is the observability surface: per-bucket request/batch
counters, a batch-size histogram, queue-wait / end-to-end latency
quantiles, plan-cache hits/misses, the vmapped executable's
dispatch/trace counters, and current queue depth — the numbers CI's
smoke job asserts one-dispatch-per-coalesced-batch with.  The counters
live on the ``repro.obs`` registry (under this server's unique scope
label) and the whole snapshot is taken while holding the server's
condition variable, so it is consistent: at any instant
``requests == queued + in_flight + errors + sum(size * count)`` over the
batch-size histogram.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Tuple

from .. import obs
from .router import BucketKey, PlanRouter, SolveRequest

__all__ = ["Server", "SolveResult"]

_REQUESTS = obs.registry().counter(
    "serve.requests", "requests accepted into the queue, per bucket and "
    "server (scope label)")
_BATCHES = obs.registry().counter(
    "serve.batches", "coalesced batches served, per bucket")
_BATCH_SIZE = obs.registry().counter(
    "serve.batch_size", "batches by exact coalesced size (labels: bucket, "
    "size) — a counter, not a histogram, so sizes stay exact")
_ERRORS = obs.registry().counter(
    "serve.errors", "requests failed through their futures, per bucket")
_QUEUE_WAIT_S = obs.registry().histogram(
    "serve.queue_wait_s", "submit -> batch-close wait, per request",
    unit="s")
_BATCH_BUILD_S = obs.registry().histogram(
    "serve.batch_build_s", "plan routing + per-request feed build, per "
    "batch", unit="s")
_DISPATCH_S = obs.registry().histogram(
    "serve.dispatch_s", "batched dispatch wall-clock, per batch (run_many "
    "syncs outputs to host, so this covers device time)", unit="s")
_E2E_S = obs.registry().histogram(
    "serve.e2e_latency_s", "submit -> result end-to-end latency, per "
    "request", unit="s")


@dataclasses.dataclass(frozen=True)
class SolveResult:
    """One request's answer: the program outputs (unbatched), the residual
    norm when the workload exposes a residual vector output, and how the
    request was served."""
    outputs: Dict[str, Any]
    residual: Optional[float]
    bucket: str
    batch_size: int
    latency_s: float


class Server:
    """Batched, cached, concurrent plan serving over a ``PlanRouter``."""

    #: bucket-scheduling policies: ``oldest`` serves the bucket whose head
    #: request has waited longest (latency-greedy, can starve a cold
    #: bucket under sustained hot-bucket load within one wait window);
    #: ``round_robin`` serves the least-recently-served non-empty bucket,
    #: so every bucket makes progress regardless of arrival rates.
    POLICIES = ("oldest", "round_robin")

    def __init__(self, router: Optional[PlanRouter] = None, *,
                 max_batch_size: int = 16, max_wait_us: float = 2000.0,
                 session=None, max_plans: int = 8, autostart: bool = True,
                 policy: str = "oldest"):
        if max_batch_size < 1:
            raise ValueError("max_batch_size must be >= 1")
        if max_wait_us < 0:
            raise ValueError("max_wait_us must be >= 0")
        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"have {self.POLICIES}")
        self.policy = policy
        self._last_served: Dict[BucketKey, int] = {}
        self._serve_seq = 0
        self.router = router if router is not None else \
            PlanRouter(session=session, max_plans=max_plans)
        self.max_batch_size = max_batch_size
        self.max_wait_us = float(max_wait_us)
        self._cv = threading.Condition()
        self._pending: Dict[BucketKey,
                            "deque[Tuple[SolveRequest, Future, float]]"] = {}
        self._closing = False
        # counters/histograms live on the obs registry under this server's
        # scope label; every bump happens while holding _cv, so stats()
        # (which snapshots under _cv) is a consistent point-in-time view
        self._scope = obs.next_scope("serve")
        self._in_flight: Dict[str, int] = {}
        self._exec_stats: Dict[str, Dict[str, int]] = {}
        self._worker = threading.Thread(target=self._loop, daemon=True,
                                        name="cello-serve-worker")
        self._started = False
        if autostart:
            self.start()

    # -- client surface -------------------------------------------------
    def start(self) -> "Server":
        """Start the worker (no-op when already running).  Construct with
        ``autostart=False`` + submit + ``start()`` to make coalescing
        deterministic — every queued request is visible before the first
        batch closes."""
        if not self._started:
            self._started = True
            self._worker.start()
        return self

    def submit(self, req: SolveRequest) -> "Future[SolveResult]":
        """Enqueue one request; resolve/raise through the future."""
        key = self.router.bucket(req)      # raises here, not on the worker
        fut: "Future[SolveResult]" = Future()
        with self._cv:
            if self._closing:
                raise RuntimeError("Server is closed")
            self._pending.setdefault(key, deque()).append(
                (req, fut, time.monotonic()))
            _REQUESTS.inc(bucket=key.label, scope=self._scope)
            self._cv.notify_all()
        return fut

    def solve(self, req: SolveRequest) -> SolveResult:
        """Submit and wait: the synchronous convenience."""
        if not self._started:
            raise RuntimeError("Server not started (autostart=False): "
                               "call start() first")
        return self.submit(req).result()

    def stats(self) -> Dict[str, Any]:
        """Merged router + queue + executable counters, per bucket.

        **One locked snapshot**: queue depths, the obs-registry counters,
        the router's counters, and the executable's counters are all read
        while holding the server's condition variable — every write to any
        of them also happens under it, so the numbers reconcile exactly:
        ``requests == queued + in_flight + errors + Σ size·count`` over
        ``batch_sizes``, at any instant.  Per-bucket ``latency`` /
        ``queue_wait`` are streaming-histogram summaries (p50/p90/p99
        within the documented ±5% relative error).
        """
        with self._cv:
            queued = {k.label: len(d) for k, d in self._pending.items() if d}
            in_flight = {lb: n for lb, n in self._in_flight.items() if n}
            exec_stats = {lb: dict(s) for lb, s in self._exec_stats.items()}
            snap = obs.snapshot(self._scope)
            rstats = self.router.stats()

        def cells(name: str):
            return snap.get(name, {}).get("cells", [])

        def per_bucket(name: str) -> Dict[str, Any]:
            return {c["labels"]["bucket"]: c["value"] for c in cells(name)}

        requests = {lb: int(v) for lb, v in
                    per_bucket("serve.requests").items()}
        batches = {lb: int(v) for lb, v in
                   per_bucket("serve.batches").items()}
        errors = {lb: int(v) for lb, v in
                  per_bucket("serve.errors").items()}
        hist: Dict[str, Dict[int, int]] = {}
        for c in cells("serve.batch_size"):
            lb = c["labels"]["bucket"]
            hist.setdefault(lb, {})[int(c["labels"]["size"])] = \
                int(c["value"])
        latency = per_bucket("serve.e2e_latency_s")
        queue_wait = per_bucket("serve.queue_wait_s")
        labels = sorted(set(requests) | set(rstats["buckets"]) | set(queued))
        buckets = {}
        for lb in labels:
            r = rstats["buckets"].get(lb, {})
            e = exec_stats.get(lb, {})
            buckets[lb] = {
                "requests": requests.get(lb, 0),
                "batches": batches.get(lb, 0),
                "batch_sizes": hist.get(lb, {}),
                "queued": queued.get(lb, 0),
                "in_flight": in_flight.get(lb, 0),
                "errors": errors.get(lb, 0),
                "cache_hits": r.get("cache_hits", 0),
                "cache_misses": r.get("cache_misses", 0),
                "dispatches": e.get("dispatches", 0),
                "traces": e.get("traces", 0),
                "latency": latency.get(lb),
                "queue_wait": queue_wait.get(lb),
            }
        return {
            "requests": sum(requests.values()),
            "batches": sum(batches.values()),
            "queue_depth": sum(queued.values()),
            "in_flight": sum(in_flight.values()),
            "errors": sum(errors.values()),
            "plans_cached": rstats["plans_cached"],
            "plan_evictions": rstats["evictions"],
            "buckets": buckets,
        }

    def close(self, *, flush: bool = True) -> None:
        """Stop accepting requests.  ``flush=True`` (default) serves
        everything already queued first; ``flush=False`` fails queued
        futures with ``RuntimeError``."""
        with self._cv:
            self._closing = True
            # a never-started server has no worker to flush the queue
            if not flush or not self._started:
                dropped = [item for d in self._pending.values()
                           for item in d]
                self._pending.clear()
                for _, fut, _ in dropped:
                    fut.set_exception(
                        RuntimeError("Server closed before this request "
                                     "was served"))
            self._cv.notify_all()
        if self._started:
            self._worker.join()
            self._started = False

    def __enter__(self) -> "Server":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close(flush=exc == (None, None, None))

    # -- the worker loop -------------------------------------------------
    def _loop(self) -> None:
        max_wait_s = self.max_wait_us * 1e-6
        while True:
            with self._cv:
                while not self._pending and not self._closing:
                    self._cv.wait()
                if not self._pending and self._closing:
                    return
                live = [k for k, d in self._pending.items() if d]
                if self.policy == "round_robin":
                    # least-recently-served non-empty bucket (never-served
                    # sorts first); ties break oldest-head-first so the
                    # first pass through fresh buckets is still fair
                    key = min(live, key=lambda k: (
                        self._last_served.get(k, -1),
                        self._pending[k][0][2]))
                else:
                    # serve the bucket whose head request waited longest
                    key = min(live, key=lambda k: self._pending[k][0][2])
                self._serve_seq += 1
                self._last_served[key] = self._serve_seq
                deadline = self._pending[key][0][2] + max_wait_s
                while (len(self._pending[key]) < self.max_batch_size
                       and not self._closing):
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cv.wait(timeout=remaining)
                d = self._pending[key]
                batch = [d.popleft()
                         for _ in range(min(self.max_batch_size, len(d)))]
                if not d:
                    del self._pending[key]
                # queued -> in_flight atomically with the pop, so stats()
                # never sees these requests in neither state
                lb = key.label
                self._in_flight[lb] = self._in_flight.get(lb, 0) \
                    + len(batch)
            self._serve_batch(key, batch, time.monotonic())

    def _serve_batch(self, key: BucketKey,
                     batch: List[Tuple[SolveRequest, Future, float]],
                     t_close: float) -> None:
        lb = key.label
        n = len(batch)
        with obs.span("serve.batch", bucket=lb, size=n):
            try:
                t0 = time.perf_counter()
                with obs.span("serve.batch_build", bucket=lb):
                    entry = self.router.plan_for(key)
                    per_request = [self.router.request_feeds(entry, req)
                                   for req, _, _ in batch]
                _BATCH_BUILD_S.observe(time.perf_counter() - t0,
                                       bucket=lb, scope=self._scope)
                t0 = time.perf_counter()
                with obs.span("serve.dispatch", bucket=lb, size=n):
                    # run_many returns host (numpy) outputs — already
                    # synced, so completion timestamps below are honest
                    outs = entry.bplan.run_many(per_request,
                                                entry.shared_feeds)
                _DISPATCH_S.observe(time.perf_counter() - t0,
                                    bucket=lb, scope=self._scope)
            except BaseException as e:  # noqa: BLE001 — futures carry it
                with self._cv:
                    self._in_flight[lb] = self._in_flight.get(lb, 0) - n
                    _ERRORS.inc(n, bucket=lb, scope=self._scope)
                for _, fut, _ in batch:
                    if not fut.done():
                        fut.set_exception(e)
                return
            done = time.monotonic()
            with self._cv:
                self._in_flight[lb] = self._in_flight.get(lb, 0) - n
                _BATCHES.inc(bucket=lb, scope=self._scope)
                _BATCH_SIZE.inc(bucket=lb, size=n, scope=self._scope)
                for _, _, t_submit in batch:
                    _QUEUE_WAIT_S.observe(t_close - t_submit,
                                          bucket=lb, scope=self._scope)
                    _E2E_S.observe(done - t_submit,
                                   bucket=lb, scope=self._scope)
                self._exec_stats[lb] = dict(entry.bplan.stats)
        rname = entry.residual_output
        for (req, fut, t_submit), out in zip(batch, outs):
            residual = None
            if rname is not None:
                import numpy as np
                residual = float(np.linalg.norm(np.asarray(out[rname])))
            fut.set_result(SolveResult(
                outputs=out, residual=residual, bucket=lb,
                batch_size=n, latency_s=done - t_submit))
