"""`Server`: an async request queue that coalesces solves into batches.

Callers :meth:`~Server.submit` :class:`~repro.serve.router.SolveRequest`\\ s
and get back ``concurrent.futures.Future``\\ s; one worker thread drains the
queue, grouping same-bucket requests into a single
:class:`~repro.serve.batched.BatchedPlan` dispatch.  Two knobs trade
latency for throughput:

* ``max_batch_size`` — a batch closes as soon as this many same-bucket
  requests are queued;
* ``max_wait_us`` — a batch also closes once its oldest request has waited
  this long, so a trickle of traffic is not stalled fishing for batchmates.

``policy`` picks which bucket the worker drains next: ``oldest`` (default,
longest-waiting head request) or ``round_robin`` (least-recently-served
non-empty bucket — no bucket starves under sustained hot-bucket load).

All JAX work happens on the one worker thread (routing, compiles and
dispatches never race each other); ``submit`` only canonicalizes the
bucket key — invalid requests raise in the caller, never poison the queue.
Execution errors propagate through each affected request's future.

**Failure handling** (see ``docs/serving.md`` / ``docs/robustness.md``):

* *Deadlines* — ``submit(req, deadline_s=...)``: the request expires
  in-queue with a typed :class:`~repro.serve.errors.DeadlineExceeded`
  once its deadline passes, and the worker closes a batch early rather
  than coalesce past a member's deadline.
* *Admission control* — ``max_queue`` bounds the queue; the ``overload``
  policy decides what happens at the bound: ``block`` (submit waits,
  still honouring its deadline), ``reject`` (submit raises a typed
  :class:`~repro.serve.errors.Overloaded`), or ``shed_oldest`` (the
  oldest queued request's future fails with ``Overloaded`` to admit the
  new one).
* *Graceful degradation* — a failed batch attempt is retried per
  ``retry`` (a :class:`~repro.serve.resilience.RetryPolicy`, executed
  through the shared ``run_with_restarts`` skeleton), and a bucket whose
  primary backend keeps failing is served by the ``fallback`` backend
  (default ``reference`` — the bitwise oracle, so degraded answers are
  *more* exact, just slower) behind a per-bucket
  :class:`~repro.serve.resilience.CircuitBreaker`.
* *Worker supervision* — a worker-thread crash fails exactly the
  in-flight batch's futures with a typed
  :class:`~repro.serve.errors.WorkerCrashed` and restarts the worker (up
  to ``max_worker_restarts``; after that the server is *down* and
  queued/new requests fail typed).  :meth:`Server.health` summarizes
  {ok, degraded, down} plus breaker states.

``stats()`` is the observability surface: per-bucket request/batch
counters, a batch-size histogram, queue-wait / end-to-end latency
quantiles, plan-cache hits/misses, the vmapped executable's
dispatch/trace counters, current queue depth, and every robustness
counter (rejected / shed / deadline_missed / retries / fallbacks /
breaker transitions / worker restarts).  The counters live on the
``repro.obs`` registry (under this server's unique scope label) and the
whole snapshot is taken while holding the server's condition variable,
so it is consistent: at any instant
``requests == queued + in_flight + errors + sum(size * count)`` over the
batch-size histogram (shed / expired / crashed / client-cancelled
requests count under ``errors``; rejected requests were never admitted
and are tallied separately).

A client may ``cancel()`` its future while the request is still queued;
the worker marks every future *running* when it pops the batch
(``set_running_or_notify_cancel``), so a won cancel simply drops the
request (counted under ``errors``) and a lost one can no longer race the
result — no settle site ever raises ``InvalidStateError`` into the
worker or an unrelated submitter.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import deque
from concurrent.futures import Future, InvalidStateError
from typing import Any, Dict, List, Optional

from .. import obs
from ..runtime.fault_tolerance import run_with_restarts
from ..testing import faults
from ..api.config import ServeConfig, UNSET as _UNSET, resolve_config
from .errors import (CircuitOpen, DeadlineExceeded, Overloaded, ServerClosed,
                     WorkerCrashed)
from .resilience import CircuitBreaker, RetryPolicy
from .router import BucketKey, PlanRouter, SolveRequest, request

__all__ = ["Server", "SolveResult"]

_REQUESTS = obs.registry().counter(
    "serve.requests", "requests accepted into the queue, per bucket and "
    "server (scope label)")
_BATCHES = obs.registry().counter(
    "serve.batches", "coalesced batches served, per bucket")
_BATCH_SIZE = obs.registry().counter(
    "serve.batch_size", "batches by exact coalesced size (labels: bucket, "
    "size) — a counter, not a histogram, so sizes stay exact")
_ERRORS = obs.registry().counter(
    "serve.errors", "requests failed through their futures, per bucket")
_QUEUE_WAIT_S = obs.registry().histogram(
    "serve.queue_wait_s", "submit -> batch-close wait, per request",
    unit="s")
_BATCH_BUILD_S = obs.registry().histogram(
    "serve.batch_build_s", "plan routing + per-request feed build, per "
    "batch", unit="s")
_DISPATCH_S = obs.registry().histogram(
    "serve.dispatch_s", "batched dispatch wall-clock, per batch (run_many "
    "syncs outputs to host, so this covers device time)", unit="s")
_E2E_S = obs.registry().histogram(
    "serve.e2e_latency_s", "submit -> result end-to-end latency, per "
    "request", unit="s")
_REJECTED = obs.registry().counter(
    "serve.rejected", "requests rejected at submit by the overload policy "
    "(never admitted — not part of serve.requests), per bucket")
_SHED = obs.registry().counter(
    "serve.shed", "admitted requests shed from the queue head by "
    "overload='shed_oldest', per bucket")
_EXPIRED = obs.registry().counter(
    "serve.deadline_missed", "requests expired by their deadline (in-queue "
    "or while blocked on admission), per bucket")
_RETRIES = obs.registry().counter(
    "serve.retries", "batch attempt retries (RetryPolicy), per bucket")
_FALLBACKS = obs.registry().counter(
    "serve.fallbacks", "requests served by the fallback backend, per "
    "bucket")
_WORKER_RESTARTS = obs.registry().counter(
    "serve.worker_restarts", "supervised worker-thread restarts, per "
    "server (scope label)")

#: a batch closes early this far before its tightest member deadline, so
#: the request is dispatched *before* it would expire (the margin is the
#: larger of 2 ms and 10% of the request's whole deadline window)
_DEADLINE_SAFETY_FRAC = 0.1
_DEADLINE_SAFETY_MIN_S = 0.002


@dataclasses.dataclass(frozen=True)
class SolveResult:
    """One request's answer: the program outputs (unbatched), the residual
    norm when the workload exposes a residual vector output, and how the
    request was served.  ``backend`` is the backend that actually served
    it; ``degraded`` is True when that was the fallback, not the
    requested backend."""
    outputs: Dict[str, Any]
    residual: Optional[float]
    bucket: str
    batch_size: int
    latency_s: float
    backend: str = ""
    degraded: bool = False


@dataclasses.dataclass
class _Item:
    """One queued request: the payload, its future, and its deadline
    (absolute ``time.monotonic`` seconds; ``inf`` = none)."""
    req: SolveRequest
    fut: "Future[SolveResult]"
    t_submit: float
    deadline: float = math.inf

    def close_by(self) -> float:
        """When the worker should stop coalescing on this item's account:
        safety-margin ahead of its deadline."""
        if self.deadline == math.inf:
            return math.inf
        margin = max(_DEADLINE_SAFETY_MIN_S,
                     _DEADLINE_SAFETY_FRAC * (self.deadline - self.t_submit))
        return max(self.t_submit, self.deadline - margin)


@dataclasses.dataclass
class _InFlightBatch:
    """The batch currently being served, tracked for crash supervision.
    ``accounted`` flips once ``_serve_batch`` has settled the in-flight /
    error counters, so the supervisor never double-counts."""
    key: BucketKey
    items: List[_Item]
    accounted: bool = False


class Server:
    """Batched, cached, concurrent plan serving over a ``PlanRouter``."""

    #: bucket-scheduling policies: ``oldest`` serves the bucket whose head
    #: request has waited longest (latency-greedy, can starve a cold
    #: bucket under sustained hot-bucket load within one wait window);
    #: ``round_robin`` serves the least-recently-served non-empty bucket,
    #: so every bucket makes progress regardless of arrival rates.
    POLICIES = ("oldest", "round_robin")

    #: what ``submit`` does when the queue holds ``max_queue`` requests
    OVERLOAD_POLICIES = ("block", "reject", "shed_oldest")

    def __init__(self, router: Optional[PlanRouter] = None,
                 config: Optional[ServeConfig] = None, *,
                 session=None,
                 max_batch_size=_UNSET, max_wait_us=_UNSET,
                 max_plans=_UNSET, autostart=_UNSET, policy=_UNSET,
                 max_queue=_UNSET, overload=_UNSET, retry=_UNSET,
                 fallback=_UNSET, breaker_failures=_UNSET,
                 breaker_reset_s=_UNSET, max_worker_restarts=_UNSET):
        # a config passed positionally lands in the router slot — shift it
        if isinstance(router, ServeConfig):
            if config is not None:
                raise TypeError("Server: got two configs (positional and "
                                "config=)")
            router, config = None, router
        # one ServeConfig carries every knob; the individual keywords are
        # the 0.9 spelling, kept one release behind a DeprecationWarning
        cfg = resolve_config(
            ServeConfig, config,
            dict(max_batch_size=max_batch_size, max_wait_us=max_wait_us,
                 max_plans=max_plans, autostart=autostart, policy=policy,
                 max_queue=max_queue, overload=overload, retry=retry,
                 fallback=fallback, breaker_failures=breaker_failures,
                 breaker_reset_s=breaker_reset_s,
                 max_worker_restarts=max_worker_restarts),
            "Server")
        max_batch_size = cfg.max_batch_size
        max_wait_us = cfg.max_wait_us
        max_plans = cfg.max_plans
        autostart = cfg.autostart
        policy = cfg.policy
        max_queue = cfg.max_queue
        overload = cfg.overload
        retry = cfg.retry
        fallback = cfg.fallback
        breaker_failures = cfg.breaker_failures
        breaker_reset_s = cfg.breaker_reset_s
        max_worker_restarts = cfg.max_worker_restarts
        if max_batch_size < 1:
            raise ValueError("max_batch_size must be >= 1")
        if max_wait_us < 0:
            raise ValueError("max_wait_us must be >= 0")
        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"have {self.POLICIES}")
        if overload not in self.OVERLOAD_POLICIES:
            raise ValueError(f"unknown overload policy {overload!r}; "
                             f"have {self.OVERLOAD_POLICIES}")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None: unbounded)")
        if breaker_failures is not None and breaker_failures < 1:
            raise ValueError("breaker_failures must be >= 1 (or None: "
                             "breaker disabled)")
        if max_worker_restarts < 0:
            raise ValueError("max_worker_restarts must be >= 0")
        self.policy = policy
        self._last_served: Dict[BucketKey, int] = {}
        self._serve_seq = 0
        self.router = router if router is not None else \
            PlanRouter(session=session, max_plans=max_plans)
        self.max_batch_size = max_batch_size
        self.max_wait_us = float(max_wait_us)
        self.max_queue = max_queue
        self.overload = overload
        self.retry = retry
        self.fallback = fallback
        self.breaker_failures = breaker_failures
        self.breaker_reset_s = float(breaker_reset_s)
        self.max_worker_restarts = max_worker_restarts
        self._cv = threading.Condition()
        self._pending: Dict[BucketKey, "deque[_Item]"] = {}
        self._closing = False
        self._down = False
        # counters/histograms live on the obs registry under this server's
        # scope label; every bump happens while holding _cv, so stats()
        # (which snapshots under _cv) is a consistent point-in-time view
        self._scope = obs.next_scope("serve")
        self._in_flight: Dict[str, int] = {}
        self._exec_stats: Dict[str, Dict[str, int]] = {}
        self._breakers: Dict[str, CircuitBreaker] = {}
        self._current: Optional[_InFlightBatch] = None
        self._worker: Optional[threading.Thread] = None
        self._worker_restarts = 0
        self._started = False
        if autostart:
            self.start()

    # -- client surface -------------------------------------------------
    def start(self) -> "Server":
        """Start the worker (no-op when already running).  Construct with
        ``autostart=False`` + submit + ``start()`` to make coalescing
        deterministic — every queued request is visible before the first
        batch closes."""
        if not self._started:
            self._started = True
            with self._cv:
                self._worker = threading.Thread(target=self._worker_main,
                                                daemon=True,
                                                name="cello-serve-worker")
            self._worker.start()
        return self

    def submit(self, req: SolveRequest, *,
               deadline_s: Optional[float] = None) -> "Future[SolveResult]":
        """Enqueue one request; resolve/raise through the future.

        ``deadline_s`` (relative, from now) bounds how long the request
        may wait for dispatch: expiry fails *only* this request's future
        with :class:`DeadlineExceeded`; omitted, it defaults to the
        request's own ``deadline_s`` field.  A full queue is handled by
        the server's ``overload`` policy — ``reject`` raises
        :class:`Overloaded` here, in the caller.

        Passing a dict instead of a :class:`SolveRequest` is deprecated
        since 0.10 (``docs/api_migration.md``).
        """
        if isinstance(req, dict):
            import warnings
            warnings.warn(
                "Server.submit(dict) is deprecated since 0.10 and will "
                "be removed in 0.11; pass a SolveRequest (see "
                "repro.serve.request and docs/api_migration.md)",
                DeprecationWarning, stacklevel=2)
            req = request(**req)
        if deadline_s is None:
            deadline_s = req.deadline_s
        key = self.router.bucket(req)      # raises here, not on the worker
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError("deadline_s must be > 0")
        fut: "Future[SolveResult]" = Future()
        t_submit = time.monotonic()
        deadline = (t_submit + deadline_s if deadline_s is not None
                    else math.inf)
        with self._cv:
            while True:
                if self._closing:
                    raise ServerClosed("Server is closed")
                if self._down:
                    raise ServerClosed("Server worker is down (restarts "
                                       "exhausted); server is closed to "
                                       "new work")
                if self.max_queue is None:
                    break
                depth = sum(len(d) for d in self._pending.values())
                if depth < self.max_queue:
                    break
                if self.overload == "reject":
                    _REJECTED.inc(bucket=key.label, scope=self._scope)
                    raise Overloaded(f"queue full ({depth}/"
                                     f"{self.max_queue}); request rejected")
                if self.overload == "shed_oldest":
                    self._shed_oldest_locked()
                    continue
                # "block": wait for space, still honouring the deadline
                now = time.monotonic()
                if now > deadline:
                    _EXPIRED.inc(bucket=key.label, scope=self._scope)
                    raise DeadlineExceeded("deadline exceeded while "
                                           "blocked on admission")
                self._cv.wait(timeout=None if deadline == math.inf
                              else deadline - now)
            self._pending.setdefault(key, deque()).append(
                _Item(req, fut, t_submit, deadline))
            _REQUESTS.inc(bucket=key.label, scope=self._scope)
            self._cv.notify_all()
        return fut

    def solve(self, req: SolveRequest, *,
              deadline_s: Optional[float] = None) -> SolveResult:
        """Submit and wait: the synchronous convenience."""
        if not self._started:
            raise RuntimeError("Server not started (autostart=False): "
                               "call start() first")
        return self.submit(req, deadline_s=deadline_s).result()

    def health(self) -> Dict[str, Any]:
        """Liveness summary: ``status`` is ``ok`` (serving, nothing
        degraded), ``degraded`` (serving, but a breaker is not closed,
        the worker has been restarted, or a supervised restart is in
        progress), or ``down`` (not serving: never started, closed, or
        restarts exhausted)."""
        with self._cv:
            worker = self._worker
            alive = bool(worker is not None and worker.is_alive())
            # a replacement registered by the supervisor but not yet
            # running (ident is None): the server is restarting, not dead
            restarting = bool(worker is not None and worker.ident is None)
            restarts = self._worker_restarts
            breakers = {lb: b.state for lb, b in self._breakers.items()}
            closing, down, started = self._closing, self._down, self._started
        if down or closing or not started or not (alive or restarting):
            status = "down"
        elif restarts > 0 or restarting \
                or any(s != CircuitBreaker.CLOSED
                       for s in breakers.values()):
            status = "degraded"
        else:
            status = "ok"
        return {"status": status, "worker_alive": alive,
                "worker_restarts": restarts,
                "max_worker_restarts": self.max_worker_restarts,
                "breakers": breakers, "closing": closing, "down": down}

    def stats(self) -> Dict[str, Any]:
        """Merged router + queue + executable + robustness counters.

        **One locked snapshot**: queue depths, the obs-registry counters,
        the router's counters, and the executable's counters are all read
        while holding the server's condition variable — every write to any
        of them also happens under it, so the numbers reconcile exactly:
        ``requests == queued + in_flight + errors + Σ size·count`` over
        ``batch_sizes``, at any instant (shed / expired / crashed
        requests are inside ``errors``; ``rejected`` were never
        admitted).  Per-bucket ``latency`` / ``queue_wait`` are
        streaming-histogram summaries (p50/p90/p99 within the documented
        ±5% relative error).
        """
        with self._cv:
            queued = {k.label: len(d) for k, d in self._pending.items() if d}
            in_flight = {lb: n for lb, n in self._in_flight.items() if n}
            exec_stats = {lb: dict(s) for lb, s in self._exec_stats.items()}
            breakers = {lb: b.stats() for lb, b in self._breakers.items()}
            worker_restarts = self._worker_restarts
            snap = obs.snapshot(self._scope)
            rstats = self.router.stats()

        def cells(name: str):
            return snap.get(name, {}).get("cells", [])

        def per_bucket(name: str) -> Dict[str, Any]:
            return {c["labels"]["bucket"]: c["value"] for c in cells(name)}

        def per_bucket_int(name: str) -> Dict[str, int]:
            return {lb: int(v) for lb, v in per_bucket(name).items()}

        requests = per_bucket_int("serve.requests")
        batches = per_bucket_int("serve.batches")
        errors = per_bucket_int("serve.errors")
        rejected = per_bucket_int("serve.rejected")
        shed = per_bucket_int("serve.shed")
        expired = per_bucket_int("serve.deadline_missed")
        retries = per_bucket_int("serve.retries")
        fallbacks = per_bucket_int("serve.fallbacks")
        hist: Dict[str, Dict[int, int]] = {}
        for c in cells("serve.batch_size"):
            lb = c["labels"]["bucket"]
            hist.setdefault(lb, {})[int(c["labels"]["size"])] = \
                int(c["value"])
        latency = per_bucket("serve.e2e_latency_s")
        queue_wait = per_bucket("serve.queue_wait_s")
        labels = sorted(set(requests) | set(rstats["buckets"]) | set(queued)
                        | set(rejected))
        buckets = {}
        for lb in labels:
            r = rstats["buckets"].get(lb, {})
            e = exec_stats.get(lb, {})
            b = breakers.get(lb)
            buckets[lb] = {
                "requests": requests.get(lb, 0),
                "batches": batches.get(lb, 0),
                "batch_sizes": hist.get(lb, {}),
                "queued": queued.get(lb, 0),
                "in_flight": in_flight.get(lb, 0),
                "errors": errors.get(lb, 0),
                "rejected": rejected.get(lb, 0),
                "shed": shed.get(lb, 0),
                "deadline_missed": expired.get(lb, 0),
                "retries": retries.get(lb, 0),
                "fallbacks": fallbacks.get(lb, 0),
                "breaker": b["state"] if b else None,
                "breaker_opens": b["opens"] if b else 0,
                "cache_hits": r.get("cache_hits", 0),
                "cache_misses": r.get("cache_misses", 0),
                "dispatches": e.get("dispatches", 0),
                "traces": e.get("traces", 0),
                "latency": latency.get(lb),
                "queue_wait": queue_wait.get(lb),
            }
        return {
            "requests": sum(requests.values()),
            "batches": sum(batches.values()),
            "queue_depth": sum(queued.values()),
            "in_flight": sum(in_flight.values()),
            "errors": sum(errors.values()),
            "rejected": sum(rejected.values()),
            "shed": sum(shed.values()),
            "deadline_missed": sum(expired.values()),
            "retries": sum(retries.values()),
            "fallbacks": sum(fallbacks.values()),
            "worker_restarts": worker_restarts,
            "plans_cached": rstats["plans_cached"],
            "plan_evictions": rstats["evictions"],
            "buckets": buckets,
        }

    def close(self, *, flush: bool = True) -> None:
        """Stop accepting requests.  ``flush=True`` (default) serves
        everything already queued first; ``flush=False`` fails queued
        futures with a typed :class:`ServerClosed`."""
        dropped: List[_Item] = []
        with self._cv:
            self._closing = True
            # a never-started (or down) server has no worker to flush
            if not flush or not self._started or self._down:
                for k, d in self._pending.items():
                    for it in d:
                        _ERRORS.inc(bucket=k.label, scope=self._scope)
                        dropped.append(it)
                self._pending.clear()
            self._cv.notify_all()
        for it in dropped:
            self._settle_error(it.fut, ServerClosed(
                "Server closed before this request was served"))
        # join the worker; the supervisor may have swapped in a restarted
        # thread, so re-read until the joined thread is still the current
        # one (restarts stop once _closing is set).  The ident-is-None
        # wait is bounded: a replacement that was registered but whose
        # start() never ran (supervisor crashed between the two) would
        # otherwise spin this loop forever
        ident_wait_until = time.monotonic() + 1.0
        while self._started:
            with self._cv:
                w = self._worker
            if w is None:
                self._started = False
            elif w.ident is None:      # restart swapped in, not yet running
                if time.monotonic() > ident_wait_until:
                    with self._cv:     # never started: nothing to join
                        if self._worker is w:
                            self._started = False
                else:
                    time.sleep(0.001)
            else:
                w.join()
                ident_wait_until = time.monotonic() + 1.0
                with self._cv:
                    if self._worker is w:
                        self._started = False

    def __enter__(self) -> "Server":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close(flush=exc == (None, None, None))

    # -- the worker loop -------------------------------------------------
    def _worker_main(self) -> None:
        try:
            self._loop()
        except BaseException as e:  # noqa: BLE001 — supervised
            self._on_worker_crash(e)

    def _loop(self) -> None:
        max_wait_s = self.max_wait_us * 1e-6
        while True:
            with self._cv:
                while True:
                    now = time.monotonic()
                    self._expire_locked(now)
                    if self._pending or self._closing:
                        break
                    self._cv.wait(timeout=self._expiry_timeout_locked(now))
                if not self._pending and self._closing:
                    return
                if not self._pending:      # everything just expired
                    continue
                live = [k for k, d in self._pending.items() if d]
                if self.policy == "round_robin":
                    # least-recently-served non-empty bucket (never-served
                    # sorts first); ties break oldest-head-first so the
                    # first pass through fresh buckets is still fair
                    key = min(live, key=lambda k: (
                        self._last_served.get(k, -1),
                        self._pending[k][0].t_submit))
                else:
                    # serve the bucket whose head request waited longest
                    key = min(live,
                              key=lambda k: self._pending[k][0].t_submit)
                self._serve_seq += 1
                self._last_served[key] = self._serve_seq
                # the max_wait window is anchored to the oldest request at
                # batch open, NOT the live head: if a deadline-bearing head
                # expires mid-wait the window must not restart, or requests
                # behind a chain of expiring heads wait >> max_wait
                anchor = self._pending[key][0].t_submit
                while (len(self._pending.get(key, ())) < self.max_batch_size
                       and not self._closing):
                    now = time.monotonic()
                    self._expire_locked(now)
                    d = self._pending.get(key)
                    if not d:
                        break
                    # close when the window anchor hits max_wait OR any
                    # member approaches its deadline (early, with margin,
                    # so it dispatches rather than expires)
                    close_at = min(anchor + max_wait_s,
                                   min(it.close_by() for it in d))
                    remaining = close_at - now
                    if remaining <= 0:
                        break
                    timeout = remaining
                    other = self._expiry_timeout_locked(now)
                    if other is not None:
                        timeout = min(timeout, other)
                    self._cv.wait(timeout=timeout)
                d = self._pending.get(key)
                if not d:
                    continue               # the whole bucket expired away
                batch = [d.popleft()
                         for _ in range(min(self.max_batch_size, len(d)))]
                if not d:
                    del self._pending[key]
                now = time.monotonic()
                lb = key.label
                kept = []
                for it in batch:
                    if now > it.deadline:
                        _EXPIRED.inc(bucket=lb, scope=self._scope)
                        _ERRORS.inc(bucket=lb, scope=self._scope)
                        self._settle_error(it.fut, DeadlineExceeded(
                            f"deadline exceeded after "
                            f"{now - it.t_submit:.3f}s in queue ({lb})"))
                    elif not it.fut.set_running_or_notify_cancel():
                        # client cancelled while queued: the cancel IS the
                        # settlement — drop the item, tally it as an error
                        _ERRORS.inc(bucket=lb, scope=self._scope)
                    else:
                        kept.append(it)
                batch = kept
                # queue space was freed: wake blocked submitters
                self._cv.notify_all()
                if not batch:
                    continue
                # queued -> in_flight atomically with the pop, so stats()
                # never sees these requests in neither state
                self._in_flight[lb] = self._in_flight.get(lb, 0) \
                    + len(batch)
                self._current = _InFlightBatch(key, batch)
            # crash-injection site: outside the lock, outside
            # _serve_batch's own error containment — exercises the
            # supervisor, not the per-batch error path
            faults.check("serve.worker", bucket=key.label)
            self._serve_batch(key, batch, time.monotonic())

    @staticmethod
    def _settle_error(fut: "Future[SolveResult]",
                      exc: BaseException) -> bool:
        """Deliver ``exc`` through ``fut`` unless the future already
        settled — a client ``cancel()`` may win at any moment while the
        future is still pending, and losing that race must never raise
        into the worker (or a submitter).  Returns True when delivered."""
        if fut.done():
            return False
        try:
            fut.set_exception(exc)
            return True
        except InvalidStateError:      # lost the race with a client cancel
            return False

    def _expire_locked(self, now: float) -> None:
        """Fail every queued request whose deadline has passed (strictly:
        ``now > deadline``) with a typed :class:`DeadlineExceeded`."""
        changed = False
        for key in list(self._pending):
            d = self._pending[key]
            if all(it.deadline >= now for it in d):
                continue
            keep: "deque[_Item]" = deque()
            lb = key.label
            for it in d:
                if now > it.deadline:
                    _EXPIRED.inc(bucket=lb, scope=self._scope)
                    _ERRORS.inc(bucket=lb, scope=self._scope)
                    self._settle_error(it.fut, DeadlineExceeded(
                        f"deadline exceeded after "
                        f"{now - it.t_submit:.3f}s in queue ({lb})"))
                    changed = True
                else:
                    keep.append(it)
            if keep:
                self._pending[key] = keep
            else:
                del self._pending[key]
        if changed:
            self._cv.notify_all()          # queue space freed

    def _expiry_timeout_locked(self, now: float) -> Optional[float]:
        """Seconds until the earliest queued deadline (None: no
        deadlines pending — wait indefinitely)."""
        nd = min((it.deadline for d in self._pending.values() for it in d),
                 default=math.inf)
        if nd == math.inf:
            return None
        return max(0.0, nd - now) + 1e-4

    def _shed_oldest_locked(self) -> None:
        """Fail the globally-oldest queued request with ``Overloaded`` to
        make room for a newer one (the shed_oldest admission policy)."""
        key = min((k for k, d in self._pending.items() if d),
                  key=lambda k: self._pending[k][0].t_submit)
        it = self._pending[key].popleft()
        if not self._pending[key]:
            del self._pending[key]
        lb = key.label
        _SHED.inc(bucket=lb, scope=self._scope)
        _ERRORS.inc(bucket=lb, scope=self._scope)
        # guarded: a concurrent client cancel() on the shed future must
        # not raise into this (unrelated) submitter's thread
        self._settle_error(it.fut, Overloaded(
            f"shed from the queue head ({lb}) to admit a newer request"))

    # -- supervision -----------------------------------------------------
    def _on_worker_crash(self, exc: BaseException) -> None:
        """The worker thread died outside the per-batch error path: fail
        exactly the in-flight futures, then restart (bounded) or mark the
        server down and fail everything queued."""
        failed: List[Any] = []
        restart: Optional[threading.Thread] = None
        with self._cv:
            self._worker_restarts += 1
            _WORKER_RESTARTS.inc(scope=self._scope)
            cur = self._current
            self._current = None
            if cur is not None:
                lb = cur.key.label
                undone = [it for it in cur.items if not it.fut.done()]
                if not cur.accounted:
                    self._in_flight[lb] = \
                        self._in_flight.get(lb, 0) - len(cur.items)
                    # once accounted, _serve_batch already tallied the
                    # batch (batch_sizes or errors) — bumping errors again
                    # here would double-count and break the stats invariant
                    if undone:
                        _ERRORS.inc(len(undone), bucket=lb,
                                    scope=self._scope)
                err = WorkerCrashed(
                    f"serve worker crashed mid-batch ({lb}): {exc!r}")
                err.__cause__ = exc
                failed += [(it.fut, err) for it in undone]
            if (not self._closing
                    and self._worker_restarts <= self.max_worker_restarts):
                restart = threading.Thread(target=self._worker_main,
                                           daemon=True,
                                           name="cello-serve-worker")
                self._worker = restart
            else:
                self._down = True
                drop_err = WorkerCrashed(
                    "serve worker is down (restarts exhausted); queued "
                    "request dropped un-served")
                drop_err.__cause__ = exc
                for k, d in self._pending.items():
                    for it in d:
                        _ERRORS.inc(bucket=k.label, scope=self._scope)
                        failed.append((it.fut, drop_err))
                self._pending.clear()
            self._cv.notify_all()
        # settle, then start the replacement under try/finally: if a
        # settle raised, a registered-but-never-started replacement would
        # wedge close() and leave the server silently dead
        try:
            for fut, e in failed:
                self._settle_error(fut, e)
        finally:
            if restart is not None:
                restart.start()

    # -- batch execution -------------------------------------------------
    def _breaker_for(self, lb: str) -> Optional[CircuitBreaker]:
        if not self.breaker_failures:
            return None
        with self._cv:
            b = self._breakers.get(lb)
            if b is None:
                b = CircuitBreaker(self.breaker_failures,
                                   self.breaker_reset_s,
                                   name=lb, scope=self._scope)
                self._breakers[lb] = b
            return b

    def _attempt(self, key: BucketKey, batch: List[_Item], lb: str):
        """One attempt at serving ``batch`` with ``key``'s plan (which
        may be the fallback variant — stats stay under the primary
        bucket's label ``lb``).

        float64 buckets build *and* dispatch under jax's thread-local
        x64 mode: without it jnp silently downcasts to float32, so the
        bucket's advertised dtype would be a lie.  The context is
        scoped to this worker call — fp32 and fp64 buckets coexist on
        one server (jit caches key on operand dtypes, so neither mode
        poisons the other's compiled plans).
        """
        import contextlib
        if key.dtype == "float64":
            import jax
            x64 = jax.experimental.enable_x64()
        else:
            x64 = contextlib.nullcontext()
        with x64:
            return self._attempt_inner(key, batch, lb)

    def _attempt_inner(self, key: BucketKey, batch: List[_Item], lb: str):
        t0 = time.perf_counter()
        with obs.span("serve.batch_build", bucket=lb):
            entry = self.router.plan_for(key)
            per_request = [self.router.request_feeds(entry, it.req)
                           for it in batch]
        _BATCH_BUILD_S.observe(time.perf_counter() - t0,
                               bucket=lb, scope=self._scope)
        t0 = time.perf_counter()
        with obs.span("serve.dispatch", bucket=lb, size=len(batch)):
            # run_many returns host (numpy) outputs — already synced, so
            # completion timestamps below are honest
            outs = entry.bplan.run_many(per_request, entry.shared_feeds)
        _DISPATCH_S.observe(time.perf_counter() - t0,
                            bucket=lb, scope=self._scope)
        return entry, outs

    def _attempt_with_retries(self, key: BucketKey, batch: List[_Item],
                              lb: str):
        """Run ``_attempt`` under the server's RetryPolicy, through the
        shared ``run_with_restarts`` skeleton.  Returns ``(entry, outs)``;
        re-raises once retries are exhausted (each retry bumps
        ``serve.retries``)."""
        policy = self.retry
        if policy is None or policy.max_retries == 0:
            return self._attempt(key, batch, lb)
        result: Dict[str, Any] = {}
        state = {"retries": 0}

        def step(_step: int) -> None:
            result["v"] = self._attempt(key, batch, lb)

        def restore(failed_step: int) -> int:
            state["retries"] += 1
            # counted here (not after the fact) so exhausted-retry
            # failures still show up in stats()
            _RETRIES.inc(bucket=lb, scope=self._scope)
            time.sleep(policy.delay_s(state["retries"]))
            return failed_step

        run_with_restarts(step, restore, 1,
                          max_restarts=policy.max_retries,
                          failure_types=(Exception,))
        return result["v"]

    def _serve_batch(self, key: BucketKey, batch: List[_Item],
                     t_close: float) -> None:
        lb = key.label
        n = len(batch)
        fell_back = False
        entry = outs = None
        primary_exc: Optional[BaseException] = None
        with obs.span("serve.batch", bucket=lb, size=n):
            breaker = self._breaker_for(lb)
            if breaker is None or breaker.allow():
                try:
                    entry, outs = self._attempt_with_retries(key, batch, lb)
                    if breaker is not None:
                        breaker.record_success()
                except BaseException as e:  # noqa: BLE001 — futures carry
                    primary_exc = e
                    if breaker is not None:
                        breaker.record_failure()
            if outs is None and self.fallback \
                    and key.backend != self.fallback:
                fb_key = dataclasses.replace(key, backend=self.fallback)
                try:
                    with obs.span("serve.fallback", bucket=lb,
                                  backend=self.fallback):
                        entry, outs = self._attempt(fb_key, batch, lb)
                    fell_back = True
                except BaseException as e:  # noqa: BLE001
                    if primary_exc is None:
                        primary_exc = e
            if outs is None:
                if primary_exc is None:
                    # breaker open, primary skipped, no usable fallback
                    primary_exc = CircuitOpen(
                        f"circuit breaker open for bucket {lb} and no "
                        "usable fallback backend")
                with self._cv:
                    self._in_flight[lb] = self._in_flight.get(lb, 0) - n
                    _ERRORS.inc(n, bucket=lb, scope=self._scope)
                    if self._current is not None:
                        self._current.accounted = True
                for it in batch:
                    self._settle_error(it.fut, primary_exc)
                with self._cv:
                    self._current = None
                return
            done = time.monotonic()
            with self._cv:
                self._in_flight[lb] = self._in_flight.get(lb, 0) - n
                _BATCHES.inc(bucket=lb, scope=self._scope)
                _BATCH_SIZE.inc(bucket=lb, size=n, scope=self._scope)
                if fell_back:
                    _FALLBACKS.inc(n, bucket=lb, scope=self._scope)
                for it in batch:
                    _QUEUE_WAIT_S.observe(t_close - it.t_submit,
                                          bucket=lb, scope=self._scope)
                    _E2E_S.observe(done - it.t_submit,
                                   bucket=lb, scope=self._scope)
                self._exec_stats[lb] = dict(entry.bplan.stats)
                if self._current is not None:
                    self._current.accounted = True
        rname = entry.residual_output
        backend = entry.key.backend
        for it, out in zip(batch, outs):
            residual = None
            if rname is not None:
                import numpy as np
                residual = float(np.linalg.norm(np.asarray(out[rname])))
            try:
                it.fut.set_result(SolveResult(
                    outputs=out, residual=residual, bucket=lb,
                    batch_size=n, latency_s=done - it.t_submit,
                    backend=backend, degraded=fell_back))
            except InvalidStateError:  # pragma: no cover — running futures
                pass                   # cannot be cancelled; defensive only
        with self._cv:
            self._current = None
