"""Solver-as-a-service: batched, cached, concurrent plan serving.

The layers, bottom-up (``docs/serving.md`` for the full architecture):

* :class:`BatchedPlan` — ``jax.vmap`` a plan's single-program executable
  over a leading batch axis (operator leaves shared, input leaves
  batched): one device dispatch answers a whole batch.
* :class:`PlanRouter` — requests carry ``(workload, params, dtype,
  density bucket, backend)``; the router canonicalizes that to a
  :class:`BucketKey` and keeps a bounded LRU of compiled ``BatchedPlan``\\ s
  over the codesign disk cache, so the hot path is zero search / zero
  trace / zero compile.
* :class:`Server` — an async request queue whose worker loop coalesces
  same-bucket requests into one batch (``max_batch_size`` /
  ``max_wait_us`` knobs) and resolves per-request futures with outputs
  and residuals; ``Server.stats()`` surfaces per-bucket counters.

On top of that sits the failure-handling layer (``docs/serving.md``
"Failure handling" + ``docs/robustness.md``): per-request deadlines,
bounded-queue admission control (:class:`Overloaded`), retry + backend
fallback behind per-bucket circuit breakers (:class:`CircuitBreaker`),
and worker supervision with ``Server.health()``.

Quickstart::

    from repro.serve import Server, request

    with Server(max_batch_size=16) as srv:
        futs = [srv.submit(request("cg", n=256, iters=4, seed=s))
                for s in range(32)]
        results = [f.result() for f in futs]
    print(results[0].residual, results[0].batch_size)

Robustness quickstart::

    from repro.serve import Overloaded, RetryPolicy, Server, request

    srv = Server(max_queue=64, overload="reject",
                 retry=RetryPolicy(max_retries=2), fallback="reference",
                 breaker_failures=3)
    try:
        res = srv.solve(request("cg", n=256, backend="pallas"),
                        deadline_s=0.5)
    except Overloaded:
        ...                       # typed, raised in the caller, no hang
    print(srv.health()["status"], srv.stats()["fallbacks"])
"""
from ..api.config import ServeConfig
from .batched import BatchedPlan
from .errors import (CircuitOpen, DeadlineExceeded, Overloaded, ServeError,
                     ServerClosed, WorkerCrashed)
from .resilience import CircuitBreaker, RetryPolicy
from .router import (BucketKey, PlanRouter, SolveRequest, density_bucket,
                     request)
from .server import Server, SolveResult

__all__ = ["BatchedPlan", "BucketKey", "CircuitBreaker", "CircuitOpen",
           "DeadlineExceeded", "Overloaded", "PlanRouter", "RetryPolicy",
           "ServeConfig", "ServeError", "Server", "ServerClosed",
           "SolveRequest",
           "SolveResult", "WorkerCrashed", "density_bucket", "request"]
