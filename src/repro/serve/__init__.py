"""Solver-as-a-service: batched, cached, concurrent plan serving.

The layers, bottom-up (``docs/serving.md`` for the full architecture):

* :class:`BatchedPlan` — ``jax.vmap`` a plan's single-program executable
  over a leading batch axis (operator leaves shared, input leaves
  batched): one device dispatch answers a whole batch.
* :class:`PlanRouter` — requests carry ``(workload, params, dtype,
  density bucket, backend)``; the router canonicalizes that to a
  :class:`BucketKey` and keeps a bounded LRU of compiled ``BatchedPlan``\\ s
  over the codesign disk cache, so the hot path is zero search / zero
  trace / zero compile.
* :class:`Server` — an async request queue whose worker loop coalesces
  same-bucket requests into one batch (``max_batch_size`` /
  ``max_wait_us`` knobs) and resolves per-request futures with outputs
  and residuals; ``Server.stats()`` surfaces per-bucket counters.

Quickstart::

    from repro.serve import Server, request

    with Server(max_batch_size=16) as srv:
        futs = [srv.submit(request("cg", n=256, iters=4, seed=s))
                for s in range(32)]
        results = [f.result() for f in futs]
    print(results[0].residual, results[0].batch_size)
"""
from .batched import BatchedPlan
from .router import (BucketKey, PlanRouter, SolveRequest, density_bucket,
                     request)
from .server import Server, SolveResult

__all__ = ["BatchedPlan", "BucketKey", "PlanRouter", "Server",
           "SolveRequest", "SolveResult", "density_bucket", "request"]
