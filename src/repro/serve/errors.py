"""Typed failures for the serving stack.

Every way a submitted request can fail *without* its solve raising is a
distinct exception type, so callers can branch on failure mode instead
of string-matching messages.  All of them subclass :class:`ServeError`
(itself a ``RuntimeError``, which keeps pre-typed callers that caught
``RuntimeError`` working).
"""
from __future__ import annotations

__all__ = ["ServeError", "DeadlineExceeded", "Overloaded", "ServerClosed",
           "WorkerCrashed", "CircuitOpen"]


class ServeError(RuntimeError):
    """Base class for serving-layer failures."""


class DeadlineExceeded(ServeError):
    """The request's deadline passed while it waited in the queue (or
    while its submit was blocked on admission)."""


class Overloaded(ServeError):
    """The bounded queue was full: the request was rejected at submit
    (``overload="reject"``) or shed from the queue head to admit a newer
    one (``overload="shed_oldest"``)."""


class ServerClosed(ServeError):
    """The server is closed (or its worker is down): the request was not
    accepted, or was dropped un-served during a non-flushing close."""


class WorkerCrashed(ServeError):
    """The worker thread crashed while this request's batch was in
    flight; the request was not served."""


class CircuitOpen(ServeError):
    """The bucket's circuit breaker is open and no fallback backend
    could serve the batch."""
