"""Request → bucket key → resident `BatchedPlan`: the serving hot path.

Requests name a problem *family*, not a plan: ``(workload, params, dtype,
density bucket, backend)``.  The router canonicalizes that into a
:class:`BucketKey` — workload params resolved against the builder's
defaults (so ``cg_sparse(n=256)`` and ``cg_sparse(n=256,
pattern="laplacian5")`` share a bucket) and sparse ``density`` snapped to a
decade bucket (:func:`density_bucket`), the heterogeneity-aware routing
move: requests with nearby densities share one co-designed plan variant
instead of fragmenting the cache per exact nnz count.

A bounded LRU of compiled :class:`~repro.serve.batched.BatchedPlan`\\ s sits
on top of the existing codesign *disk* cache: a hot bucket costs one dict
lookup (zero search, zero trace, zero compile); a cold bucket pays trace →
codesign (disk-cached across processes) → lower → vmap once, then stays
resident until evicted.  All router state is guarded by one lock — worker
threads and callers can route concurrently.
"""
from __future__ import annotations

import dataclasses
import inspect
import math
import threading
from collections import OrderedDict
from typing import Any, Dict, Mapping, Optional, Tuple

import numpy as np

from .. import obs
from .batched import BatchedPlan

__all__ = ["SolveRequest", "request", "BucketKey", "density_bucket",
           "PlanRouter"]

_PLAN_HITS = obs.registry().counter(
    "serve.plan_cache.hits", "resident BatchedPlan LRU hits, per bucket "
    "and router (scope label)")
_PLAN_MISSES = obs.registry().counter(
    "serve.plan_cache.misses", "resident-plan LRU misses (a cold bucket "
    "pays trace -> codesign -> lower -> vmap)")
_PLAN_EVICTIONS = obs.registry().counter(
    "serve.plan_cache.evictions", "resident plans evicted by the LRU bound")
_PLANS_RESIDENT = obs.registry().gauge(
    "serve.plans_resident", "currently resident compiled plans")


def density_bucket(density: float) -> float:
    """Snap a sparse density to its decade bucket: ``10 ** round(log10)``.

    ``0.0008``–``0.003`` (roughly) all route to ``1e-3``: one plan serves
    the decade, and the bucket's canonical density sizes its operand.
    """
    density = float(density)
    if not 0.0 < density <= 1.0:
        raise ValueError(f"density must be in (0, 1], got {density}")
    return min(1.0, 10.0 ** round(math.log10(density)))


@dataclasses.dataclass(frozen=True)
class BucketKey:
    """Canonical identity of one servable plan variant."""
    workload: str
    params: Tuple[Tuple[str, Any], ...]    # canonicalized, sorted
    dtype: str                             # numpy name: "float32"
    density: str          # "dense" | "d0.001" | "laplacian5" | "banded/b64"
    backend: str

    @property
    def label(self) -> str:
        """Compact stable string — the per-bucket stats key."""
        params = ", ".join(f"{k}={v}" for k, v in self.params
                           if v is not None)
        return (f"{self.workload}({params})/{self.dtype}"
                f"/{self.density}/{self.backend}")


@dataclasses.dataclass(frozen=True)
class SolveRequest:
    """One user request: a problem family plus per-request inputs.

    ``seed`` generates deterministic input-leaf feeds; ``feeds`` overlays
    explicit values for (a subset of) the input leaves — the operator is
    always the bucket's shared one, that is the point of bucketing.
    """
    workload: str
    params: Tuple[Tuple[str, Any], ...] = ()
    dtype: str = "float32"
    backend: str = "reference"
    seed: int = 0
    feeds: Optional[Mapping[str, Any]] = dataclasses.field(
        default=None, compare=False)
    # per-request serving deadline (seconds from submit); None = the
    # server's default.  Serving metadata, not bucket identity.
    deadline_s: Optional[float] = dataclasses.field(
        default=None, compare=False)

    def bucket(self) -> BucketKey:
        """Canonical bucket key for this request (raises early on
        unknown workloads/params — before anything is queued)."""
        from ..frontends.hpc import WORKLOADS
        if self.workload not in WORKLOADS:
            raise KeyError(f"unknown HPC workload {self.workload!r}; "
                           f"have {sorted(WORKLOADS)}")
        sig = inspect.signature(WORKLOADS[self.workload])
        try:
            bound = sig.bind(**dict(self.params))
        except TypeError as e:
            raise TypeError(f"workload {self.workload!r}: {e}") from None
        bound.apply_defaults()
        params = dict(bound.arguments)
        density = params.get("density")
        if density is not None:
            bucketed = density_bucket(density)
            params["density"] = bucketed
            dlabel = f"d{bucketed:g}"
        elif "pattern" in params:
            dlabel = str(params["pattern"])
            if params.get("bandwidth") is not None:
                dlabel += f"/b{params['bandwidth']}"
        else:
            dlabel = "dense"
        dt = np.dtype(self.dtype)
        if dt.kind != "f":
            raise ValueError(f"request dtype must be a float dtype, "
                             f"got {self.dtype}")
        return BucketKey(workload=self.workload,
                         params=tuple(sorted(params.items())),
                         dtype=dt.name, density=dlabel,
                         backend=self.backend)


def request(workload: str, *, dtype: str = "float32",
            backend: str = "reference", seed: int = 0,
            feeds: Optional[Mapping[str, Any]] = None,
            deadline_s: Optional[float] = None,
            **params) -> SolveRequest:
    """Build a :class:`SolveRequest`; workload params go as kwargs::

        request("cg", n=256, iters=4, seed=7)
        request("cg_sparse", n=256, density=1e-3, dtype="float64")
    """
    dt = np.dtype(dtype)
    if dt.kind != "f":
        raise ValueError(f"request dtype must be a float dtype, got {dtype}")
    return SolveRequest(workload=workload,
                        params=tuple(sorted(params.items())),
                        dtype=dt.name, backend=backend, seed=seed,
                        feeds=feeds, deadline_s=deadline_s)


class _PlanEntry:
    """One resident bucket: the vmapped plan + its shared operator feeds."""

    def __init__(self, key: BucketKey, bplan: BatchedPlan, np_dtype):
        self.key = key
        self.bplan = bplan
        self.np_dtype = np_dtype
        self.program = bplan.program
        from ..frontends.reference import make_feeds
        # the bucket's operator is fixed (seed 0): every request in the
        # bucket solves against the same shared operand — generated once
        self.shared_feeds = make_feeds(self.program, seed=0, dtype=np_dtype,
                                       only=bplan.shared_leaves)
        self.residual_output = _residual_output(self.program)


def _residual_output(program) -> Optional[str]:
    """The latest residual-vector output (``r<k>``), if the workload
    exposes one — Krylov workloads output ``(x{k}, r{k})``."""
    import re
    cands = [(int(m.group(1)), o) for o in program.outputs
             for m in [re.fullmatch(r"r(\d+)", o)] if m is not None]
    return max(cands)[1] if cands else None


class PlanRouter:
    """Bounded LRU of compiled ``BatchedPlan``s, keyed by bucket."""

    def __init__(self, session=None, *, max_plans: int = 8):
        if session is None:
            from ..api.session import Session
            session = Session()
        if max_plans < 1:
            raise ValueError("max_plans must be >= 1")
        self.session = session
        self.max_plans = max_plans
        self._lru: "OrderedDict[BucketKey, _PlanEntry]" = OrderedDict()
        self._lock = threading.RLock()
        # hit/miss/eviction counters live on the obs registry under this
        # router's unique scope label; stats() reads them back
        self._scope = obs.next_scope("router")

    @property
    def evictions(self) -> int:
        return int(_PLAN_EVICTIONS.value(scope=self._scope))

    # -- canonicalization ----------------------------------------------
    def bucket(self, req: SolveRequest) -> BucketKey:
        """Canonical bucket key for a request — delegates to
        :meth:`SolveRequest.bucket` (kept as a router method so callers
        holding only a router keep working)."""
        return req.bucket()

    # -- the cache ------------------------------------------------------
    def plan_for(self, key: BucketKey) -> _PlanEntry:
        """The bucket's resident entry — compiled on first use, then LRU.

        The lock spans lookup+build+insert: two threads racing a cold
        bucket build it once (compiles serialize — the codesign disk
        cache and ``Session.trace`` memo make the loser's path cheap
        anyway).
        """
        with self._lock:
            entry = self._lru.get(key)
            if entry is not None:
                self._lru.move_to_end(key)
                _PLAN_HITS.inc(bucket=key.label, scope=self._scope)
                return entry
            _PLAN_MISSES.inc(bucket=key.label, scope=self._scope)
            with obs.span("serve.plan_build", bucket=key.label):
                entry = self._build(key)
            self._lru[key] = entry
            while len(self._lru) > self.max_plans:
                self._lru.popitem(last=False)
                _PLAN_EVICTIONS.inc(scope=self._scope)
            _PLANS_RESIDENT.set(len(self._lru), scope=self._scope)
            return entry

    def _build(self, key: BucketKey) -> _PlanEntry:
        traced = self.session.trace(workload=key.workload,
                                    **dict(key.params))
        plan = traced.codesign().lower(backend=key.backend)
        return _PlanEntry(key, BatchedPlan(plan), np.dtype(key.dtype))

    def request_feeds(self, entry: _PlanEntry,
                      req: SolveRequest) -> Dict[str, Any]:
        """Per-request values for the batched (input) leaves only:
        deterministic from ``req.seed``, overlaid with ``req.feeds``."""
        from ..frontends.reference import make_feeds
        feeds = make_feeds(entry.program, seed=req.seed,
                           dtype=entry.np_dtype,
                           only=entry.bplan.batched_leaves)
        if req.feeds:
            batched = set(entry.bplan.batched_leaves)
            for name, val in req.feeds.items():
                if name not in batched:
                    raise KeyError(
                        f"request feeds may only set input leaves "
                        f"{sorted(batched)}; {name!r} is "
                        + ("the bucket's shared operator"
                           if name in entry.bplan.shared_leaves
                           else "not a leaf"))
                want = entry.program.nodes[name].shape
                val = np.asarray(val)
                if val.shape != tuple(want):
                    raise ValueError(f"feed {name!r}: expected shape "
                                     f"{tuple(want)}, got {val.shape}")
                if val.dtype.kind == "f":
                    val = val.astype(entry.np_dtype, copy=False)
                feeds[name] = val
        return feeds

    def stats(self) -> Dict[str, Any]:
        # one consistent read: the LRU size and the registry snapshot are
        # taken under the router lock (every counter bump happens under it
        # too, so no hit/miss can land between the two reads)
        with self._lock:
            plans_cached = len(self._lru)
            snap = obs.snapshot(self._scope)

        def per_bucket(name: str) -> Dict[str, int]:
            return {c["labels"]["bucket"]: int(c["value"])
                    for c in snap.get(name, {}).get("cells", [])}

        hits = per_bucket("serve.plan_cache.hits")
        misses = per_bucket("serve.plan_cache.misses")
        evictions = sum(
            int(c["value"]) for c in
            snap.get("serve.plan_cache.evictions", {}).get("cells", []))
        labels = sorted(set(hits) | set(misses))
        return {
            "plans_cached": plans_cached,
            "max_plans": self.max_plans,
            "evictions": evictions,
            "buckets": {lb: {"cache_hits": hits.get(lb, 0),
                             "cache_misses": misses.get(lb, 0)}
                        for lb in labels},
        }
