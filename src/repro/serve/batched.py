"""`BatchedPlan`: one device dispatch answers a whole batch of requests.

A compiled plan solves one problem instance per ``run()``.  Serving wants
the opposite shape: many user requests against the *same* operator (the
expensive, co-designed part) with different right-hand sides / starting
points (the cheap, per-request part).  ``BatchedPlan`` vmaps the backend's
pure single-program callable (:meth:`repro.exec.base.Executor.compile_pure`)
over a leading batch axis:

* **operator leaves are shared** — ``in_axes=None``: the dense ``A`` (or a
  CSR operand's indptr/indices/data sub-leaves) is passed once, unbatched,
  and every lane of the vmap reads the same buffers;
* **input leaves are batched** — ``in_axes=0``: each request contributes
  one row of ``b``, ``x0``, ... stacked on a new leading axis.

The vmapped callable is wrapped in one ``jax.jit``, so a ``run_batch()`` is
exactly one device dispatch regardless of batch size — the serving-layer
image of the PR-4 single-program guarantee, and ``stats`` mirrors its
counters: ``dispatches`` counts ``run_batch`` calls, ``traces`` counts jit
retraces (one per distinct (batch size, dtype); batch sizes are not padded
to a bucket — the server's coalescing loop keeps the set of sizes small).

Numerics: under the ``reference`` backend the vmapped solve matches the
*jitted* single-request path (:meth:`run_one`) bitwise for gather/segment
workloads (``cg_sparse``); dense matvecs lower to a batched contraction
whose summation order may differ in the last ulps — see
``docs/serving.md`` for the measured tolerance policy.  Pallas plans match
within the tolerances already documented in ``docs/execution_backends.md``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional, Sequence

from .. import obs
from ..exec import get_backend
from ..exec.base import plan_program
from ..testing import faults

__all__ = ["BatchedPlan"]

_BP_TRACES = obs.registry().counter(
    "serve.batch_traces", "BatchedPlan jit retraces (one per distinct "
    "(batch size, dtype)), per plan (scope label)")
_BP_DISPATCHES = obs.registry().counter(
    "serve.batch_dispatches", "BatchedPlan coalesced-batch device "
    "dispatches, per plan (scope label)")


class BatchedPlan:
    """vmap a plan's single-program executable over a request batch.

    ``feeds`` for :meth:`run_batch` carry every leaf of the program:
    operator leaves at their traced shape (shared across the batch), input
    leaves with one extra leading batch axis.  :meth:`run_many` stacks
    per-request feed dicts for you.
    """

    def __init__(self, plan, *, backend: Optional[str] = None,
                 donate: Optional[bool] = None):
        program = plan_program(plan)
        self.plan = plan
        self.program = program
        executor = get_backend(backend or plan.backend)
        self.backend = executor.name
        leaves = program.leaves()
        self.shared_leaves = [nd.name for nd in leaves
                              if nd.op == "operator"]
        self.batched_leaves = [nd.name for nd in leaves
                               if nd.op != "operator"]
        if not self.batched_leaves:
            raise ValueError(f"{program.name!r} has no per-request (input) "
                             "leaves to batch over")
        self._single = executor.compile_pure(plan)
        if donate is None:
            from ..exec.pallas import use_donation
            donate = use_donation()
        self.donate = bool(donate)
        # counters live on the obs registry under this plan's unique scope
        # label; ``stats`` reads them back as the familiar dict
        self._scope = obs.next_scope("batched")
        self._jit = None        # built lazily: importing jax is deferred
        self._jit_one = None

    @property
    def stats(self) -> Dict[str, int]:
        """This plan's counters off the obs registry (dict-comparable)."""
        return {
            "traces": int(_BP_TRACES.value(backend=self.backend,
                                           scope=self._scope)),
            "dispatches": int(_BP_DISPATCHES.value(backend=self.backend,
                                                   scope=self._scope)),
        }

    # -- construction of the jitted executables -------------------------
    def _one(self, shared_vals, batched_vals):
        _BP_TRACES.inc(backend=self.backend, scope=self._scope)
        feeds = dict(zip(self.shared_leaves, shared_vals))
        feeds.update(zip(self.batched_leaves, batched_vals))
        return dict(self._single(feeds))

    def _build(self):
        import jax
        vmapped = jax.vmap(self._one, in_axes=(None, 0))
        kwargs = {"donate_argnums": (1,)} if self.donate else {}
        return jax.jit(vmapped, **kwargs)

    # -- execution -------------------------------------------------------
    def run_batch(self, feeds: Mapping[str, Any]) -> Dict[str, Any]:
        """One dispatch over a stacked batch: ``{output: (B, ...) array}``.

        Shared (operator) leaves must come at their traced shape; batched
        (input) leaves with a consistent leading batch axis prepended.
        When donation is on, batched feeds that are caller-owned
        ``jax.Array``\\ s are copied first (donation must never consume a
        caller's buffer); numpy feeds transfer fresh buffers anyway.
        """
        if self._jit is None:
            self._jit = self._build()
        shared_vals = []
        for n in self.shared_leaves:
            v = _require(feeds, n)
            want = self.program.nodes[n].shape
            if tuple(getattr(v, "shape", ())) != tuple(want):
                raise ValueError(
                    f"operator leaf {n!r} is shared across the batch: "
                    f"expected shape {tuple(want)}, got "
                    f"{tuple(getattr(v, 'shape', ()))} (pass it unbatched)")
            shared_vals.append(v)
        batch = None
        batched_vals = []
        for n in self.batched_leaves:
            v = _require(feeds, n)
            want = self.program.nodes[n].shape
            shape = tuple(getattr(v, "shape", ()))
            if len(shape) != len(want) + 1 or shape[1:] != tuple(want):
                raise ValueError(
                    f"input leaf {n!r} must be batched: expected "
                    f"(B,) + {tuple(want)}, got {shape}")
            if batch is None:
                batch = shape[0]
            elif shape[0] != batch:
                raise ValueError(f"inconsistent batch sizes: leaf {n!r} "
                                 f"has {shape[0]}, expected {batch}")
            if self.donate:
                v = _own(v)
            batched_vals.append(v)
        _BP_DISPATCHES.inc(backend=self.backend, scope=self._scope)
        with obs.span("serve.batch_dispatch", backend=self.backend,
                      batch=batch):
            # fault-injection site (docs/robustness.md):
            # serve.dispatch@<backend> — fail or slow the coalesced
            # dispatch itself
            faults.check("serve.dispatch", backend=self.backend)
            return dict(self._jit(shared_vals, batched_vals))

    def run_many(self, requests: Sequence[Mapping[str, Any]],
                 shared: Mapping[str, Any], *,
                 pad: bool = True) -> List[Dict[str, Any]]:
        """Stack per-request feed dicts, dispatch once, unstack results.

        ``requests`` each map every batched (input) leaf to its unbatched
        value; ``shared`` maps the operator leaves.  Returns one output
        dict per request (numpy arrays — the stacked device outputs
        transfer to host in one sync per output, never one per request).

        ``pad=True`` (default) rounds the batch up to the next power of
        two by repeating the last request, then drops the filler lanes.
        jit retraces per distinct batch size, so an open-loop server
        coalescing variable-size batches would otherwise pay a fresh
        trace (hundreds of ms) for every new size; padding bounds the
        trace set to {1, 2, 4, ...} at ≤ 2× wasted lanes.  vmap lanes are
        independent, so filler lanes cannot perturb real ones.
        """
        import numpy as np
        if not requests:
            return []
        n_real = len(requests)
        n_lanes = _next_pow2(n_real) if pad else n_real
        feeds: Dict[str, Any] = dict(shared)
        for n in self.batched_leaves:
            vals = [np.asarray(_require(r, n)) for r in requests]
            vals += [vals[-1]] * (n_lanes - n_real)
            feeds[n] = np.stack(vals)
        out = {k: np.asarray(v) for k, v in self.run_batch(feeds).items()}
        return [{k: v[i] for k, v in out.items()} for i in range(n_real)]

    def run_one(self, feeds: Mapping[str, Any]) -> Dict[str, Any]:
        """The *jitted* unbatched solve — the sequential twin of one vmap
        lane.  This is the parity anchor: for gather/segment programs the
        vmapped batch matches a loop of ``run_one`` bitwise under the
        reference backend (same jit, same lowering), which a loop of eager
        ``plan.run()`` calls does not guarantee (jit fusion reorders)."""
        import jax
        if self._jit_one is None:
            self._jit_one = jax.jit(self._one)
        shared_vals = [_require(feeds, n) for n in self.shared_leaves]
        batched_vals = [_require(feeds, n) for n in self.batched_leaves]
        return dict(self._jit_one(shared_vals, batched_vals))


def _require(feeds: Mapping[str, Any], name: str):
    if name not in feeds:
        raise KeyError(f"feeds missing leaf {name!r}")
    return feeds[name]


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


def _own(v):
    """A buffer safe to donate: copy caller-owned jax.Arrays."""
    import jax
    import jax.numpy as jnp
    if isinstance(v, jax.Array):
        return jnp.array(v, copy=True)
    return v
