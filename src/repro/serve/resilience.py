"""Resilience primitives for the serving stack: retry policy + breaker.

:class:`RetryPolicy` is pure data — how many times to re-attempt a
failed batch and how long to back off between attempts.  The server
executes it through :func:`repro.runtime.fault_tolerance.run_with_restarts`,
so serving and training share one restart skeleton.

:class:`CircuitBreaker` is the classic three-state machine, one per
bucket: **closed** (serving normally; consecutive failures counted) →
**open** after ``failure_threshold`` consecutive failures (primary
attempts skipped — no retry storm against a plan that cannot compile on
this host) → **half_open** after ``reset_timeout_s`` (exactly one probe
attempt allowed; success closes the breaker, failure re-opens it).
Transitions are counted on the ``repro.obs`` registry
(``serve.breaker.transitions``, labels: name/from/to/scope) so
``Server.stats()`` and the span log can show *when* a bucket degraded.

Thread-safety: all state sits behind one lock; the clock is injectable
for deterministic tests.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, Optional

from .. import obs

__all__ = ["RetryPolicy", "CircuitBreaker"]

_TRANSITIONS = obs.registry().counter(
    "serve.breaker.transitions",
    "circuit-breaker state transitions (labels: name, from, to, scope)")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with exponential backoff.

    ``max_retries`` extra attempts follow a failed first attempt;
    attempt ``k``'s backoff is ``backoff_s * multiplier**(k-1)``, capped
    at ``max_backoff_s``.  ``RetryPolicy(max_retries=0)`` disables
    retries without disabling the policy plumbing.
    """
    max_retries: int = 2
    backoff_s: float = 0.01
    multiplier: float = 2.0
    max_backoff_s: float = 1.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff seconds must be >= 0")
        if self.multiplier < 1.0:
            raise ValueError("multiplier must be >= 1")

    def delay_s(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based)."""
        if attempt < 1:
            raise ValueError("attempt is 1-based")
        return min(self.backoff_s * self.multiplier ** (attempt - 1),
                   self.max_backoff_s)


class CircuitBreaker:
    """closed → open after N consecutive failures → half-open probe."""

    CLOSED = "closed"
    OPEN = "open"
    HALF_OPEN = "half_open"

    def __init__(self, failure_threshold: int = 3,
                 reset_timeout_s: float = 30.0, *, name: str = "",
                 scope: str = "",
                 clock: Callable[[], float] = time.monotonic):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if reset_timeout_s < 0:
            raise ValueError("reset_timeout_s must be >= 0")
        self.failure_threshold = failure_threshold
        self.reset_timeout_s = reset_timeout_s
        self.name = name
        self.scope = scope
        self._clock = clock
        self._lock = threading.Lock()
        self._state = self.CLOSED
        self._failures = 0          # consecutive, while closed
        self._opened_at: Optional[float] = None
        self._probing = False       # half-open probe outstanding
        self._opens = 0
        self._transitions = 0

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def allow(self) -> bool:
        """May the next attempt proceed?  Transitions open → half_open
        once the cooldown elapses and hands out exactly one probe."""
        with self._lock:
            if self._state == self.CLOSED:
                return True
            if self._state == self.OPEN:
                if self._clock() - self._opened_at >= self.reset_timeout_s:
                    self._transition(self.HALF_OPEN)
                    self._probing = True
                    return True
                return False
            # half-open: one probe at a time
            if self._probing:
                return False
            self._probing = True
            return True

    def record_success(self) -> None:
        with self._lock:
            self._failures = 0
            self._probing = False
            if self._state != self.CLOSED:
                self._transition(self.CLOSED)

    def record_failure(self) -> None:
        with self._lock:
            self._probing = False
            if self._state == self.HALF_OPEN:
                self._open()
            elif self._state == self.CLOSED:
                self._failures += 1
                if self._failures >= self.failure_threshold:
                    self._open()

    def _open(self) -> None:
        self._opened_at = self._clock()
        self._opens += 1
        self._transition(self.OPEN)

    def _transition(self, to: str) -> None:
        _TRANSITIONS.inc(**{"name": self.name, "from": self._state,
                            "to": to, "scope": self.scope})
        self._state = to
        self._transitions += 1

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {"state": self._state, "failures": self._failures,
                    "opens": self._opens,
                    "transitions": self._transitions}
