"""CELLO-JAX: schedule × hybrid implicit/explicit buffer co-design for
complex tensor reuse, as a production-grade JAX training/inference
framework (see DESIGN.md)."""

__version__ = "0.10.0"
