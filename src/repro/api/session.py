"""`Session`: the staged front-end for the CELLO toolchain.

One object owns the arch config, hardware model, capacity and result cache;
explicit stages carry the pipeline::

    from repro.api import Session
    from repro.core import V5E

    plan = (Session(arch="gemma_7b", hw=V5E)
            .trace(phase="decode")          # -> TracedGraph   (op DAG)
            .analyze()                      # -> AnalyzedGraph (reuse info)
            .codesign(strategy="default")   # -> CoDesigned    (schedule×buffer)
            .lower())                       # -> CompiledPlan  (kernels+remat)
    print(plan.explain())
    bundle = plan.serve()

Each stage returns a frozen, reprable artifact (`repro.api.artifacts`), so
intermediate decisions are inspectable and cacheable.  ``codesign`` results
are persisted to a disk cache keyed by (arch, phase, shape, hw, capacity,
strategy, graph fingerprint): repeated benchmark runs skip the search.
"""
from __future__ import annotations

import re
import threading
import time
from contextlib import contextmanager
from typing import Optional, Sequence, Union

from .. import obs
from ..configs import get_config, list_archs
from ..configs.base import ArchConfig
from ..core.costmodel import HardwareModel, V5E
from ..core.graph import OpGraph
from ..core.lowering import (decode_graph, layer_graph, partition_plan,
                             plan_execution, select_group_kernels)
from ..core.policy import CelloPlan
from ..core.policy import default_plan as _default_plan
from ..core.policy import lower_codesign
from ..core.reuse import analyze as _analyze
from ..core.schedule import sparse_operand_groups
from ..core.search import DEFAULT_SPLITS, get_strategy, run_codesign
from .artifacts import AnalyzedGraph, CoDesigned, CompiledPlan, TracedGraph
from .config import CodesignConfig, ExecConfig, UNSET, resolve_config
from .cache import (CodesignCache, algo_fingerprint, cache_disabled_by_env,
                    frontend_fingerprint, graph_fingerprint, hw_fingerprint,
                    strategy_fingerprint)

PHASES = ("train", "prefill", "decode")

# observability: per-stage wall-clock always lands in the global registry;
# spans additionally record when the tracer is enabled (CELLO_OBS)
_STAGE_S = obs.registry().histogram(
    "session.stage_s", "wall-clock per pipeline stage "
    "(trace | analyze | codesign | lower)", unit="s")
_STAGE_RUNS = obs.registry().counter(
    "session.stage_runs", "pipeline stage executions")


@contextmanager
def _stage(stage: str, **meta):
    """One pipeline-stage measurement: a span (tracing on) + a labeled
    duration histogram (always)."""
    t0 = time.perf_counter()
    with obs.span(f"session.{stage}", **meta) as sp:
        yield sp
    _STAGE_S.observe(time.perf_counter() - t0, stage=stage)
    _STAGE_RUNS.inc(stage=stage)

# paper-table default shapes per phase (override per trace() call)
_PHASE_DEFAULTS = {
    "train": dict(batch=4, seq=4096),
    "prefill": dict(batch=1, seq=32768),
    "decode": dict(batch=128, kv_len=32768),
}


def _resolve_arch(arch: Union[str, ArchConfig, None]) -> Optional[ArchConfig]:
    if arch is None or arch == "hpc":
        # arch-less session: only frontend traces (trace(workload=...) /
        # Session.from_graph) are available
        return None
    if isinstance(arch, ArchConfig):
        return arch
    try:
        return get_config(arch)
    except KeyError:
        # accept python-identifier spellings (gemma_7b == gemma-7b), incl.
        # dotted registry names (llama_3_2_vision_11b == llama-3.2-vision-11b)
        def squash(s: str) -> str:
            return re.sub(r"[^a-z0-9]", "", s.lower())
        matches = [n for n in list_archs() if squash(n) == squash(arch)]
        if len(matches) != 1:
            raise
        return get_config(matches[0])


class Session:
    """Staged compilation session for one (arch, hardware) pair."""

    def __init__(self, arch: Union[str, ArchConfig, None] = None, *,
                 hw: HardwareModel = V5E,
                 capacity_bytes: Optional[int] = None,
                 use_cache: bool = True,
                 cache_dir=None):
        self.cfg = _resolve_arch(arch)
        self.hw = hw
        self.capacity_bytes = capacity_bytes or hw.vmem_bytes
        # env kill-switch is checked per codesign() call, not frozen here
        self.use_cache = use_cache
        self.cache = CodesignCache(cache_dir)
        # trace memoization is thread-safe: the serving layer traces from
        # worker threads while callers may trace concurrently.  The lock
        # spans lookup+build+insert, so one (phase, shape) / (workload,
        # params) cell is built exactly once and every thread sees the
        # same TracedGraph (builds serialize; they are cheap vs codesign).
        self._trace_memo = {}
        self._trace_lock = threading.Lock()

    # -- stage 1: trace -------------------------------------------------
    def trace(self, phase: Optional[str] = None, *,
              batch: Optional[int] = None,
              seq: Optional[int] = None, kv_len: Optional[int] = None,
              layer_kind: Optional[str] = None,
              workload: Optional[str] = None,
              **workload_params) -> TracedGraph:
        """Build the analysis-level op DAG for one phase of this arch —
        or, with ``workload=``, for a registered HPC frontend workload::

            Session().trace(workload="cg", n=4096, iters=4)

        HPC traces carry ``phase="hpc"`` and need no arch config; extra
        keyword arguments go to the workload builder
        (``repro.frontends.hpc``).  Traces are memoized per (phase, shape)
        or (workload, params): repeat calls return the same artifact, so
        treat the carried ``OpGraph`` as read-only.
        """
        with _stage("trace", arch=self.cfg.name if self.cfg else None,
                    phase=phase, workload=workload):
            return self._trace(phase, batch=batch, seq=seq, kv_len=kv_len,
                               layer_kind=layer_kind, workload=workload,
                               **workload_params)

    def _trace(self, phase: Optional[str] = None, *,
               batch: Optional[int] = None,
               seq: Optional[int] = None, kv_len: Optional[int] = None,
               layer_kind: Optional[str] = None,
               workload: Optional[str] = None,
               **workload_params) -> TracedGraph:
        if workload is not None:
            if any(v is not None for v in (batch, seq, kv_len, layer_kind)):
                raise ValueError("workload= traces take workload builder "
                                 "params, not batch/seq/kv_len/layer_kind")
            if phase is not None:
                raise ValueError("workload= traces have phase='hpc'; do "
                                 f"not combine workload with "
                                 f"phase={phase!r}")
            return self._trace_workload(workload, workload_params)
        phase = "train" if phase is None else phase
        if workload_params:
            raise TypeError(f"unexpected trace() kwargs "
                            f"{sorted(workload_params)} (workload builder "
                            "params need workload=)")
        if self.cfg is None:
            raise ValueError("this Session has no arch config; pass arch= "
                             "to Session() or trace a frontend workload "
                             "via trace(workload=...)")
        if phase not in PHASES:
            raise ValueError(f"phase {phase!r} not in {PHASES}")
        if phase == "decode" and self.cfg.encoder_only:
            raise ValueError(f"{self.cfg.name} is encoder-only: no decode")
        defaults = _PHASE_DEFAULTS[phase]
        batch = batch if batch is not None else defaults["batch"]
        if phase == "decode":
            if seq is not None:
                raise ValueError("decode traces take kv_len=, not seq=")
            if layer_kind is not None:
                raise ValueError("decode traces pick their layer kind from "
                                 "the arch; layer_kind= is train/prefill-only")
            kv_len = kv_len if kv_len is not None else defaults["kv_len"]
        else:
            if kv_len is not None:
                raise ValueError(f"{phase} traces take seq=, not kv_len=")
            seq = seq if seq is not None else defaults["seq"]
        memo_key = (phase, batch, seq, kv_len, layer_kind)
        with self._trace_lock:
            hit = self._trace_memo.get(memo_key)
            if hit is not None:
                return hit
            if phase == "decode":
                graph = decode_graph(self.cfg, batch, kv_len)
            else:
                graph = layer_graph(self.cfg, batch, seq,
                                    layer_kind=layer_kind)
            traced = TracedGraph(arch=self.cfg.name, phase=phase,
                                 batch=batch, seq=seq, kv_len=kv_len,
                                 layer_kind=layer_kind, graph=graph,
                                 session=self)
            self._trace_memo[memo_key] = traced
            return traced

    def _trace_workload(self, workload: str, params: dict) -> TracedGraph:
        from ..frontends.hpc import build_workload    # lazy: optional path
        wl_params = tuple(sorted(params.items()))
        memo_key = ("hpc", workload, wl_params)
        with self._trace_lock:
            hit = self._trace_memo.get(memo_key)
            if hit is not None:
                return hit
            program = build_workload(workload, **params)
            traced = TracedGraph(arch=f"hpc:{workload}", phase="hpc",
                                 batch=1, seq=None, kv_len=None,
                                 layer_kind=None, graph=program.to_graph(),
                                 session=self, program=program,
                                 workload=workload, wl_params=wl_params)
            self._trace_memo[memo_key] = traced
            return traced

    @classmethod
    def from_graph(cls, obj, *, hw: HardwareModel = V5E,
                   capacity_bytes: Optional[int] = None,
                   use_cache: bool = True, cache_dir=None) -> TracedGraph:
        """Wrap a frontend ``Program`` / ``Expr`` or a raw ``OpGraph`` as a
        TracedGraph on a fresh arch-less session, ready for
        ``analyze → codesign → lower``.

        An ``Expr`` is marked as its program's output when none is set;
        raw ``OpGraph``\\ s lower to an analysis plan but cannot ``run()``
        (there is no expression program to interpret).
        """
        from ..frontends.expr import Expr, Program   # lazy: optional path
        if isinstance(obj, TracedGraph):
            return obj
        sess = cls(None, hw=hw, capacity_bytes=capacity_bytes,
                   use_cache=use_cache, cache_dir=cache_dir)
        if isinstance(obj, Expr):
            if not obj.program.outputs:
                obj.program.output(obj)
            obj = obj.program
        if isinstance(obj, Program):
            return TracedGraph(arch=f"hpc:{obj.name}", phase="hpc", batch=1,
                               seq=None, kv_len=None, layer_kind=None,
                               graph=obj.to_graph(), session=sess,
                               program=obj)
        if isinstance(obj, OpGraph):
            obj.validate()
            return TracedGraph(arch=f"graph:{obj.name}", phase="hpc",
                               batch=1, seq=None, kv_len=None,
                               layer_kind=None, graph=obj, session=sess)
        raise TypeError(f"from_graph takes a Program, Expr, OpGraph or "
                        f"TracedGraph, got {type(obj).__name__}")

    # -- stage 2: analyze -----------------------------------------------
    def analyze(self, traced: TracedGraph) -> AnalyzedGraph:
        """Reuse-distance/frequency analysis over the natural order."""
        with _stage("analyze", arch=traced.arch, phase=traced.phase):
            return AnalyzedGraph(trace=traced,
                                 analysis=_analyze(traced.graph))

    # -- stage 3: codesign ----------------------------------------------
    def codesign(self, staged: Union[TracedGraph, AnalyzedGraph],
                 config: Optional[CodesignConfig] = None, *,
                 strategy=UNSET,
                 capacity_bytes=UNSET,
                 max_orders=UNSET,
                 splits=UNSET,
                 overbook=UNSET,
                 use_cache=UNSET) -> CoDesigned:
        """The joint schedule × buffer search (disk-cached).

        Knobs arrive as one :class:`~repro.api.config.CodesignConfig`;
        the individual keywords are a 0.9-era spelling kept for one
        release (DeprecationWarning — see ``docs/api_migration.md``).

        ``overbook`` lets a sparse operand's pin exceed the explicit
        region by that fraction of its capacity: an indptr-aligned row
        prefix pins while the spill tail streams per pass.  ``0.0``
        (default) keeps the historical all-or-nothing pins bit-for-bit.
        """
        cfg = resolve_config(
            CodesignConfig, config,
            dict(strategy=strategy, capacity_bytes=capacity_bytes,
                 max_orders=max_orders, splits=splits, overbook=overbook,
                 use_cache=use_cache),
            "Session.codesign")
        traced = staged if isinstance(staged, TracedGraph) else staged.trace
        with _stage("codesign", arch=traced.arch,
                    phase=traced.phase) as sp:
            return self._codesign(
                traced, sp,
                natural_analysis=(staged.analysis
                                  if isinstance(staged, AnalyzedGraph)
                                  else None),
                strategy=cfg.strategy, capacity_bytes=cfg.capacity_bytes,
                max_orders=cfg.max_orders, splits=cfg.splits,
                overbook=cfg.overbook, use_cache=cfg.use_cache)

    def _codesign(self, traced: TracedGraph, sp, *, natural_analysis,
                  strategy, capacity_bytes, max_orders, splits, overbook,
                  use_cache, shards: int = 1) -> CoDesigned:
        splits = list(splits)    # one-shot iterables: key + search see same
        capacity = capacity_bytes or self.capacity_bytes
        strategy_obj = get_strategy(strategy)
        strategy_name = strategy_obj.name
        sp.annotate(strategy=strategy_name)
        cached = self.use_cache if use_cache is None else use_cache
        if cache_disabled_by_env():     # env kill-switch beats per-call opts
            cached = False
        if cached:
            # the key tracks the strategy's own code + instance state, not
            # just its name: algo_fingerprint only hashes the core modules,
            # a registered custom strategy can be edited between runs, and
            # an instance passed directly (never registered) must not alias
            # a registered name's entries.  None = no stable identity
            # (REPL-defined class, address-bearing attr reprs): don't cache.
            strategy_src = strategy_fingerprint(strategy_obj)
            if strategy_src is None:
                cached = False
        key = None
        if cached:
            # shards only enters the key when > 1 so pre-0.10 cache
            # entries keep hitting for single-device plans
            shard_key = {"shards": shards} if shards > 1 else {}
            key = self.cache.key(
                **shard_key,
                # any edit to the search/sim/cost code invalidates old entries
                algo=algo_fingerprint(),
                arch=traced.arch, phase=traced.phase, batch=traced.batch,
                seq=traced.seq, kv_len=traced.kv_len,
                layer_kind=traced.layer_kind, hw=hw_fingerprint(self.hw),
                capacity=capacity, strategy=strategy_name,
                strategy_src=strategy_src, max_orders=max_orders,
                splits=list(splits), overbook=overbook,
                graph=graph_fingerprint(traced.graph),
                # frontend-built graphs fold in the expression DAG + the
                # frontend lowering code (None for registry traces)
                frontend=frontend_fingerprint(traced.program))
            hit = self.cache.get(key)
            if hit is not None:
                sp.annotate(cache="hit")
                return CoDesigned(trace=traced, result=hit,
                                  strategy=strategy_name,
                                  capacity_bytes=capacity, from_cache=True)
        sp.annotate(cache="miss" if cached else "off")

        # pass the resolved object so the strategy the cache checks is the
        # one the search actually runs (a class arg would re-instantiate)
        result = run_codesign(traced.graph, capacity_bytes=capacity,
                              hw=self.hw, max_orders=max_orders,
                              strategy=strategy_obj, splits=splits,
                              overbook=overbook,
                              natural_analysis=natural_analysis)
        if cached:
            self.cache.put(key, result)
        return CoDesigned(trace=traced, result=result,
                          strategy=strategy_name, capacity_bytes=capacity,
                          from_cache=False)

    # -- stage 4: lower --------------------------------------------------
    def lower(self, designed: CoDesigned,
              config: Optional[ExecConfig] = None, *,
              seq: Optional[int] = None,
              backend: Optional[str] = None,
              mesh=None) -> CompiledPlan:
        """Turn the co-design decision into an executable CelloPlan.

        ``backend`` picks the default execution backend ``plan.run()``
        uses for frontend (HPC) plans — any name registered in
        ``repro.exec`` (``"reference"``, ``"pallas"``, ...); each run can
        still override it via ``run(backend=...)``.

        ``mesh`` (frontend plans only) partitions the co-designed DAG
        across a 1-D device mesh: the shard count ``K`` or an
        ``(axis, K)`` pair.  Sharded plans re-run the schedule × buffer
        search at aggregate capacity ``K·C`` (each shard pins/streams
        its own row block) and execute via ``shard_map`` on the pallas
        backend or a bitwise simulated mesh on the reference backend —
        see ``docs/distributed.md``.  An :class:`ExecConfig` consolidates
        these (plus the pallas donation/interpret toggles).
        """
        if config is not None:
            if backend is not None or mesh is not None:
                raise TypeError("Session.lower: pass either config= or "
                                "backend=/mesh=, not both")
            backend = config.backend
            mesh = config.mesh
            config.apply_toggles()
        backend = backend if backend is not None else "reference"
        traced = designed.trace
        with _stage("lower", arch=traced.arch, phase=traced.phase,
                    backend=backend):
            if traced.phase == "hpc":
                if seq is not None:
                    raise ValueError("frontend (HPC) plans take no seq=: "
                                     "block sizing comes from the "
                                     "expression shapes")
                return self._lower_frontend(designed, backend=backend,
                                            mesh=mesh)
            if mesh is not None:
                raise ValueError("mesh= partitioning applies to frontend "
                                 "(HPC) plans; LLM plans distribute via "
                                 "repro.launch")
            if seq is None:
                seq = traced.seq if traced.seq is not None else \
                    (traced.kv_len or 4096)
            plan = lower_codesign(self.cfg, designed.result, seq=seq,
                                  hw=self.hw)
            return CompiledPlan(cfg=self.cfg, plan=plan, trace=traced,
                                codesigned=designed, backend=backend)

    def _lower_frontend(self, designed: CoDesigned, *,
                        backend: str = "reference",
                        mesh=None) -> CompiledPlan:
        """HPC/frontend lowering: no LLM kernels or remat save-sets apply;
        the plan carries the co-designed split, a kernel shape per fusion
        group (`core.lowering.select_group_kernels`), and executes in the
        scheduled group order through an execution backend
        (`plan.run(backend=...)`)."""
        traced = designed.trace
        axis, n_shards = ("shards", 1) if mesh is None else \
            (("shards", mesh) if isinstance(mesh, int)
             else (mesh[0], int(mesh[1])))
        if n_shards > 1:
            # co-design the *global* graph against the mesh's aggregate
            # buffer capacity K·C: each shard holds a 1/K row block, so a
            # pin that fits K·C globally fits C per shard — this is what
            # lets a matrix too large to pin on one device pin once the
            # mesh is wide enough (TABLE 11's crossover)
            with _stage("codesign", arch=traced.arch,
                        phase=traced.phase) as sp2:
                sp2.annotate(shards=n_shards)
                designed = self._codesign(
                    traced, sp2, natural_analysis=None,
                    strategy=designed.strategy,
                    capacity_bytes=designed.capacity_bytes * n_shards,
                    max_orders=16, splits=DEFAULT_SPLITS,
                    overbook=getattr(designed.result, "overbook", 0.0),
                    use_cache=None, shards=n_shards)
        sched = designed.result.best.schedule
        partial = dict(getattr(sched.pins, "partial", None) or {})
        kernels = select_group_kernels(traced.graph, sched.groups,
                                       sched.config.explicit_bytes,
                                       partial=partial)
        # density-aware pin outcome: a CSR operand pins as one unit when
        # its nnz footprint fits, or as an overbooked row prefix — surface
        # the decision in explain()
        sparse_note = ""
        sparse_grps = sparse_operand_groups(traced.graph)
        if sparse_grps:
            prefix = sum(any(m in partial for m in g) for g in sparse_grps)
            pinned = sum(all(m in sched.pins for m in g)
                         and not any(m in partial for m in g)
                         for g in sparse_grps)
            sparse_note = (f" sparse-operands={len(sparse_grps)} "
                           f"pinned-by-nnz-footprint={pinned}")
            if prefix:
                sparse_note += f" prefix-pinned={prefix}"
        # execution-level plan: residency-fused dispatch units + the rolled
        # iteration segment (when the frontend recorded bodies and the
        # scheduled units repeat them) — surfaced by explain()/report() and
        # consumed by the single-program pallas executable
        exec_plan = plan_execution(traced.graph, kernels,
                                   sched.config.explicit_bytes,
                                   program=traced.program, partial=partial)
        sharded = None
        if mesh is not None:
            # K=1 still goes through partition_plan so the degenerate
            # mesh validates exactly like a real one; executors only
            # take the sharded route when n_shards > 1
            sharded = partition_plan(exec_plan, (axis, n_shards),
                                     program=traced.program)
            sparse_note += f" mesh={axis}:{n_shards}"
        plan = CelloPlan(
            arch=traced.arch,
            use_flash_attention=False, q_block=0, kv_block=0,
            use_fused_mlp=False, mlp_block_m=0, mlp_block_f=0,
            use_fused_rmsnorm=False, remat_save_names=(),
            explicit_frac=sched.config.explicit_frac,
            notes=(f"frontend graph: groups={len(sched.groups)} "
                   f"pins={len(sched.pins)} "
                   f"speedup={designed.result.speedup():.2f}x"
                   + sparse_note))
        return CompiledPlan(cfg=None, plan=plan, trace=traced,
                            codesigned=designed, backend=backend,
                            group_kernels=kernels, exec_plan=exec_plan,
                            sharded=sharded)

    # -- fast path (no search) -------------------------------------------
    def default_plan(self, *, seq: int = 4096) -> CompiledPlan:
        """Paper-faithful default plan without running the search (smoke
        tests, dry-runs, CPU-scale examples)."""
        if self.cfg is None:
            raise ValueError("default_plan needs an arch config; frontend "
                             "workloads always go through codesign()")
        plan = _default_plan(self.cfg, seq=seq, hw=self.hw)
        return CompiledPlan(cfg=self.cfg, plan=plan)

    # -- one-shot convenience --------------------------------------------
    def compile(self, phase: str = "train", *,
                lower_seq: Optional[int] = None,
                **trace_kwargs) -> CompiledPlan:
        """trace → analyze → codesign → lower in one call.

        ``trace_kwargs`` (batch/seq/kv_len/layer_kind) go to :meth:`trace`;
        ``lower_seq`` overrides the block-sizing seq used by :meth:`lower`
        (defaults to the traced shape).
        """
        traced = self.trace(phase, **trace_kwargs)
        # codesign straight from the trace: a disk-cache hit then skips the
        # reuse analysis entirely (it only pre-seeds the search's cache)
        return self.lower(self.codesign(traced), seq=lower_seq)

    def __repr__(self) -> str:
        on = self.use_cache and not cache_disabled_by_env()
        name = self.cfg.name if self.cfg is not None else "<frontend>"
        return (f"Session({name!r}, hw={self.hw.name!r}, "
                f"capacity={self.capacity_bytes // 1024 // 1024} MiB, "
                f"cache={'on' if on else 'off'})")
