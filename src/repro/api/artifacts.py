"""Frozen stage artifacts for the staged `cello` compilation pipeline.

Each :class:`~repro.api.session.Session` stage returns one of these:

    Session.trace()    -> TracedGraph
    TracedGraph.analyze()   -> AnalyzedGraph
    AnalyzedGraph.codesign()-> CoDesigned
    CoDesigned.lower()      -> CompiledPlan

Artifacts are frozen dataclasses with compact reprs; each keeps a reference
to its session so the stages chain, but all the decision state is in the
artifact itself (inspect, cache, or compare them freely).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Dict, Optional, Tuple

from ..configs.base import ArchConfig
from ..core.graph import OpGraph
from ..core.lowering import ExecPlan, GroupKernel, ShardedExecPlan
from ..core.policy import CelloPlan
from ..core.reuse import ReuseAnalysis
from ..core.schedule import CoDesignResult, EvaluatedSchedule
from .config import UNSET as _UNSET

if TYPE_CHECKING:                                      # pragma: no cover
    from ..frontends.expr import Program
    from .session import Session


@dataclasses.dataclass(frozen=True)
class TracedGraph:
    """Stage 1: the analysis-level op DAG for one (arch, phase, shape).

    ``Session.trace`` memoizes these per shape, so the carried ``graph``
    is shared between repeat calls — treat it as read-only; to experiment
    with graph edits, build your own via ``OpGraph.build()``.

    Frontend-built traces (``trace(workload=...)`` / ``Session.from_graph``)
    use ``phase="hpc"`` and carry the source expression ``program`` so the
    lowered plan can be executed and validated numerically.
    """
    arch: str
    phase: str                # "train" | "prefill" | "decode" | "hpc"
    batch: int
    seq: Optional[int]                # train/prefill
    kv_len: Optional[int]             # decode
    layer_kind: Optional[str]
    graph: OpGraph = dataclasses.field(repr=False, compare=False)
    session: "Session" = dataclasses.field(repr=False, compare=False)
    # frontend (HPC) traces only
    program: Optional["Program"] = dataclasses.field(
        default=None, repr=False, compare=False)
    workload: Optional[str] = None
    wl_params: Tuple[Tuple[str, Any], ...] = ()

    @property
    def shape_key(self) -> str:
        if self.phase == "hpc":
            return ("-".join(f"{k}{v}" for k, v in self.wl_params)
                    or self.graph.name)
        span = f"s{self.seq}" if self.phase != "decode" else f"kv{self.kv_len}"
        return f"b{self.batch}{span}"

    def analyze(self) -> "AnalyzedGraph":
        return self.session.analyze(self)

    def codesign(self, config=None, **kwargs) -> "CoDesigned":
        """Convenience: codesign straight from the trace.  The reuse
        analysis is computed only if the search actually runs, so a disk
        cache hit skips it entirely."""
        return self.session.codesign(self, config, **kwargs)

    def __repr__(self) -> str:
        return (f"TracedGraph({self.arch!r}, phase={self.phase!r}, "
                f"{self.shape_key}, {len(self.graph.ops)} ops, "
                f"{self.graph.total_flops:.3e} FLOPs)")


@dataclasses.dataclass(frozen=True)
class AnalyzedGraph:
    """Stage 2: reuse distances/frequencies over the natural schedule."""
    trace: TracedGraph
    analysis: ReuseAnalysis = dataclasses.field(repr=False, compare=False)

    @property
    def session(self) -> "Session":
        return self.trace.session

    def reuse_of(self, tensor: str):
        return self.analysis.tensors[tensor]

    def pin_candidates(self):
        return self.analysis.ranked_pin_candidates()

    def codesign(self, config=None, **kwargs) -> "CoDesigned":
        return self.session.codesign(self, config, **kwargs)

    def __repr__(self) -> str:
        multi = sum(1 for t in self.analysis.tensors.values()
                    if t.frequency > 1)
        return (f"AnalyzedGraph({self.trace.arch!r}, "
                f"phase={self.trace.phase!r}, "
                f"{len(self.analysis.tensors)} tensors, "
                f"{multi} with reuse)")


@dataclasses.dataclass(frozen=True)
class CoDesigned:
    """Stage 3: the joint schedule × buffer decision (plus baselines)."""
    trace: TracedGraph
    result: CoDesignResult = dataclasses.field(repr=False, compare=False)
    strategy: str = "default"
    capacity_bytes: int = 0
    from_cache: bool = False

    @property
    def session(self) -> "Session":
        return self.trace.session

    # -- passthroughs to the underlying result -------------------------
    @property
    def best(self) -> EvaluatedSchedule:
        return self.result.best

    @property
    def baselines(self) -> Dict[str, EvaluatedSchedule]:
        return self.result.baselines

    @property
    def split_sweep(self):
        return self.result.split_sweep

    def speedup(self, baseline: str = "seq-implicit") -> float:
        return self.result.speedup(baseline)

    def energy_ratio(self, baseline: str = "seq-implicit") -> float:
        return self.result.energy_ratio(baseline)

    def lower(self, config=None, *, seq: Optional[int] = None,
              backend: Optional[str] = None,
              mesh=None) -> "CompiledPlan":
        return self.session.lower(self, config, seq=seq, backend=backend,
                                  mesh=mesh)

    def __repr__(self) -> str:
        s = self.best.schedule
        return (f"CoDesigned({self.trace.arch!r}, phase={self.trace.phase!r}, "
                f"split={s.config.explicit_frac:.3f}, "
                f"{len(s.groups)} groups, {len(s.pins)} pins, "
                f"speedup={self.speedup():.2f}x"
                f"{', cached' if self.from_cache else ''})")


@dataclasses.dataclass(frozen=True)
class CompiledPlan:
    """Stage 4: the lowered execution plan, ready to serve or train.

    ``.serve()`` / ``.train()`` drive the JAX execution stack with this
    plan; ``.report()`` returns the headline co-design numbers and
    ``.explain()`` a human-readable schedule/pin/split summary.

    Frontend (HPC) plans carry ``cfg=None``: they execute through
    :meth:`run`, which hands the plan to a registered execution backend
    (``repro.exec``) — ``reference`` replays the scheduled op order through
    the jax.numpy interpreter, ``pallas`` compiles each fusion group into
    tile-streaming kernels.  No LLM serving stack applies.
    """
    cfg: Optional[ArchConfig] = dataclasses.field(repr=False)
    plan: CelloPlan = dataclasses.field(repr=False)
    trace: Optional[TracedGraph] = dataclasses.field(
        default=None, repr=False, compare=False)
    codesigned: Optional[CoDesigned] = dataclasses.field(
        default=None, repr=False, compare=False)
    # execution-backend selection (frontend plans): the default backend
    # `.run()` uses, and the kernel shape chosen for every fusion group
    # (`core.lowering.select_group_kernels`)
    backend: str = "reference"
    group_kernels: Tuple[GroupKernel, ...] = dataclasses.field(
        default=(), repr=False, compare=False)
    # execution-level plan (frontend plans): fused dispatch units,
    # cross-pass residency spans, rolled iteration segment
    # (`core.lowering.plan_execution`)
    exec_plan: Optional[ExecPlan] = dataclasses.field(
        default=None, repr=False, compare=False)
    # mesh partitioning (frontend plans lowered with mesh=): row blocks,
    # CSR entry windows, gather/psum/halo exchange sets
    # (`core.lowering.partition_plan`); None for single-device plans
    sharded: Optional[ShardedExecPlan] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def arch(self) -> str:
        return self.cfg.name if self.cfg is not None else self.plan.arch

    # -- execution ------------------------------------------------------
    def serve(self, *, unroll: bool = False):
        """Serving bundle (prefill/decode fns + greedy generate driver)."""
        if self.cfg is None:
            raise ValueError("frontend (HPC) plans have no LLM serving "
                             "stack; execute them with plan.run()")
        from ..launch.serve import make_serving      # lazy: pulls in jax
        return make_serving(self.cfg, self.plan, unroll=unroll)

    def train(self, *, data_iter, n_steps: int, opt_cfg=None, **kwargs
              ) -> Dict[str, Any]:
        """Run the CPU-scale training loop under this plan's remat policy."""
        if self.cfg is None:
            raise ValueError("frontend (HPC) plans have no LLM training "
                             "stack; execute them with plan.run()")
        from ..launch.train import train_loop        # lazy: pulls in jax
        from ..optim import AdamWConfig
        if opt_cfg is None:
            opt_cfg = AdamWConfig(total_steps=n_steps)
        return train_loop(self.cfg, self.plan, opt_cfg,
                          data_iter=data_iter, n_steps=n_steps, **kwargs)

    def run(self, feeds=None, *, seed: int = 0,
            backend: Optional[str] = None,
            config=None) -> Dict[str, Any]:
        """Execute a frontend plan through an execution backend.

        ``backend`` (or ``config=ExecConfig(backend=...)``) overrides the
        plan's default (picked at ``lower()``): ``"reference"`` replays
        the co-designed schedule order through the jax.numpy
        interpreter — ops are pure, so this matches natural-order
        evaluation bit-for-bit; ``"pallas"`` runs each fusion group as
        tile-streaming kernels, matching reference within the tolerances
        documented in ``docs/execution_backends.md``.  Plans lowered with
        ``mesh=`` execute sharded on either backend
        (``docs/distributed.md``).
        """
        if config is not None:
            if backend is not None:
                raise TypeError("run(): pass either config= or backend=, "
                                "not both")
            if config.mesh is not None:
                raise ValueError("the mesh is fixed when the plan is "
                                 "lowered; re-lower with "
                                 "Session.lower(..., mesh=...)")
            backend = config.backend
            config.apply_toggles()
        if self.trace is None or self.trace.program is None:
            raise ValueError("run() needs a frontend-traced plan "
                             "(Session.trace(workload=...) or "
                             "Session.from_graph(program))")
        from ..exec import get_backend                   # lazy: pulls in jax
        return get_backend(backend or self.backend).run(
            self, feeds=feeds, seed=seed)

    def batched(self, config=None, *, backend: Optional[str] = None,
                donate=_UNSET):
        """Wrap this frontend plan for batched serving: one vmapped
        dispatch answers a whole batch of requests (operator leaves
        shared, input leaves batched) — see ``repro.serve.BatchedPlan``.

        ``donate=`` is deprecated since 0.10: pass
        ``config=ExecConfig(donate=...)`` (``docs/api_migration.md``).
        """
        donate_val: Optional[bool] = None
        if donate is not _UNSET:
            if config is not None:
                raise TypeError("batched(): pass either config= or "
                                "donate=, not both")
            import warnings
            warnings.warn(
                "batched(donate=...) is deprecated since 0.10 and will "
                "be removed in 0.11; pass config=ExecConfig(donate=...) "
                "instead (see docs/api_migration.md)",
                DeprecationWarning, stacklevel=2)
            donate_val = donate
        if config is not None:
            if backend is not None:
                raise TypeError("batched(): pass either config= or "
                                "backend=, not both")
            if config.mesh is not None:
                raise ValueError("the mesh is fixed when the plan is "
                                 "lowered; re-lower with "
                                 "Session.lower(..., mesh=...)")
            backend = config.backend
            donate_val = config.donate
        if self.trace is None or self.trace.program is None:
            raise ValueError("batched() needs a frontend-traced plan "
                             "(Session.trace(workload=...) or "
                             "Session.from_graph(program))")
        from ..serve import BatchedPlan                  # lazy: pulls in jax
        return BatchedPlan(self, backend=backend, donate=donate_val)

    # -- introspection --------------------------------------------------
    def report(self) -> Dict[str, Any]:
        """Headline co-design metrics (empty-ish for default plans)."""
        out: Dict[str, Any] = {
            "arch": self.arch,
            "plan": dataclasses.asdict(self.plan),
        }
        if self.trace is not None:
            out["phase"] = self.trace.phase
            out["shape"] = self.trace.shape_key
        if self.cfg is None:
            out["backend"] = self.backend
            out["group_kernel_kinds"] = [gk.kind
                                         for gk in self.group_kernels]
            if self.exec_plan is not None:
                ep = self.exec_plan
                out["exec_units"] = len(ep.units)
                out["exec_fused_from"] = ep.n_prefuse
                out["rolled_iters"] = (ep.roll.n_iters
                                       if ep.roll is not None else 0)
            if self.sharded is not None:
                out["mesh"] = {"axis": self.sharded.axis,
                               "n_shards": self.sharded.n_shards,
                               "rows_per_shard":
                                   self.sharded.rows_per_shard,
                               "plan": self.sharded.describe()}
        cd = self.codesigned
        if cd is not None:
            m = cd.best.metrics
            out.update({
                "strategy": cd.strategy,
                "capacity_bytes": cd.capacity_bytes,
                "overbook": getattr(cd.result, "overbook", 0.0),
                "explicit_frac": cd.best.schedule.config.explicit_frac,
                "time_s": m.time_s,
                "energy_j": m.energy_j,
                "hbm_bytes": m.hbm_bytes,
                "arithmetic_intensity": m.ai,
                "speedup_vs_implicit": cd.speedup(),
                "energy_ratio_vs_implicit": cd.energy_ratio(),
                "baselines": {
                    name: {"time_s": ev.metrics.time_s,
                           "energy_j": ev.metrics.energy_j,
                           "hbm_bytes": ev.metrics.hbm_bytes}
                    for name, ev in cd.baselines.items()},
                "from_cache": cd.from_cache,
            })
        from .. import obs
        out["obs"] = obs.snapshot()
        return out

    def explain(self) -> str:
        """Human-readable schedule / pin / split / kernel summary."""
        p = self.plan
        lines = [f"CompiledPlan for {self.arch}"]
        if self.trace is not None:
            lines.append(f"  traced phase      : {self.trace.phase} "
                         f"({self.trace.shape_key})")
        cd = self.codesigned
        if cd is not None:
            s = cd.best.schedule
            cap = cd.capacity_bytes
            lines += [
                f"  search strategy   : {cd.strategy}"
                + (" [cache hit]" if cd.from_cache else ""),
                f"  buffer split      : {s.config.explicit_frac:.3f} explicit"
                f" ({s.config.explicit_bytes // 1024 // 1024} MiB of"
                f" {cap // 1024 // 1024} MiB)",
                f"  fusion groups     : "
                + (", ".join("{" + "+".join(g) + "}"
                             for g in s.groups if len(g) > 1) or "(none)"),
                f"  explicit pins     : "
                + (", ".join(f"{t}[g{a}..g{b}]"
                             for t, (a, b) in sorted(s.pins.items()))
                   or "(none)"),
                f"  speedup           : {cd.speedup():.3f}x vs implicit-only,"
                f" energy {cd.energy_ratio():.3f}x better",
                f"  HBM traffic       : "
                f"{cd.best.metrics.hbm_bytes / 1e6:,.1f} MB "
                f"(AI {cd.best.metrics.ai:,.1f} FLOP/B)",
            ]
            ob = getattr(cd.result, "overbook", 0.0)
            if ob:
                lines.append(f"  pin overbook      : {ob:.3f} of the "
                             "explicit region (prefix pins allowed)")
            if self.trace is not None:
                from ..core.schedule import sparse_operand_groups
                partial = dict(getattr(s.pins, "partial", None) or {})
                terms = []
                for grp in sparse_operand_groups(self.trace.graph):
                    base = grp[0].rsplit(".", 1)[0]
                    pp = next((partial[m] for m in grp if m in partial),
                              None)
                    if pp is not None:
                        terms.append(
                            f"{base} pinned=prefix(rows={pp.rows}/"
                            f"{pp.total_rows}, frac={pp.frac:.2f})")
                    elif all(m in s.pins for m in grp):
                        terms.append(f"{base} pinned=full")
                    else:
                        terms.append(f"{base} pinned=streamed")
                if terms:
                    lines.append("  sparse operands   : "
                                 + ", ".join(terms))
        else:
            lines.append("  (default plan — no search was run)")
        if self.cfg is None:
            g = self.trace.graph if self.trace is not None else None
            lines.append(
                f"  execution backend : {self.backend}"
                + (f" over {len(g.ops)} ops" if g is not None else "")
                + " (run(backend=...) to override)")
            if self.group_kernels:
                lines.append("  group kernels     :")
                for i, gk in enumerate(self.group_kernels):
                    lines.append(f"    g{i} {{{'+'.join(gk.ops)}}}: "
                                 f"{gk.describe()}")
            if self.exec_plan is not None:
                lines.append(f"  execution plan    : "
                             f"{self.exec_plan.describe()}")
            if self.sharded is not None:
                lines.append(f"  device mesh       : "
                             f"{self.sharded.describe()}")
        else:
            lines += [
                f"  flash attention   : {p.use_flash_attention} "
                f"(q_block={p.q_block}, kv_block={p.kv_block})",
                f"  fused MLP         : {p.use_fused_mlp} "
                f"(m={p.mlp_block_m}, f={p.mlp_block_f})",
                f"  remat save-set    : {', '.join(p.remat_save_names)}",
            ]
        if p.notes:
            lines.append(f"  notes             : {p.notes}")
        return "\n".join(lines)

    def __repr__(self) -> str:
        tag = (f"phase={self.trace.phase!r}, " if self.trace else "")
        how = "codesigned" if self.codesigned else "default"
        return (f"CompiledPlan({self.arch!r}, {tag}{how}, "
                f"flash={self.plan.use_flash_attention}, "
                f"fused_mlp={self.plan.use_fused_mlp})")
