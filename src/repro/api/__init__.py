"""`repro.api` — the staged, inspectable front-end for the CELLO toolchain.

The paper's contribution is a *co-designed pipeline*: SCORE's schedule search
and CHORD's hybrid buffer split are decided jointly and lowered onto the
hardware together.  This package exposes that pipeline as explicit stages::

    from repro.api import Session

    plan = (Session("gemma_7b")
            .trace(phase="decode")       # TracedGraph   — the op DAG
            .analyze()                   # AnalyzedGraph — reuse structure
            .codesign()                  # CoDesigned    — schedule × buffer
            .lower())                    # CompiledPlan  — kernels + remat
    print(plan.explain())
    plan.serve()                         # or plan.train(...)

Search internals are a registry of composable passes with pluggable ordering
strategies (``repro.core.search``), re-exported here so new strategies and
buffer policies plug in without touching call sites.  Execution backends
(``repro.exec``) follow the same registry pattern: frontend plans run via
``CompiledPlan.run(backend="reference" | "pallas" | ...)``.

The 0.2-era flat entry points (``co_design``, ``plan_from_codesign``) were
removed in 0.4 — see ``docs/api_migration.md`` for the mapping.
"""
from ..core.costmodel import HardwareModel, V5E
from ..core.search import (DEFAULT_SPLITS, EvaluatePass, FusionPass,
                           OrderPass, PASS_REGISTRY, Pass, PinPass,
                           SearchContext, SearchPoint, SearchStrategy,
                           SplitSweepPass, STRATEGY_REGISTRY,
                           default_pipeline, get_strategy, register_pass,
                           register_strategy, run_codesign, run_pipeline)
from ..exec import (EXECUTOR_REGISTRY, Executor, get_backend, list_backends,
                    register_backend)
from .artifacts import AnalyzedGraph, CoDesigned, CompiledPlan, TracedGraph
from .cache import CodesignCache, frontend_fingerprint, graph_fingerprint
from .config import CodesignConfig, ExecConfig, ServeConfig
from .session import PHASES, Session

__all__ = [
    "Session", "PHASES",
    "CodesignConfig", "ExecConfig", "ServeConfig",
    "TracedGraph", "AnalyzedGraph", "CoDesigned", "CompiledPlan",
    "CodesignCache", "frontend_fingerprint", "graph_fingerprint",
    "HardwareModel", "V5E",
    "Pass", "OrderPass", "FusionPass", "PinPass", "SplitSweepPass",
    "EvaluatePass", "SearchContext", "SearchPoint", "SearchStrategy",
    "PASS_REGISTRY", "STRATEGY_REGISTRY", "DEFAULT_SPLITS",
    "default_pipeline", "get_strategy", "register_pass", "register_strategy",
    "run_codesign", "run_pipeline",
    "Executor", "EXECUTOR_REGISTRY", "get_backend", "list_backends",
    "register_backend",
]
