"""Typed configuration objects for the public API (0.10).

Three frozen dataclasses consolidate the keyword sprawl that grew on
``Session.codesign``, ``Session.lower`` / ``CompiledPlan.run`` /
``CompiledPlan.batched``, and ``Server``:

* :class:`CodesignConfig` — the schedule × buffer search knobs.
* :class:`ExecConfig` — lowering/execution: backend, device mesh,
  buffer donation, pallas interpret mode.
* :class:`ServeConfig` — batching, admission control, and resilience
  (retry / fallback / circuit breaker) for :class:`repro.serve.Server`.

Every legacy keyword keeps working for one release through a single
normalization shim (:func:`resolve_config`): passing the old kwargs
emits a :class:`DeprecationWarning` and builds the equivalent config;
passing *both* a config and legacy kwargs is a :class:`TypeError`
(there is no sensible merge order).  ``docs/api_migration.md`` maps
every old name to its new field.

``ExecConfig.interpret`` / ``ExecConfig.donate`` deserve a note: the
pallas executor reads the process-level toggles
``CELLO_PALLAS_INTERPRET`` / ``CELLO_PALLAS_DONATE`` when it builds a
program, so these two fields *pin the process-level toggle* when set
(a programmatic spelling of the env var, applied at ``lower()`` /
``run()`` time) rather than acting per-plan.  ``donate`` additionally
flows per-plan into ``CompiledPlan.batched``, which already threads an
explicit donation flag.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Any, Dict, Optional, Sequence, Tuple, Union

from ..core.search import DEFAULT_SPLITS

__all__ = [
    "CodesignConfig", "ExecConfig", "ServeConfig",
    "UNSET", "resolve_config",
]


class _Unset:
    """Sentinel for 'keyword not passed' (``None`` is meaningful for
    several legacy defaults, e.g. ``Server(fallback=None)`` disables
    fallback while omitting it means ``"reference"``)."""

    _inst = None

    def __new__(cls):
        if cls._inst is None:
            cls._inst = super().__new__(cls)
        return cls._inst

    def __repr__(self) -> str:
        return "<unset>"

    def __bool__(self) -> bool:
        return False


UNSET = _Unset()


@dataclasses.dataclass(frozen=True)
class CodesignConfig:
    """Knobs of the joint schedule × buffer search
    (``Session.codesign``).

    Field-for-field the old keyword surface: ``strategy`` (registered
    name or strategy instance), ``capacity_bytes`` (None → session
    capacity), ``max_orders``, ``splits`` (explicit/implicit boundary
    candidates), ``overbook`` (fractional pin spill for sparse
    operands), ``use_cache`` (None → session default).
    """
    strategy: Any = "default"
    capacity_bytes: Optional[int] = None
    max_orders: int = 16
    splits: Sequence[float] = DEFAULT_SPLITS
    overbook: float = 0.0
    use_cache: Optional[bool] = None


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """Lowering/execution knobs (``Session.lower``,
    ``CompiledPlan.run`` / ``batched``).

    ``backend`` — any name registered in ``repro.exec`` (None keeps
    the surface's default).  ``mesh`` — shard count ``K`` or
    ``(axis_name, K)``; partitions the co-designed DAG across the
    first ``K`` devices (see ``docs/distributed.md``).  ``donate`` /
    ``interpret`` — pin the ``CELLO_PALLAS_DONATE`` /
    ``CELLO_PALLAS_INTERPRET`` process toggles when not None (see the
    module docstring; donation is additionally honoured per-plan by
    ``batched``).
    """
    backend: Optional[str] = None
    mesh: Optional[Union[int, Tuple[str, int]]] = None
    donate: Optional[bool] = None
    interpret: Optional[bool] = None

    def apply_toggles(self) -> None:
        """Pin the process-level pallas toggles this config sets."""
        if self.interpret is not None:
            os.environ["CELLO_PALLAS_INTERPRET"] = \
                "1" if self.interpret else "0"
        if self.donate is not None:
            os.environ["CELLO_PALLAS_DONATE"] = \
                "1" if self.donate else "0"


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Batching + admission + resilience knobs of
    :class:`repro.serve.Server`.

    ``retry`` takes a :class:`repro.serve.RetryPolicy`;
    ``fallback=None`` disables backend fallback;
    ``breaker_failures=None`` disables the circuit breaker.
    """
    max_batch_size: int = 16
    max_wait_us: float = 2000.0
    max_plans: int = 8
    autostart: bool = True
    policy: str = "oldest"
    max_queue: Optional[int] = None
    overload: str = "block"
    retry: Optional[Any] = None
    fallback: Optional[str] = "reference"
    breaker_failures: Optional[int] = 3
    breaker_reset_s: float = 30.0
    max_worker_restarts: int = 2


def resolve_config(cls, config, legacy: Dict[str, Any], where: str):
    """Normalize ``(config=, **legacy kwargs)`` to one config instance.

    The single deprecation shim behind every config-accepting surface:
    legacy kwargs still passed (values ``is not UNSET``) build the
    equivalent config with a :class:`DeprecationWarning`; mixing them
    with an explicit ``config=`` raises (no merge order is obvious);
    neither given returns ``cls()`` defaults.
    """
    given = {k: v for k, v in legacy.items() if v is not UNSET}
    if config is not None:
        if given:
            raise TypeError(
                f"{where}: pass either config= or the legacy keyword(s) "
                f"{sorted(given)}, not both")
        if not isinstance(config, cls):
            raise TypeError(f"{where}: config= takes a {cls.__name__}, "
                            f"got {type(config).__name__}")
        return config
    if given:
        warnings.warn(
            f"{where}: keyword argument(s) {sorted(given)} are deprecated "
            f"since 0.10 and will be removed in 0.11; pass "
            f"config={cls.__name__}(...) instead "
            f"(see docs/api_migration.md)",
            DeprecationWarning, stacklevel=3)
        return dataclasses.replace(cls(), **given)
    return cls()
