"""Disk cache for co-design results.

Repeated benchmark / serving runs hit the same (arch, phase, hw, capacity)
cells over and over; the search is deterministic, so its result is cached on
disk as JSON and replayed instead of re-searched.  Keys additionally cover a
content fingerprint of the traced graph and the search knobs, so a config or
strategy change can never alias a stale entry.

JSON round-trips Python floats exactly (``float(repr(x)) == x``), so a cache
hit is bit-identical to the search that produced it.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import inspect
import json
import logging
import os
import pathlib
import re
import tempfile
from typing import Any, Dict, Optional

from .. import obs
from ..testing import faults
from ..core.buffer import BufferConfig, TrafficReport
from ..core.costmodel import HardwareModel, Metrics
from ..core.graph import OpGraph
from ..core.schedule import (CoDesignResult, EvaluatedSchedule, PartialPin,
                             PinSet, Schedule)

_FORMAT_VERSION = 1

_CACHE_HITS = obs.registry().counter(
    "codesign.cache.hits", "codesign disk-cache entries replayed")
_CACHE_MISSES = obs.registry().counter(
    "codesign.cache.misses",
    "codesign disk-cache lookups that re-searched (absent/corrupt/stale)")
_CACHE_CORRUPT = obs.registry().counter(
    "codesign.cache.corrupt",
    "codesign disk-cache entries found corrupt/truncated/stale-format "
    "(logged, deleted, re-derived — also counted in misses)")
_CACHE_READ_B = obs.registry().counter(
    "codesign.cache.read_bytes", "bytes read on codesign cache hits",
    unit="B")
_CACHE_WRITE_B = obs.registry().counter(
    "codesign.cache.write_bytes", "bytes published to the codesign cache",
    unit="B")


def default_cache_dir() -> pathlib.Path:
    env = os.environ.get("CELLO_CACHE_DIR")
    if env:
        return pathlib.Path(env).expanduser()
    return pathlib.Path("~/.cache/cello/codesign").expanduser()


def cache_disabled_by_env() -> bool:
    # CELLO_NO_CACHE=0 / =false / ="" means "leave caching on"
    return os.environ.get("CELLO_NO_CACHE", "").lower() not in ("", "0", "false")


def graph_fingerprint(graph: OpGraph) -> str:
    """Content hash over tensors + ops (shapes, dtypes, kinds, FLOPs)."""
    h = hashlib.sha256()
    for t in graph.tensors.values():
        h.update(repr((t.name, t.shape, t.dtype_bytes, t.kind.value,
                       t.meta)).encode())
    for o in graph.topo_order():
        op = graph.ops[o]
        h.update(repr((op.name, op.spec, op.inputs, op.output, op.flops,
                       op.irregular)).encode())
    return h.hexdigest()


def hw_fingerprint(hw: HardwareModel) -> str:
    return hashlib.sha256(repr(dataclasses.astuple(hw)).encode()).hexdigest()


def frontend_fingerprint(program) -> Optional[str]:
    """Cache-key component for frontend-built (HPC) graphs: the expression
    DAG's content hash plus the frontend lowering code itself, so an edit
    to ``frontends.expr`` invalidates entries even when the lowered graph
    would hash the same.  ``None`` for registry (LLM) traces."""
    if program is None:
        return None
    from ..frontends import expr
    h = hashlib.sha256(program.fingerprint().encode())
    try:
        h.update(inspect.getsource(expr).encode())
    except OSError:                    # no source (zipapp etc.)
        from .. import __version__
        h.update(__version__.encode())
    return h.hexdigest()


def strategy_fingerprint(strategy) -> Optional[str]:
    """Hash of the strategy implementation's source code.

    `algo_fingerprint` only covers the core modules, so a user-registered
    custom strategy edited between runs would otherwise replay a stale
    cached search under its unchanged name.  Instance state is folded in
    too: two differently-configured instances of one class (e.g. a beam
    width knob) must not alias each other's entries.  Returns None when
    the source is unavailable (e.g. a REPL-defined class): the caller must
    then skip the disk cache entirely."""
    try:
        # the whole MRO (minus object): an edited user base class holding
        # orders() must invalidate entries keyed by an unchanged subclass
        src = "\0".join(inspect.getsource(klass)
                        for klass in type(strategy).__mro__
                        if klass is not object)
    except (OSError, TypeError):
        return None
    attrs = dict(getattr(strategy, "__dict__", {}))
    for klass in type(strategy).__mro__:      # __slots__-based state too
        slots = getattr(klass, "__slots__", ())
        for slot in ((slots,) if isinstance(slots, str) else slots):
            if hasattr(strategy, slot):
                attrs[slot] = getattr(strategy, slot)
    state = repr(sorted(attrs.items()))
    if re.search(r"0x[0-9a-fA-F]{6,}", state):
        # address-bearing default reprs (functions, lambdas, objects) differ
        # per process — the key would never repeat, a permanent silent miss;
        # declare the strategy uncacheable instead
        return None
    return hashlib.sha256((src + "\0" + state).encode()).hexdigest()


@functools.lru_cache(maxsize=1)
def algo_fingerprint() -> str:
    """Hash of the search/simulator/cost-model source code.

    Folding this into cache keys means *any* edit to the co-design
    arithmetic invalidates old entries — no stale replays between version
    bumps."""
    from ..core import buffer, costmodel, graph, reuse, schedule, search
    h = hashlib.sha256()
    for mod in (buffer, costmodel, graph, reuse, schedule, search):
        try:
            h.update(inspect.getsource(mod).encode())
        except OSError:       # no source (zipapp etc.): fall back to version
            from .. import __version__
            h.update(__version__.encode())
    return h.hexdigest()


# --------------------------------------------------------------------------
# (de)serialization
# --------------------------------------------------------------------------

def _sched_to(s: Schedule) -> Dict[str, Any]:
    out = {
        "order": list(s.order),
        "groups": [list(g) for g in s.groups],
        "pins": {t: list(ab) for t, ab in s.pins.items()},
        "config": dataclasses.asdict(s.config),
    }
    partial = getattr(s.pins, "partial", None)
    if partial:
        out["partial"] = {t: dataclasses.asdict(pp)
                          for t, pp in partial.items()}
    return out


def _sched_from(d: Dict[str, Any]) -> Schedule:
    pins = PinSet({t: tuple(ab) for t, ab in d["pins"].items()})
    for t, pp in d.get("partial", {}).items():
        pins.partial[t] = PartialPin(**pp)
    return Schedule(
        order=list(d["order"]),
        groups=[list(g) for g in d["groups"]],
        pins=pins,
        config=BufferConfig(**d["config"]),
    )


def _ev_to(ev: EvaluatedSchedule) -> Dict[str, Any]:
    return {
        "schedule": _sched_to(ev.schedule),
        "report": dataclasses.asdict(ev.report),
        "metrics": dataclasses.asdict(ev.metrics),
    }


def _ev_from(d: Dict[str, Any]) -> EvaluatedSchedule:
    return EvaluatedSchedule(
        schedule=_sched_from(d["schedule"]),
        report=TrafficReport(**d["report"]),
        metrics=Metrics(**d["metrics"]),
    )


def result_to_dict(res: CoDesignResult) -> Dict[str, Any]:
    return {
        "v": _FORMAT_VERSION,
        "best": _ev_to(res.best),
        "baselines": {k: _ev_to(v) for k, v in res.baselines.items()},
        # float keys serialized by repr so they round-trip exactly
        "split_sweep": {repr(k): dataclasses.asdict(v)
                        for k, v in res.split_sweep.items()},
        "overbook": res.overbook,
    }


def result_from_dict(d: Dict[str, Any]) -> CoDesignResult:
    if d.get("v") != _FORMAT_VERSION:
        raise ValueError(f"cache format {d.get('v')!r} != {_FORMAT_VERSION}")
    return CoDesignResult(
        best=_ev_from(d["best"]),
        baselines={k: _ev_from(v) for k, v in d["baselines"].items()},
        split_sweep={float(k): Metrics(**v)
                     for k, v in d["split_sweep"].items()},
        overbook=d.get("overbook", 0.0),
    )


# --------------------------------------------------------------------------
# the cache
# --------------------------------------------------------------------------

class CodesignCache:
    """One JSON file per key under ``root`` (atomic, best-effort writes).

    **Concurrency contract** (a serving process hits this from several
    threads/processes at once): every writer serializes into its *own*
    ``mkstemp`` temp file — unique per writer, no shared partial file —
    and publishes it with a single atomic ``os.replace`` onto the final
    path.  Readers only ever open the final path, so they see either a
    previous complete entry or the new complete entry, never a torn
    write.  Racing writers of the same key are last-writer-wins, which is
    safe because the search is deterministic: both writers hold the same
    bytes.  No file locks are needed; failures (read-only cache dir, disk
    full, Windows replace-over-open) degrade to a miss/no-op — caching is
    best-effort and the computed result always stands.
    """

    def __init__(self, root: Optional[os.PathLike] = None):
        self.root = pathlib.Path(root) if root else default_cache_dir()

    @staticmethod
    def key(**fields: Any) -> str:
        blob = json.dumps(fields, sort_keys=True, default=repr)
        return hashlib.sha256(blob.encode()).hexdigest()

    def _path(self, key: str) -> pathlib.Path:
        return self.root / f"{key}.json"

    def get(self, key: str) -> Optional[CoDesignResult]:
        path = self._path(key)
        try:
            with open(path) as f:
                blob = f.read()
        except OSError:
            _CACHE_MISSES.inc()
            return None    # absent (or unreadable): plain miss, re-search
        # fault-injection site (docs/robustness.md): codesign.cache —
        # a corrupt rule truncates the entry as if the disk had
        blob = faults.corrupt_text("codesign.cache", blob)
        try:
            res = result_from_dict(json.loads(blob))
        except (ValueError, KeyError, TypeError):
            # corrupt / truncated / stale-format entry: count it, drop the
            # bad file so the re-derived result can be re-published, and
            # re-search — never raise out of a cache read
            _CACHE_CORRUPT.inc()
            _CACHE_MISSES.inc()
            logging.getLogger(__name__).warning(
                "codesign cache entry %s is corrupt or stale; deleting "
                "and re-deriving", path.name)
            try:
                os.unlink(path)
            except OSError:
                pass
            return None
        _CACHE_HITS.inc()
        _CACHE_READ_B.inc(len(blob))
        return res

    def put(self, key: str, res: CoDesignResult) -> None:
        tmp = None
        try:
            blob = json.dumps(result_to_dict(res))
            self.root.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                f.write(blob)
            os.replace(tmp, self._path(key))
            tmp = None
            _CACHE_WRITE_B.inc(len(blob))
        except OSError:
            pass           # caching is best-effort; the search result stands
        finally:
            if tmp is not None:     # failed mid-write: don't orphan the .tmp
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
