"""Jit'd public wrapper for the WKV6 kernel."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel import wkv6 as _wkv6_call


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
         u: jnp.ndarray, s0: Optional[jnp.ndarray] = None, *,
         interpret: Optional[bool] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    interp = _on_cpu() if interpret is None else interpret
    return _wkv6_call(r, k, v, w, u, s0, interpret=interp)
