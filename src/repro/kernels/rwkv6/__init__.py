from .ops import wkv6
from .ref import wkv6_reference

__all__ = ["wkv6", "wkv6_reference"]
