"""WKV6 recurrence Pallas TPU kernel (RWKV-6 / Finch time-mix core).

CELLO treatment: the per-head (E × E) f32 state matrix is the explicit-
buffer resident — it lives in VMEM scratch for the whole sequence and hits
HBM exactly twice (initial load, final store).  r/k/v/decay stream through
VMEM in (S, E) tiles.  E = 64 for all RWKV-6 sizes, so the state tile is
16 KiB — VREG/VMEM friendly; the sequential fori_loop over time is the
TPU-native replacement for the CUDA per-warp scan in the reference
implementations (documented hardware adaptation).

Grid: (batch, heads), both parallel.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
                 s_scr, *, seq_len: int):
    s_scr[...] = s0_ref[0, 0].astype(jnp.float32)          # (E, E)
    u = u_ref[0].astype(jnp.float32)                       # (E,)

    def step(t, _):
        rt = r_ref[0, 0, t, :].astype(jnp.float32)         # (E,)
        kt = k_ref[0, 0, t, :].astype(jnp.float32)
        vt = v_ref[0, 0, t, :].astype(jnp.float32)
        dt = jnp.exp(-jnp.exp(w_ref[0, 0, t, :].astype(jnp.float32)))
        s = s_scr[...]
        kv = kt[:, None] * vt[None, :]                     # (E, E)
        y = ((s + u[:, None] * kv) * rt[:, None]).sum(axis=0)
        s_scr[...] = dt[:, None] * s + kv
        y_ref[0, 0, t, :] = y.astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, seq_len, step, ())
    sT_ref[0, 0] = s_scr[...].astype(sT_ref.dtype)


def wkv6(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
         u: jnp.ndarray, s0: Optional[jnp.ndarray] = None, *,
         interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r,k,v,w: (B,H,S,E); u: (H,E); s0: (B,H,E,E). -> (y, sT)."""
    B, H, S, E = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, E, E), jnp.float32)
    grid = (B, H)
    seq_spec = pl.BlockSpec((1, 1, S, E), lambda b, h: (b, h, 0, 0))
    u_spec = pl.BlockSpec((1, E), lambda b, h: (h, 0))
    s_spec = pl.BlockSpec((1, 1, E, E), lambda b, h: (b, h, 0, 0))

    y, sT = pl.pallas_call(
        functools.partial(_wkv6_kernel, seq_len=S),
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec, u_spec, s_spec],
        out_specs=[seq_spec, s_spec],
        out_shape=[jax.ShapeDtypeStruct((B, H, S, E), r.dtype),
                   jax.ShapeDtypeStruct((B, H, E, E), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((E, E), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, sT
