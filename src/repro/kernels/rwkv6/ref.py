"""Pure-jnp oracle for the RWKV-6 (Finch) WKV recurrence.

Per head with key/value dim E, state S in R^{E×E}:

    y_t   = (S_t + (u ⊙ k_t) v_t^T)^T r_t
    S_t+1 = diag(w_t) S_t + k_t v_t^T

with data-dependent decay w_t = exp(-exp(w̃_t)) and learned bonus u.
All math f32; returns y in r.dtype plus the final state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def wkv6_reference(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   w: jnp.ndarray, u: jnp.ndarray,
                   s0: Optional[jnp.ndarray] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r,k,v,w: (B, H, S, E); u: (H, E); s0: (B, H, E, E) or None.

    ``w`` is the log-decay pre-activation w̃ (decay = exp(-exp(w̃))).
    Returns (y: (B,H,S,E), sT: (B,H,E,E))."""
    B, H, S, E = r.shape
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    decay = jnp.exp(-jnp.exp(w.astype(jnp.float32)))
    uf = u.astype(jnp.float32)
    s = (jnp.zeros((B, H, E, E), jnp.float32) if s0 is None
         else s0.astype(jnp.float32))

    def step(s, t):
        rt, kt, vt, dt = rf[:, :, t], kf[:, :, t], vf[:, :, t], decay[:, :, t]
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,E,E)
        # y_t[j] = sum_i r_t[i] * (S[i,j] + u[i] k_t[i] v_t[j])
        y = jnp.einsum("bhi,bhij->bhj", rt, s + uf[None, :, :, None] * kv)
        s = dt[..., :, None] * s + kv
        return s, y

    sT, ys = jax.lax.scan(step, s, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 2)                              # (B,H,S,E)
    return y.astype(r.dtype), sT
