"""Pallas TPU kernels — the explicit-buffer instantiations of CELLO fusion
groups. Each subpackage: kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd wrapper, interpret-mode on CPU), ref.py (pure-jnp
oracle used by the allclose test sweeps)."""
from .flash_attention import flash_attention, mha_reference
from .fused_mlp import fused_mlp, mlp_reference
from .rglru import rglru, rglru_reference
from .rwkv6 import wkv6, wkv6_reference
from .rmsnorm import rmsnorm, rmsnorm_reference

__all__ = ["flash_attention", "mha_reference", "fused_mlp", "mlp_reference",
           "rglru", "rglru_reference", "wkv6", "wkv6_reference",
           "rmsnorm", "rmsnorm_reference"]
