"""Jit'd public wrapper for the fused RMSNorm kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import rmsnorm as _rmsnorm_call


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("eps", "row_block", "interpret"))
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, *, eps: float = 1e-6,
            row_block: int = 256,
            interpret: Optional[bool] = None) -> jnp.ndarray:
    interp = _on_cpu() if interpret is None else interpret
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    out = _rmsnorm_call(x2, w, eps=eps, row_block=row_block, interpret=interp)
    return out.reshape(*lead, -1)
