"""Fused RMSNorm Pallas TPU kernel.

Single pass over a (row_block, D) VMEM tile: mean-of-squares reduction and
the normalise+scale stay fused — x is read from HBM once and y written once
(the unfused HLO does two passes).  Uses the Gemma convention
``y = x * rsqrt(mean x² + eps) * (1 + w)``.

Grid: (row_blocks,), parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._compat import CompilerParams


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                    # (rb, D)
    var = (x * x).mean(axis=-1, keepdims=True)
    w = w_ref[...].astype(jnp.float32)                    # (1, D)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * (1.0 + w)).astype(o_ref.dtype)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, *, eps: float = 1e-6,
            row_block: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x: (M, D); w: (D,)."""
    M, D = x.shape
    row_block = min(row_block, M)
    Mp = -(-M // row_block) * row_block
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(Mp // row_block,),
        in_specs=[pl.BlockSpec((row_block, D), lambda i: (i, 0)),
                  pl.BlockSpec((1, D), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((row_block, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, D), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, w[None, :])
    return out[:M]
