"""Pure-jnp oracle for fused RMSNorm."""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_reference(x: jnp.ndarray, w: jnp.ndarray,
                      eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(axis=-1, keepdims=True)
    y = xf * jnp.reciprocal(jnp.sqrt(var + eps)) * (1.0 + w.astype(jnp.float32))
    return y.astype(x.dtype)
