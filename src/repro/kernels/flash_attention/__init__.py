from .ops import flash_attention
from .ref import mha_reference

__all__ = ["flash_attention", "mha_reference"]
