"""Flash attention Pallas TPU kernel — the CELLO "explicit buffer" for the
attention fusion group.

The schedule's fusion group {scores, softmax, pv} lowers to this kernel: the
(q_block × kv_block) score tile, the running softmax statistics and the
output accumulator live in VMEM scratch (the explicit region); K/V stream
through VMEM tile-by-tile.  The score matrix never materialises in HBM —
exactly the traffic the hybrid-buffer simulator credits to this fusion group.

Grid: (batch, heads, q_blocks, kv_blocks); kv is innermost and sequential
("arbitrary") so VMEM scratch accumulates across kv tiles; the outer three
axes are parallel.  GQA is handled in the K/V BlockSpec index maps
(h → h * KVH // H), so repeated K/V never moves through HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  q_block: int, kv_block: int, kv_blocks: int,
                  q_offset: int, t_valid: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level early-out for fully-masked tiles (saves MXU work)
    needed = ik * kv_block < t_valid
    if causal:
        needed = jnp.logical_and(
            needed, ik * kv_block <= iq * q_block + q_offset + q_block - 1)
    if window is not None:
        needed = jnp.logical_and(
            needed, (ik + 1) * kv_block > iq * q_block + q_offset - window + 1)

    @pl.when(needed)
    def _compute():
        # absolute positions (queries offset when T != S: decode/extension)
        q_pos = iq * q_block + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 0) + q_offset
        k_pos = ik * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 1)

        q = q_ref[0, 0].astype(jnp.float32) * scale       # (qb, E)
        k = k_ref[0, 0].astype(jnp.float32)               # (kb, E)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = k_pos < t_valid                            # kv padding
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                               # (qb, 1)
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l_prev + p.sum(axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)               # (kb, E)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    q_block: int = 512, kv_block: int = 512,
                    interpret: bool = False) -> jnp.ndarray:
    """Fused attention. q: (B,H,S,E); k,v: (B,KVH,T,E). Returns (B,H,S,E)."""
    B, H, S, E = q.shape
    KVH, T = k.shape[1], k.shape[2]
    assert H % KVH == 0, (H, KVH)
    scale = scale if scale is not None else E ** -0.5
    q_block = min(q_block, S)
    kv_block = min(kv_block, T)
    Sp = -(-S // q_block) * q_block
    Tp = -(-T // kv_block) * kv_block
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    grid = (B, H, Sp // q_block, Tp // kv_block)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_block=q_block, kv_block=kv_block, kv_blocks=grid[3],
        q_offset=T - S, t_valid=T)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_block, E),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, kv_block, E),
                         lambda b, h, iq, ik: (b, h * KVH // H, ik, 0)),
            pl.BlockSpec((1, 1, kv_block, E),
                         lambda b, h, iq, ik: (b, h * KVH // H, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, E),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, E), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),       # running max
            pltpu.VMEM((q_block, 1), jnp.float32),       # running denom
            pltpu.VMEM((q_block, E), jnp.float32),       # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S, :]
