"""Pure-jnp oracle for flash attention (causal / sliding-window / GQA)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def mha_reference(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """Reference attention.

    Args:
      q: (B, H, S, E)
      k, v: (B, KVH, T, E) with H % KVH == 0 (GQA broadcast)
      causal: apply causal mask (q position i attends to kv positions <= i,
        aligned at the end: kv position j corresponds to query i = j + S - T
        offsets when T != S).
      window: if set, query i attends only to j in (i - window, i].
    Returns: (B, H, S, E) in q.dtype.
    """
    B, H, S, E = q.shape
    KVH, T = k.shape[1], k.shape[2]
    assert H % KVH == 0
    rep = H // KVH
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else E ** -0.5
    logits = jnp.einsum("bhse,bhte->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qi = jnp.arange(S)[:, None] + (T - S)       # absolute kv-aligned position
    kj = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.nan_to_num(jnp.exp(logits - logits.max(-1, keepdims=True)))
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhst,bhte->bhse", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
