"""Jit'd public wrapper for the flash-attention kernel.

On CPU (this container) the kernel body executes in Pallas interpret mode —
numerics identical, used by tests; on TPU it compiles through Mosaic.
Head dims that aren't lane-aligned (multiples of 128) are zero-padded: QK^T
over zero-padded features adds zero, padded V columns are sliced off.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention as _flash_kernel_call


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "q_block", "kv_block",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    q_block: int = 512, kv_block: int = 512,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused attention, lane-aligned. q (B,H,S,E); k,v (B,KVH,T,E)."""
    interp = _on_cpu() if interpret is None else interpret
    E = q.shape[-1]
    Ep = -(-E // 128) * 128
    if Ep != E:
        pad = ((0, 0), (0, 0), (0, 0), (0, Ep - E))
        # scale must follow the true head dim, not the padded one
        scale = scale if scale is not None else E ** -0.5
        q, k, v = (jnp.pad(t, pad) for t in (q, k, v))
    out = _flash_kernel_call(q, k, v, causal=causal, window=window,
                             scale=scale, q_block=q_block, kv_block=kv_block,
                             interpret=interp)
    return out[..., :E]
