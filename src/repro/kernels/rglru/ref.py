"""Pure-jnp oracle for the RG-LRU recurrence (recurrentgemma / Griffin).

    r_t = sigmoid(gate_r_t)                 (recurrence gate, pre-act input)
    i_t = sigmoid(gate_i_t)                 (input gate)
    a_t = exp(c * softplus(a_param) * (-r_t))       elementwise, c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

All math in f32; returns h in x.dtype plus the final state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

RGLRU_C = 8.0


def rglru_reference(x: jnp.ndarray, gate_r: jnp.ndarray, gate_i: jnp.ndarray,
                    a_param: jnp.ndarray,
                    h0: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x, gate_r, gate_i: (B, S, D); a_param: (D,); h0: (B, D) or None."""
    B, S, D = x.shape
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(gate_r.astype(jnp.float32))
    i = jax.nn.sigmoid(gate_i.astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(a_param.astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_x = i * xf
    # sqrt(1 - a^2) input normalisation (Griffin eq. 4)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    h = jnp.zeros((B, D), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        h = a[:, t] * h + beta[:, t] * gated_x[:, t]
        return h, h

    hT, hs = jax.lax.scan(step, h, jnp.arange(S))
    return jnp.swapaxes(hs, 0, 1).astype(x.dtype), hT
