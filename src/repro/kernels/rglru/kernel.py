"""RG-LRU recurrence Pallas TPU kernel.

The recurrence is sequential in time — CELLO marks it ``scan`` (unfusable
with neighbouring matmuls) and gives it a dedicated kernel whose *state* is
the explicit-buffer resident: h (B-tile × D-tile, f32) lives in VMEM scratch
across the whole time loop and is written to HBM exactly once at the end.

Grid: (batch, d_blocks) — both parallel (channels are independent; the
sequential dependency is the in-kernel fori_loop over time).  Inputs stream
as (1, S, d_block) VMEM tiles.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

from .ref import RGLRU_C


def _rglru_kernel(x_ref, gr_ref, gi_ref, ap_ref, h0_ref, y_ref, hT_ref,
                  h_scr, *, seq_len: int):
    h_scr[...] = h0_ref[...].astype(jnp.float32)          # (1, db)
    a_param = ap_ref[...].astype(jnp.float32)             # (1, db)
    log_a_coef = -RGLRU_C * jax.nn.softplus(a_param)

    def step(t, _):
        x = x_ref[0, t, :].astype(jnp.float32)[None, :]
        r = jax.nn.sigmoid(gr_ref[0, t, :].astype(jnp.float32))[None, :]
        i = jax.nn.sigmoid(gi_ref[0, t, :].astype(jnp.float32))[None, :]
        a = jnp.exp(log_a_coef * r)
        beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
        h = a * h_scr[...] + beta * (i * x)
        h_scr[...] = h
        y_ref[0, t, :] = h[0].astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, seq_len, step, ())
    hT_ref[...] = h_scr[...].astype(hT_ref.dtype)


def rglru(x: jnp.ndarray, gate_r: jnp.ndarray, gate_i: jnp.ndarray,
          a_param: jnp.ndarray, h0: Optional[jnp.ndarray] = None, *,
          d_block: int = 512, interpret: bool = False
          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x, gate_r, gate_i: (B,S,D); a_param: (D,); h0: (B,D). -> (y, hT)."""
    B, S, D = x.shape
    d_block = min(d_block, D)
    Dp = -(-D // d_block) * d_block
    if Dp != D:
        pad3 = ((0, 0), (0, 0), (0, Dp - D))
        x, gate_r, gate_i = (jnp.pad(t, pad3) for t in (x, gate_r, gate_i))
        a_param = jnp.pad(a_param, (0, Dp - D))
    if h0 is None:
        h0 = jnp.zeros((B, Dp), jnp.float32)
    elif Dp != D:
        h0 = jnp.pad(h0, ((0, 0), (0, Dp - D)))
    ap2 = a_param[None, :]                                 # (1, Dp)

    grid = (B, Dp // d_block)
    seq_spec = pl.BlockSpec((1, S, d_block), lambda b, j: (b, 0, j))
    vec_spec = pl.BlockSpec((1, d_block), lambda b, j: (0, j))
    state_spec = pl.BlockSpec((1, d_block), lambda b, j: (b, j))

    y, hT = pl.pallas_call(
        functools.partial(_rglru_kernel, seq_len=S),
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, vec_spec, state_spec],
        out_specs=[seq_spec, state_spec],
        out_shape=[jax.ShapeDtypeStruct((B, S, Dp), x.dtype),
                   jax.ShapeDtypeStruct((B, Dp), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((1, d_block), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, gate_r, gate_i, ap2, h0)
    return y[:, :, :D], hT[:, :D]
