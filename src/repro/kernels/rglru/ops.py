"""Jit'd public wrapper for the RG-LRU kernel."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel import rglru as _rglru_call


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("d_block", "interpret"))
def rglru(x: jnp.ndarray, gate_r: jnp.ndarray, gate_i: jnp.ndarray,
          a_param: jnp.ndarray, h0: Optional[jnp.ndarray] = None, *,
          d_block: int = 512,
          interpret: Optional[bool] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    interp = _on_cpu() if interpret is None else interpret
    return _rglru_call(x, gate_r, gate_i, a_param, h0, d_block=d_block,
                       interpret=interp)
