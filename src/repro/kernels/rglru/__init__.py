from .ops import rglru
from .ref import rglru_reference

__all__ = ["rglru", "rglru_reference"]
