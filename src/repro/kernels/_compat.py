"""Version compatibility for Pallas TPU symbols.

`pltpu.TPUCompilerParams` was renamed to `pltpu.CompilerParams` in newer
JAX releases; resolve whichever this installation provides.
"""
from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) or \
    _pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
