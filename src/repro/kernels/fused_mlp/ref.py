"""Pure-jnp oracle for the fused gated MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _act(h: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "silu":
        return jax.nn.silu(h)
    if kind == "gelu":
        return jax.nn.gelu(h, approximate=True)
    if kind == "relu2":
        r = jax.nn.relu(h)
        return r * r
    raise ValueError(kind)


def mlp_reference(x: jnp.ndarray, w_gate, w_up, w_down, *,
                  activation: str = "silu") -> jnp.ndarray:
    """x: (M, D). Gated: h = act(x@w_gate) * (x@w_up); non-gated (w_gate is
    None): h = act(x@w_up).  Returns h @ w_down, in x.dtype, f32 compute."""
    xf = x.astype(jnp.float32)
    if w_gate is not None:
        g = xf @ w_gate.astype(jnp.float32)
        u = xf @ w_up.astype(jnp.float32)
        h = _act(g, activation) * u
    else:
        h = _act(xf @ w_up.astype(jnp.float32), activation)
    return (h @ w_down.astype(jnp.float32)).astype(x.dtype)
