"""Jit'd public wrapper for the fused MLP kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import fused_mlp as _fused_mlp_call


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("activation", "m_block",
                                             "f_block", "interpret"))
def fused_mlp(x: jnp.ndarray, w_gate: Optional[jnp.ndarray],
              w_up: jnp.ndarray, w_down: jnp.ndarray, *,
              activation: str = "silu", m_block: int = 256,
              f_block: int = 512,
              interpret: Optional[bool] = None) -> jnp.ndarray:
    interp = _on_cpu() if interpret is None else interpret
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    out = _fused_mlp_call(x2, w_gate, w_up, w_down, activation=activation,
                          m_block=m_block, f_block=f_block, interpret=interp)
    return out.reshape(*lead, -1)
