from .ops import fused_mlp
from .ref import mlp_reference

__all__ = ["fused_mlp", "mlp_reference"]
