"""Fused gated-MLP Pallas TPU kernel (up-proj → activation → down-proj).

CELLO's MLP fusion group {up, act, down}: the (m_block × f_block) hidden tile
and the (m_block × D) output accumulator live in VMEM (explicit region); the
hidden activation tensor (tokens × d_ff — the largest activation in a
transformer block) never reaches HBM.  Weights stream through VMEM in
f_block-wide tiles (double-buffered by the Pallas pipeline), matching the
streamed-weight-tile feasibility rule in ``core.schedule``.

Grid: (m_blocks, f_blocks); f innermost & sequential — the accumulator in
VMEM scratch integrates partial down-projections across hidden tiles.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams


def _act(h: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "silu":
        return h * jax.nn.sigmoid(h)
    if kind == "gelu":
        return jax.nn.gelu(h, approximate=True)
    if kind == "relu2":
        r = jnp.maximum(h, 0.0)
        return r * r
    raise ValueError(kind)


def _mlp_kernel_gated(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_scr, *,
                      activation: str, f_blocks: int):
    jf = pl.program_id(1)

    @pl.when(jf == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)                    # (mb, D)
    g = jax.lax.dot(x, wg_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)   # (mb, fb)
    u = jax.lax.dot(x, wu_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    h = _act(g, activation) * u
    acc_scr[...] += jax.lax.dot(h, wd_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(jf == f_blocks - 1)
    def _fin():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def _mlp_kernel_plain(x_ref, wu_ref, wd_ref, o_ref, acc_scr, *,
                      activation: str, f_blocks: int):
    jf = pl.program_id(1)

    @pl.when(jf == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)
    h = _act(jax.lax.dot(x, wu_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32), activation)
    acc_scr[...] += jax.lax.dot(h, wd_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(jf == f_blocks - 1)
    def _fin():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def fused_mlp(x: jnp.ndarray, w_gate: Optional[jnp.ndarray],
              w_up: jnp.ndarray, w_down: jnp.ndarray, *,
              activation: str = "silu", m_block: int = 256,
              f_block: int = 512, interpret: bool = False) -> jnp.ndarray:
    """x: (M, D); w_gate/w_up: (D, F); w_down: (F, D). Returns (M, D)."""
    M, D = x.shape
    F = w_up.shape[1]
    m_block = min(m_block, M)
    f_block = min(f_block, F)
    Mp = -(-M // m_block) * m_block
    Fp = -(-F // f_block) * f_block
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
    if Fp != F:
        pad_w = ((0, 0), (0, Fp - F))
        w_up = jnp.pad(w_up, pad_w)
        w_down = jnp.pad(w_down, ((0, Fp - F), (0, 0)))
        if w_gate is not None:
            w_gate = jnp.pad(w_gate, pad_w)
            # relu2/silu/gelu(0) = 0 ⇒ padded hidden cols contribute zero
    grid = (Mp // m_block, Fp // f_block)

    x_spec = pl.BlockSpec((m_block, D), lambda im, jf: (im, 0))
    wcol_spec = pl.BlockSpec((D, f_block), lambda im, jf: (0, jf))
    wrow_spec = pl.BlockSpec((f_block, D), lambda im, jf: (jf, 0))
    o_spec = pl.BlockSpec((m_block, D), lambda im, jf: (im, 0))
    scratch = [pltpu.VMEM((m_block, D), jnp.float32)]
    params = CompilerParams(
        dimension_semantics=("parallel", "arbitrary"))

    if w_gate is not None:
        kern = functools.partial(_mlp_kernel_gated, activation=activation,
                                 f_blocks=grid[1])
        out = pl.pallas_call(
            kern, grid=grid,
            in_specs=[x_spec, wcol_spec, wcol_spec, wrow_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((Mp, D), x.dtype),
            scratch_shapes=scratch, compiler_params=params,
            interpret=interpret,
        )(x, w_gate, w_up, w_down)
    else:
        kern = functools.partial(_mlp_kernel_plain, activation=activation,
                                 f_blocks=grid[1])
        out = pl.pallas_call(
            kern, grid=grid,
            in_specs=[x_spec, wcol_spec, wrow_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((Mp, D), x.dtype),
            scratch_shapes=scratch, compiler_params=params,
            interpret=interpret,
        )(x, w_up, w_down)
    return out[:M]
