"""Top-k MoE FFN with GShard-style 2D grouped dispatch + expert parallelism.

Tokens are viewed as (groups, tokens/group); groups align with the data-
parallel shards and experts shard over the "model" axis, so the dispatch
buffer (G, E, C, D) is sharded on *both* leading axes and every scatter/
gather stays shard-local — the naive global-scatter formulation partitions
catastrophically (the SPMD partitioner replicates the scatter; measured ~20×
FLOP inflation at 256 chips, recorded in EXPERIMENTS.md §Perf).

Capacity C = tokens_per_group × top_k × capacity_factor / E; overflow tokens
are dropped (standard Switch/GShard semantics) and their combine weight is
zero.

CELLO view: router probabilities and the dispatch permutation are *data
dependent* — their reuse is irregular, so the co-designer leaves them to the
implicit buffer region; the expert weight tiles stream through the explicit
region like any other matmul fusion group.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .common import (COMPUTE_DTYPE, activation_fn, constrain, get_mesh,
                     is_gated, tag)


def init_moe_params(key, d_model: int, d_ff: int, n_experts: int,
                    activation: str, dtype) -> Dict[str, jnp.ndarray]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    gated = is_gated(activation)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {
        "w_router": (jax.random.normal(k1, (d_model, n_experts)) *
                     scale_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (n_experts, d_model, d_ff)) *
                 scale_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (n_experts, d_ff, d_model)) *
                   scale_out).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(k4, (n_experts, d_model, d_ff)) *
                       scale_in).astype(dtype)
    return p


def moe_pspecs(activation: str) -> Dict[str, tuple]:
    """Logical PartitionSpec per param (expert axis on "model")."""
    specs = {
        "w_router": (None, None),
        "w_up": ("model", None, None),
        "w_down": ("model", None, None),
    }
    if is_gated(activation):
        specs["w_gate"] = ("model", None, None)
    return specs


def _n_groups(T: int, groups: Optional[int]) -> int:
    if groups is not None:
        return groups
    mesh = get_mesh()
    if mesh is None:
        return 1
    g = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            g *= mesh.shape[a]
    while g > 1 and T % g != 0:
        g //= 2
    return max(1, g)


def apply_moe(params: Dict[str, jnp.ndarray], x: jnp.ndarray, *,
              top_k: int, activation: str,
              capacity_factor: float = 1.25,
              groups: Optional[int] = None) -> jnp.ndarray:
    """x: (tokens, d_model) -> (tokens, d_model)."""
    T, D = x.shape
    E = params["w_router"].shape[1]
    act = activation_fn(activation)
    gated = is_gated(activation)
    G = _n_groups(T, groups)
    Tg = T // G
    C = max(top_k, int(Tg * top_k * capacity_factor) // E)

    xg = constrain(x.reshape(G, Tg, D), "batch", None, None)

    # --- routing (f32 numerics) ---------------------------------------
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["w_router"].astype(jnp.float32))
    logits = tag(logits, "router_logits")
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- per-(group, expert) slot assignment ----------------------------
    flat_e = idx.reshape(G, Tg * top_k)                       # (G, Tg*k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # (G, Tg*k, E)
    pos = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1   # (G, Tg*k)
    keep = (pos >= 0) & (pos < C)
    slot = jnp.clip(pos, 0, C - 1)

    # --- dispatch: per-group scatter (shard-local under SPMD) ----------
    xk = jnp.repeat(xg, top_k, axis=1)                        # (G, Tg*k, D)
    contrib = jnp.where(keep[..., None], xk.astype(COMPUTE_DTYPE), 0)

    def scatter_group(fe, sl, xb):
        return jnp.zeros((E, C, D), COMPUTE_DTYPE).at[fe, sl].add(xb)

    buf = jax.vmap(scatter_group)(flat_e, slot, contrib)      # (G, E, C, D)
    # two-step reshard: materialise the buffer token-local first, THEN move
    # it to expert shards — the backward of the reshard then travels on the
    # compact (G,E,C,D) buffer instead of all-reducing the full (G,Tg·k,D)
    # dispatched activation over the model axis (§Perf iteration 2b).
    buf = constrain(buf, "batch", None, None, None)
    buf = constrain(buf, "batch", "model", None, None)

    # --- expert FFN (experts sharded over "model") ----------------------
    up = jnp.einsum("gecd,edf->gecf", buf,
                    params["w_up"].astype(COMPUTE_DTYPE))
    if gated:
        g_ = jnp.einsum("gecd,edf->gecf", buf,
                        params["w_gate"].astype(COMPUTE_DTYPE))
        h = act(g_.astype(jnp.float32)).astype(COMPUTE_DTYPE) * up
    else:
        h = act(up.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    h = tag(h, "mlp_hidden")
    out_buf = jnp.einsum("gecf,efd->gecd", h,
                         params["w_down"].astype(COMPUTE_DTYPE))
    out_buf = constrain(out_buf, "batch", "model", None, None)

    # --- combine ---------------------------------------------------------
    # Reshard the compact (G,E,C,D) buffer back to token owners BEFORE the
    # gather.  Without this, XLA computes the gather against the expert-
    # sharded buffer and all-reduces the full dispatched activation
    # (G, Tg·k, D) in f32 over the model axis — measured 2 GiB/layer/dir on
    # granite-moe train_4k (EXPERIMENTS.md §Perf iteration 2a).  The
    # explicit reshard moves ~C/(Tg·k)·bf16 as a buffer collective instead.
    out_buf = constrain(out_buf, "batch", None, None, None)

    def gather_group(buf_g, fe, sl):
        return buf_g[fe, sl]                                  # (Tg*k, D)

    y = jax.vmap(gather_group)(out_buf, flat_e, slot)
    y = jnp.where(keep[..., None], y, 0)
    y = y.reshape(G, Tg, top_k, D) * gates[..., None].astype(COMPUTE_DTYPE)
    out = y.sum(axis=2).reshape(T, D)
    out = constrain(out, "batch", None)
    return out.astype(x.dtype)
