"""Model zoo: config-driven assembly of the ten assigned architectures."""
from .common import (COMPUTE_DTYPE, PARAM_DTYPE, constrain, get_mesh,
                     named_sharding, pspec, rms_norm, set_mesh_context)
from .transformer import (cache_pspecs, decode_step, forward, init_cache,
                          init_params, param_pspecs, period_structure)

__all__ = [
    "COMPUTE_DTYPE", "PARAM_DTYPE", "constrain", "get_mesh",
    "named_sharding", "pspec", "rms_norm", "set_mesh_context",
    "cache_pspecs", "decode_step", "forward", "init_cache", "init_params",
    "param_pspecs", "period_structure",
]
